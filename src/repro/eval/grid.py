"""Experiment grids: the conformance grids (``tiny``/``small``/``full``/
``engine-smoke``) plus spec constructors for every legacy ``benchmarks/``
table and figure.

This is stage 1 of the grid-cell lifecycle (spec → seeded RequestSet →
result → claim, see :mod:`repro.eval.spec`): a grid is nothing but a list
of :class:`ExperimentSpec` values; everything downstream — request
generation, replay, claims — is derived from them.

The conformance grids cross {workload case} x {SLO scale} x {seed} x
{system} and are what the claims layer (:mod:`repro.eval.claims`)
evaluates.  SLO scales are chosen where the repro's orderings are
*reproducible*: tight scales (1.25, 1.5) for the dominance claim and a
loose anchor (3.0) for the monotonicity claim.  Intermediate scales
(≈2×P99) are deliberately absent from the gated grids — there Nexus's
fixed-batch plan is genuinely competitive in this repro and the gate does
not assert an ordering the code does not reproduce (see DESIGN.md §7).
The small grid also carries a handful of heterogeneous pool cells feeding
the scale-out dispatch claim (§3.1: jsq_work >= round_robin).

``engine-smoke`` is the real-substrate tier: a few tiny
``substrate="engine"`` cells that drive the actual JAX model through the
same lifecycle (DESIGN.md §8).  It is tracked, not gated — engine finish
rates are real measurements and CI-runner timing variance is not yet
characterized.

The ``tableN``/``figN``/``cluster`` constructors mirror the historical
benchmark sweeps cell-for-cell; ``benchmarks/*.py`` are thin formatters
over them.
"""

from __future__ import annotations

from typing import Sequence

from .spec import ExperimentSpec

__all__ = ["GRIDS", "SYSTEMS", "tiny", "small", "full", "engine_smoke"]

# Every compared system, ORLOJ first (the paper's Tables 2-5 set plus the
# EDF ablation from core/baselines.py).
SYSTEMS = ("orloj", "clockwork", "nexus", "clipper", "edf")

# name -> (family, params, utilization) of the gated workload cases.
_SMALL_CASES = (
    ("bimodal", "bimodal", {"std": 1.0}, 0.85),
    ("3-modal", "k_modal", {"k": 3}, 0.85),
    ("static", "static", {"mean": 12.0}, 0.7),
)
_SMALL_SLOS = (1.25, 1.5, 3.0)
_SMALL_SEEDS = (7, 11, 23, 31, 43)


def _conformance(
    cases: Sequence[tuple[str, str, dict, float]],
    slos: Sequence[float],
    seeds: Sequence[int],
    n_requests: int,
    systems: Sequence[str] = SYSTEMS,
) -> list[ExperimentSpec]:
    return [
        ExperimentSpec(
            workload=family,
            workload_params=dict(params),
            slo_scale=slo,
            utilization=util,
            n_requests=n_requests,
            seed=seed,
            system=system,
            tag=f"eval/{case}/slo{slo:g}/{system}/s{seed}",
        )
        for case, family, params, util in cases
        for slo in slos
        for seed in seeds
        for system in systems
    ]


def tiny() -> list[ExperimentSpec]:
    """8 cells in seconds — CLI smoke and unit tests, not gate-worthy."""
    return _conformance(
        _SMALL_CASES[:1] + _SMALL_CASES[2:],
        slos=(1.25, 3.0),
        seeds=(7,),
        n_requests=120,
        systems=("orloj", "nexus"),
    )


def _scaleout_cells() -> list[ExperimentSpec]:
    """Pool cells feeding the scale-out dispatch claims: a 4-replica pool
    under each compared front-end policy, heterogeneous (half the replicas
    2x slower; offered load 0.8 x the 3 fast-worker-equivalent capacity)
    AND homogeneous (offered load 0.8 x 4 capacities).  ``round_robin``
    and ``jsq_work`` are the original PR-5 cells (their specs are
    unchanged — the bitwise grid contract covers them); ``p2c`` rides the
    same traces and feeds the p2c-dispatch claim, and the homogeneous
    pool feeds homog-pool-parity (DESIGN.md §7 carry-over, now asserted
    since fleet mode exercises both at scale)."""
    hetero_cells = [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=3.0,
            utilization=0.8 * 3,
            n_requests=500,
            seed=seed,
            system="orloj",
            n_workers=4,
            policy=policy,
            hetero=True,
            tag=f"eval/pool-hetero/{policy}/s{seed}",
        )
        for policy in ("round_robin", "jsq_work", "p2c")
        for seed in (7, 11, 23)
    ]
    homog_cells = [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=3.0,
            utilization=0.8 * 4,
            n_requests=500,
            seed=seed,
            system="orloj",
            n_workers=4,
            policy=policy,
            tag=f"eval/pool-homog/{policy}/s{seed}",
        )
        for policy in ("round_robin", "jsq_work", "p2c")
        for seed in (7, 11, 23)
    ]
    return hetero_cells + homog_cells


def small() -> list[ExperimentSpec]:
    """The CI conformance grid: 3 cases x 3 SLOs x 5 seeds x 5 systems at
    n=300 (~1 min serial), plus the scale-out pool cells and the
    token-mode conformance cells (:func:`tokens` — the
    token-length-awareness claim rides in the same acceptance artifact).
    This is the grid the acceptance gate runs on."""
    return (
        _conformance(_SMALL_CASES, _SMALL_SLOS, _SMALL_SEEDS, n_requests=300)
        + _scaleout_cells()
        + tokens()
    )


_FULL_CASES = (
    ("bimodal-std0.5", "bimodal", {"std": 0.5}, 0.85),
    ("bimodal", "bimodal", {"std": 1.0}, 0.85),
    ("bimodal-std2", "bimodal", {"std": 2.0}, 0.85),
    ("bimodal-std2/0.5", "bimodal", {"std": [2.0, 0.5]}, 0.85),
    ("bimodal-std0.5/2", "bimodal", {"std": [0.5, 2.0]}, 0.85),
    ("2-modal", "k_modal", {"k": 2}, 0.85),
    ("3-modal", "k_modal", {"k": 3}, 0.85),
    ("5-modal", "k_modal", {"k": 5}, 0.85),
    ("8-modal", "k_modal", {"k": 8}, 0.85),
    ("more-short", "unequal_bimodal", {"more": "short"}, 0.85),
    ("more-long", "unequal_bimodal", {"more": "long"}, 0.85),
    ("inception", "static", {"mean": 12.0}, 0.7),
    ("resnet", "static", {"mean": 7.0}, 0.7),
    ("gpt-cornell", "real", {"name": "gpt-cornell"}, 0.85),
    ("bart-cnn", "real", {"name": "bart-cnn"}, 0.85),
)


def full() -> list[ExperimentSpec]:
    """Paper-scale sweep (~900 cells at n=1200; use ``--jobs``)."""
    return _conformance(
        _FULL_CASES, slos=(1.25, 1.5, 3.0, 5.0), seeds=(7, 11, 23), n_requests=1200
    )


def engine_smoke() -> list[ExperimentSpec]:
    """The real-substrate tier: 4 tiny ``substrate="engine"`` cells that
    serve the bimodal case through the toy ``orloj_gpt`` JAX model —
    ORLOJ vs Nexus at a tight and a loose SLO.  Minutes on CPU (model
    init + per-shape XLA compilation dominate; the cells themselves are
    cheap).  Tracked, not gated: see DESIGN.md §8."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=slo,
            utilization=0.6,
            n_requests=48,
            seed=7,
            system=system,
            substrate="engine",
            tag=f"engine/bimodal/slo{slo:g}/{system}/s7",
        )
        for slo in (1.5, 5.0)
        for system in ("orloj", "nexus")
    ]


# --------------------------------------------------------------------------
# Fleet-scale cluster grids (DESIGN.md §10): 10^5-request traces over
# 10^2–10^3 workers, dispatched hierarchically (front-end p2c/jsq_work
# between pools, a flat policy within each) on the array engine.


def _fleet_cell(
    n_workers: int,
    n_pools: int,
    inter: str,
    *,
    budget_s: float,
    n_requests: int = 100_000,
    engine: str = "array",
    seed: int = 13,
) -> ExperimentSpec:
    return ExperimentSpec(
        workload="bimodal",
        workload_params={"std": 1.0},
        slo_scale=3.0,
        utilization=0.8 * n_workers,
        n_requests=n_requests,
        seed=seed,
        system="orloj",
        n_workers=n_workers,
        policy=inter,
        n_pools=n_pools,
        intra_policy="round_robin",
        engine=engine,
        tick_ms=4.0,
        wall_budget_s=budget_s,
        loop_seed=0,
        tag=f"cluster/fleet-w{n_workers}p{n_pools}/{inter}/{engine}",
    )


def _fleet_equiv_cells(inters: Sequence[str] = ("p2c", "jsq_work")) -> list[ExperimentSpec]:
    """Scalar/array paired fleet cells at small scale: identical specs up
    to ``engine``, feeding the array-scalar-equivalence claim (the fleet
    grids' correctness contract — finish counts must match exactly)."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=3.0,
            utilization=0.8 * 16,
            n_requests=2_000,
            seed=13,
            system="orloj",
            n_workers=16,
            policy=inter,
            n_pools=4,
            intra_policy="round_robin",
            engine=engine,
            tick_ms=4.0,
            loop_seed=0,
            tag=f"cluster/equiv-w16p4/{inter}/{engine}",
        )
        for inter in inters
        for engine in ("scalar", "array")
    ]


def cluster_fleet() -> list[ExperimentSpec]:
    """The fleet grid: 10^5-request hierarchical-dispatch cells at 100 and
    1000 workers (array engine, tick-quantized arrivals), wall-budgeted,
    plus the scalar/array equivalence pairs at small scale.  Gated on
    budget + equivalence (claims ``cluster-wall-budget`` and
    ``array-scalar-equivalence``); finish rates are tracked evidence."""
    return [
        _fleet_cell(100, 10, "p2c", budget_s=300.0),
        _fleet_cell(100, 10, "jsq_work", budget_s=300.0),
        _fleet_cell(1000, 32, "p2c", budget_s=600.0),
    ] + _fleet_equiv_cells()


def cluster_smoke() -> list[ExperimentSpec]:
    """Trimmed CI tier of :func:`cluster_fleet`: one 10^5-request
    100-worker cell under its wall budget plus one scalar/array
    equivalence pair (~2 min locally)."""
    return [_fleet_cell(100, 10, "p2c", budget_s=300.0)] + _fleet_equiv_cells(
        inters=("p2c",)
    )


# --------------------------------------------------------------------------
# Chaos grids (DESIGN.md §11): seeded fault injection over the same
# lifecycle.  Every chaos cell carries a ``faults`` dict — even the
# fault-free anchors, whose plans are *disabled* (every knob off) — so
# the whole family is excluded from the paper-claim domains by
# construction (claims._eligible filters on ``spec.faults``) and feeds
# only the robustness claims ``fault-free-noop``, ``graceful-degradation``
# and the fault-extended ``array-scalar-equivalence``.

# Nominal virtual makespan of the 2-worker degradation cell (measured
# ~36 s); the MTTF severity ladder is expressed in units of it.
_CHAOS_SPAN_MS = 36_000.0
# level name -> MTTF in units of _CHAOS_SPAN_MS (0 = crashes off).
_CHAOS_LEVELS = (("off", 0.0), ("mild", 2.0), ("moderate", 0.5), ("severe", 0.15))


def _chaos_plan(level_x: float, seed: int, **extra) -> dict:
    """The degradation sweep's fault dict at one severity level.  At
    ``level_x == 0`` the crash/straggler knobs are off but the dict is
    still populated — a *disabled* plan that threads the hooks (the
    fault-free-noop domain)."""
    on = level_x > 0.0
    return dict(
        seed=101 + seed,
        mttf_ms=level_x * _CHAOS_SPAN_MS,
        restart_delay_ms=250.0 if on else 0.0,
        max_retries=3,
        retry_backoff_ms=10.0,
        retry_threshold=0.05,
        straggler_prob=0.05 if on else 0.0,
        straggler_factor=2.5 if on else 1.0,
        **extra,
    )


def _chaos_noop_twins(seeds: Sequence[int]) -> list[ExperimentSpec]:
    """Paired cells per (engine, seed): identical specs except one has no
    faults dict at all and the other a populated-but-*disabled* plan.
    The fault-free-noop claim asserts each pair is bitwise identical —
    i.e. threading the fault hooks costs nothing observable."""
    base = dict(
        workload="bimodal",
        workload_params={"std": 1.0},
        slo_scale=1.5,
        utilization=0.85 * 2,
        n_requests=300,
        n_workers=2,
        policy="round_robin",
    )
    return [
        ExperimentSpec(
            **base,
            seed=seed,
            engine=engine,
            faults=faults,
            tag=f"chaos/noop-{variant}/{engine}/s{seed}",
        )
        for seed in seeds
        for engine in ("scalar", "array")
        for variant, faults in (
            ("bare", {}),
            ("disabled", _chaos_plan(0.0, seed)),
        )
    ]


def _chaos_equiv_cells(fleet: bool = True) -> list[ExperimentSpec]:
    """Scalar/array twins under *active* plans (crashes + stragglers +
    admission), extending the array-scalar-equivalence claim to the
    fault tier; optionally one fleet pair so requeue re-dispatch across
    pool boundaries is covered too."""
    active = _chaos_plan(0.5, 13, admission_floor=0.05)
    cells = [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=1.5,
            utilization=0.85 * 4,
            n_requests=500,
            seed=13,
            system="orloj",
            n_workers=4,
            policy="least_loaded",
            engine=engine,
            faults=dict(active),
            tag=f"chaos/equiv-w4/{engine}",
        )
        for engine in ("scalar", "array")
    ]
    if fleet:
        cells += [
            ExperimentSpec(
                workload="bimodal",
                workload_params={"std": 1.0},
                slo_scale=1.5,
                utilization=0.85 * 6,
                n_requests=500,
                seed=13,
                system="orloj",
                n_workers=6,
                policy="p2c",
                n_pools=2,
                intra_policy="round_robin",
                engine=engine,
                loop_seed=0,
                faults=dict(active),
                tag=f"chaos/equiv-fleet-w6p2/{engine}",
            )
            for engine in ("scalar", "array")
        ]
    return cells


def _chaos_degradation(
    seeds: Sequence[int], systems: Sequence[str] = SYSTEMS
) -> list[ExperimentSpec]:
    """The severity ladder: every compared system under each MTTF level
    at the tight SLO (1.5 — where the dominance ordering is
    reproducible).  Feeds graceful-degradation: per-system finish rate
    must fall monotonically (within slack) with no cliff between
    adjacent levels, and ORLOJ must stay on top at every level."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=1.5,
            utilization=0.85 * 2,
            n_requests=300,
            seed=seed,
            system=system,
            n_workers=2,
            policy="least_loaded",
            faults=_chaos_plan(level_x, seed),
            tag=f"chaos/degrade-{level}/{system}/s{seed}",
        )
        for level, level_x in _CHAOS_LEVELS
        for system in systems
        for seed in seeds
    ]


def chaos() -> list[ExperimentSpec]:
    """The chaos grid: noop twins + scalar/array equivalence under
    active plans (flat and fleet) + the graceful-degradation severity
    ladder.  Gated on ``fault-free-noop``, ``graceful-degradation`` and
    ``array-scalar-equivalence`` (claims layer)."""
    return (
        _chaos_noop_twins(seeds=(7, 11))
        + _chaos_equiv_cells(fleet=True)
        + _chaos_degradation(seeds=(7, 11, 23))
    )


def chaos_smoke() -> list[ExperimentSpec]:
    """Trimmed CI tier of :func:`chaos`: one noop-twin set, the flat
    equivalence pair, and a single-seed severity ladder over
    {orloj, nexus, clockwork} (~30 s serial)."""
    return (
        _chaos_noop_twins(seeds=(7,))
        + _chaos_equiv_cells(fleet=False)
        + _chaos_degradation(seeds=(7,), systems=("orloj", "nexus", "clockwork"))
    )


# --------------------------------------------------------------------------
# Token-mode grids (DESIGN.md §12): continuous-batching decode cells.
# ``slo_scale`` is the TPOT tightness axis (tpot = scale × one
# reference-batch step time); systems are the token schedulers
# (length-aware ``token_orloj`` vs length-blind ``token_fcfs``), feeding
# the ``token-length-awareness`` claim, with scalar/array paired cells
# extending ``array-scalar-equivalence`` to resumable decode runs.

_TOKEN_SYSTEMS = ("token_orloj", "token_fcfs")


def _token_cells(
    slos: Sequence[float],
    seeds: Sequence[int],
    n_requests: int,
    engines: Sequence[str] = ("scalar",),
    utilization: float = 0.85,
) -> list[ExperimentSpec]:
    return [
        ExperimentSpec(
            workload="tokens",
            workload_params={"short_mean": 8.0, "long_mean": 64.0},
            slo_scale=slo,
            utilization=utilization,
            n_requests=n_requests,
            seed=seed,
            system=system,
            engine=engine,
            lm_c0=2.0,  # decode-step cost model: 2 + 0.25·k ms per step
            lm_c1=0.25,
            tag=f"tokens/slo{slo:g}/{system}/s{seed}/{engine}",
        )
        for slo in slos
        for seed in seeds
        for system in _TOKEN_SYSTEMS
        for engine in engines
    ]


def tokens() -> list[ExperimentSpec]:
    """The token-mode conformance grid: tight TPOT scales (1.25, 1.5) for
    the length-awareness ordering plus a loose anchor (3.0) for
    monotonicity, 5 seeds, both token systems; plus scalar/array paired
    cells extending the equivalence claim to decode.  The equivalence
    pairs run at a distinct utilization so their case label never
    seed-averages into the ordering sweep's cells."""
    return _token_cells(
        slos=(1.25, 1.5, 3.0), seeds=_SMALL_SEEDS, n_requests=300
    ) + _token_cells(
        slos=(1.25,),
        seeds=(13,),
        n_requests=300,
        engines=("scalar", "array"),
        utilization=0.9,
    )


def tokens_smoke() -> list[ExperimentSpec]:
    """Trimmed CI tier of :func:`tokens`: two seeds at a tight and a loose
    TPOT scale plus one scalar/array equivalence pair (~seconds)."""
    return _token_cells(
        slos=(1.25, 3.0), seeds=(7, 11), n_requests=200
    ) + _token_cells(
        slos=(1.25,),
        seeds=(13,),
        n_requests=200,
        engines=("scalar", "array"),
        utilization=0.9,
    )


# --------------------------------------------------------------------------
# Multi-model grids (DESIGN.md §13): Zipf-skewed traffic over a zoo
# roster with a weights-residency cache per worker.  Feeds two gated
# claims: ``single-model-noop`` (the tier is bitwise inert at
# n_models=1, scalar AND array) and ``cold-start-dominance``
# (residency-aware dispatch beats residency-blind round_robin under
# memory pressure), plus scalar/array equivalence pairs under an active
# residency plan on both eviction policies.

# 3 GiB holds roughly one resident zoo model (olmo_1b 2.19 GiB +
# internvl2_1b 1.17 GiB > 3 GiB) — the memory-pressure point where
# residency-blind dispatch reloads weights on nearly every batch.
_MM_MEM = float(3 * 2**30)


def _mm_noop_twins(seeds: Sequence[int]) -> list[ExperimentSpec]:
    """Paired cells per (engine, seed): identical specs except one leaves
    every multi-model knob at its default and the other sets skew, memory
    and eviction policy while keeping ``n_models=1``.  The
    single-model-noop claim asserts each pair is bitwise identical — the
    residency tier costs nothing until a second model exists."""
    base = dict(
        workload="bimodal",
        workload_params={"std": 1.0},
        slo_scale=1.5,
        utilization=0.85 * 2,
        n_requests=300,
        # 2-worker pool, like the chaos noop twins: keeps the twins out
        # of the single-worker paper-claim domains (which would state
        # tight-slo-dominance on a grid carrying no baselines).
        n_workers=2,
        policy="round_robin",
    )
    return [
        ExperimentSpec(
            **base,
            **knobs,
            seed=seed,
            engine=engine,
            tag=f"mm/noop-{variant}/{engine}/s{seed}",
        )
        for seed in seeds
        for engine in ("scalar", "array")
        for variant, knobs in (
            ("bare", {}),
            (
                "inert",
                dict(
                    n_models=1,
                    model_skew=1.7,
                    worker_mem=_MM_MEM,
                    residency_policy="cost_aware",
                ),
            ),
        )
    ]


def _mm_coldstart_cells(
    seeds: Sequence[int], n_requests: int = 400
) -> list[ExperimentSpec]:
    """The memory-pressure sweep: 4 zoo models over a 4-worker pool whose
    cache holds ~1 model, residency-aware vs residency-blind dispatch on
    the same traces.  Offered load 0.4 x 4 capacities — low enough that
    the Zipf head fits on one worker, so the comparison isolates
    cold-start churn rather than load imbalance."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=1.5,
            utilization=0.4 * 4,
            n_requests=n_requests,
            seed=seed,
            system="orloj",
            n_workers=4,
            policy=policy,
            n_models=4,
            worker_mem=_MM_MEM,
            tag=f"mm/coldstart/{policy}/s{seed}",
        )
        for policy in ("residency", "round_robin")
        for seed in seeds
    ]


def _mm_equiv_cells() -> list[ExperimentSpec]:
    """Scalar/array twins under an *active* residency plan, one pair per
    eviction policy, extending array-scalar-equivalence to weight-load
    stalls (the residency counters are equivalence fields too).  Distinct
    utilization so their case label never seed-averages into the
    cold-start sweep's cells."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=1.5,
            utilization=0.5 * 4,
            n_requests=400,
            seed=13,
            system="orloj",
            n_workers=4,
            policy="residency",
            n_models=4,
            worker_mem=_MM_MEM,
            residency_policy=respolicy,
            engine=engine,
            tag=f"mm/equiv-{respolicy}/{engine}",
        )
        for respolicy in ("lru", "cost_aware")
        for engine in ("scalar", "array")
    ]


def multi_model() -> list[ExperimentSpec]:
    """The multi-model grid: noop twins (both engines), the cold-start
    dominance sweep at 5 seeds, and scalar/array equivalence pairs under
    both eviction policies.  Gated on ``single-model-noop``,
    ``cold-start-dominance`` and ``array-scalar-equivalence``."""
    return (
        _mm_noop_twins(seeds=(7, 11))
        + _mm_coldstart_cells(seeds=_SMALL_SEEDS)
        + _mm_equiv_cells()
    )


def multi_model_smoke() -> list[ExperimentSpec]:
    """Trimmed CI tier of :func:`multi_model`: one noop-twin set, a
    3-seed cold-start sweep, and the equivalence pairs (~30 s serial)."""
    return (
        _mm_noop_twins(seeds=(7,))
        + _mm_coldstart_cells(seeds=(7, 11, 23))
        + _mm_equiv_cells()
    )


def slo2_bimodal() -> list[ExperimentSpec]:
    """Diagnostic grid for the intermediate-SLO regime (DESIGN.md §7):
    bimodal at SLO scales around 2 x P99, ORLOJ vs Nexus, 5 seeds.
    Feeds the *bounding* claim ``nexus-slo2-gap`` — the regime where
    Nexus's fixed-batch plan is genuinely competitive in this repro is
    documented and bounded, not asserted away."""
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=slo,
            n_requests=300,
            seed=seed,
            system=system,
            tag=f"slo2/bimodal/slo{slo:g}/{system}/s{seed}",
        )
        for slo in (1.75, 2.0, 2.25)
        for system in ("orloj", "nexus")
        for seed in _SMALL_SEEDS
    ]


GRIDS = {
    "tiny": tiny,
    "small": small,
    "full": full,
    "engine-smoke": engine_smoke,
    "cluster": cluster_fleet,
    "cluster-smoke": cluster_smoke,
    "chaos": chaos,
    "chaos-smoke": chaos_smoke,
    "slo2-bimodal": slo2_bimodal,
    "tokens": tokens,
    "tokens-smoke": tokens_smoke,
    "multi-model": multi_model,
    "multi-model-smoke": multi_model_smoke,
}


# --------------------------------------------------------------------------
# Legacy benchmark sweeps (benchmarks/*.py), one constructor per table/fig.
# ``tag`` is the full legacy CSV row name wherever it is spec-derivable.

_SLOS_FULL = (1.5, 2.0, 3.0, 4.0, 5.0)
_SLOS_FAST = (1.5, 3.0, 5.0)


def _table_specs(
    table: str,
    cases: list[tuple[str, str, dict]],
    slos: Sequence[float],
    *,
    utilization: float = 0.85,
    n_requests: int = 1200,
    seed: int = 7,
) -> list[ExperimentSpec]:
    return [
        ExperimentSpec(
            workload=family,
            workload_params=dict(params),
            slo_scale=slo,
            utilization=utilization,
            n_requests=n_requests,
            seed=seed,
            system=system,
            tag=f"{table}/{case}/slo{slo:g}/{system}",
        )
        for case, family, params in cases
        for slo in slos
        for system in SYSTEMS
    ]


def table2(full: bool = False) -> list[ExperimentSpec]:
    """Table 2: bimodal request distributions with varying per-peak std."""
    cases = [
        ("std-0.5", "bimodal", {"std": 0.5}),
        ("std-1", "bimodal", {"std": 1.0}),
        ("std-2", "bimodal", {"std": 2.0}),
        ("std-2/0.5", "bimodal", {"std": [2.0, 0.5]}),
        ("std-0.5/2", "bimodal", {"std": [0.5, 2.0]}),
    ]
    return _table_specs("table2", cases, _SLOS_FULL if full else _SLOS_FAST)


def table3(full: bool = False) -> list[ExperimentSpec]:
    """Table 3 / Fig. 8: one- to eight-modal distributions."""
    ks = range(1, 9) if full else (1, 2, 3, 5, 8)
    cases = [(f"{k}-modal", "k_modal", {"k": k}) for k in ks]
    return _table_specs("table3", cases, _SLOS_FULL if full else _SLOS_FAST)


def fig9(full: bool = False) -> list[ExperimentSpec]:
    cases = [
        (f"more-{m}", "unequal_bimodal", {"more": m}) for m in ("short", "long")
    ]
    return _table_specs("fig9", cases, _SLOS_FULL if full else _SLOS_FAST)


def table4(full: bool = False) -> list[ExperimentSpec]:
    """Table 4 / Fig. 11: static models (no execution-time variance)."""
    cases = [
        ("inception", "static", {"mean": 12.0}),
        ("resnet", "static", {"mean": 7.0}),
    ]
    return _table_specs(
        "table4", cases, _SLOS_FULL if full else _SLOS_FAST, utilization=0.7
    )


def table5(full: bool = False) -> list[ExperimentSpec]:
    """Table 5: real model/dataset pairs fitted from published mean/P99."""
    from ..serving.workload import REAL_TASKS

    names = (
        list(REAL_TASKS)
        if full
        else ["gpt-cornell", "bart-cnn", "skipnet-imagenet", "rdinet-cifar"]
    )
    cases = [(name, "real", {"name": name}) for name in names]
    return _table_specs("table5", cases, _SLOS_FULL if full else _SLOS_FAST)


def ablation(full: bool = False) -> list[ExperimentSpec]:
    variants = {
        "base": {},
        "paper-desc-order": {"bs_order": "paper_desc"},
        "no-refine": {"refine_feasibility": False},
        "bins-4": {"n_bins": 4},
        "bins-32": {"n_bins": 32},
    }
    return [
        ExperimentSpec(
            workload="k_modal",
            workload_params={"k": 3},
            slo_scale=slo,
            utilization=0.8,  # the legacy sweeps used TraceConfig's default
            n_requests=1200,
            seed=11,
            system="orloj",
            sched_cfg=dict(cfg),
            tag=f"ablation/{name}/slo{slo:g}",
        )
        for name, cfg in variants.items()
        for slo in (1.5, 3.0, 5.0)
    ]


def fig13(full: bool = False) -> list[ExperimentSpec]:
    """Sensitivity to the anticipated-delay parameter b (3-modal)."""
    bs = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1)
    slos = (1.5, 2.0, 3.0, 4.0, 5.0) if full else (2.0, 3.0, 5.0)
    return [
        ExperimentSpec(
            workload="k_modal",
            workload_params={"k": 3},
            slo_scale=slo,
            utilization=0.8,  # the legacy sweeps used TraceConfig's default
            n_requests=1000,
            seed=3,
            system="orloj",
            sched_cfg={"b": b},
            tag=f"fig13/slo{slo:g}/b{b:g}",
        )
        for slo in slos
        for b in bs
    ]


def fig14(full: bool = False) -> list[ExperimentSpec]:
    """Shrink the execution-time scale until scheduling overhead bites.
    ``tag`` is completed by the formatter (needs the measured P99)."""
    scales = (
        (1.0, 0.5, 0.25, 0.1, 0.075, 0.05, 0.025)
        if full
        else (1.0, 0.5, 0.25, 0.1, 0.05)
    )
    return [
        ExperimentSpec(
            workload="k_modal",
            workload_params={"k": 3},
            slo_scale=slo,
            utilization=0.8,  # the legacy sweeps used TraceConfig's default
            n_requests=800,
            seed=4,
            system="orloj",
            lm_c0=25.0 * scale,
            time_scale=scale,
            charge_overhead=True,
            tag=f"fig14/scale{scale:g}/slo{slo:g}",
        )
        for scale in scales
        for slo in (1.5, 3.0, 5.0)
    ]


def cluster(full: bool = False) -> list[ExperimentSpec]:
    """Scale-out: finish rate vs replica count and dispatch policy."""
    from ..core.eventloop import DISPATCH_POLICIES

    replicas = (1, 2, 4, 8) if full else (1, 2, 4)
    n = 1500 if full else 800
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=3.0,
            utilization=0.8 * k,  # offered load ~ 0.8 x k worker capacities
            n_requests=n,
            seed=13,
            system="orloj",
            n_workers=k,
            policy=policy,
            loop_seed=0,  # the pre-refactor simulate_cluster default
            tag=f"cluster/{policy}/r{k}",
        )
        for k in replicas
        for policy in DISPATCH_POLICIES
    ]


def cluster_hetero(full: bool = False) -> list[ExperimentSpec]:
    """Mixed pool: half fast, half 2x-slow replicas (a slow replica is
    worth half a fast one, hence the 0.8 x 3 offered load at k=4)."""
    from ..core.eventloop import DISPATCH_POLICIES

    k = 4
    n = 1500 if full else 800
    return [
        ExperimentSpec(
            workload="bimodal",
            workload_params={"std": 1.0},
            slo_scale=3.0,
            utilization=0.8 * (k / 2 + k / 4),
            n_requests=n,
            seed=13,
            system="orloj",
            n_workers=k,
            policy=policy,
            hetero=True,
            loop_seed=1,  # the pre-refactor cluster_hetero loop seed
            tag=f"cluster_hetero/{policy}/r{k}",
        )
        for policy in DISPATCH_POLICIES
    ]
