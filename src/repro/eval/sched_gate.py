"""CI regression gate over the ``BENCH_sched.json`` scheduler-throughput
artifact (ROADMAP "BENCH_sched.json regression gate" item).

``benchmarks/queue_micro.py::sched_throughput`` measures arrival-path
throughput and ``next_batch`` latency at 10²/10³/10⁴ pending and writes
them to ``BENCH_sched.json``.  This gate compares a freshly measured
artifact against the committed baseline and fails CI when the hot path
regresses beyond a *loose* ratio band — 2.5× by default, because
absolute rates swing across shared CI runners (DESIGN.md §8 documents
the band).  The band started at 3×; a season of runs showed run-to-run
wobble of the gated numbers well under 2× even on loaded runners, so
2.5 keeps the same headroom while catching smaller real regressions.

    # regenerate BENCH_sched.json in place, then compare to the committed one
    cp BENCH_sched.json /tmp/sched_baseline.json
    python -m benchmarks.run --only sched
    python -m repro.eval.sched_gate --baseline /tmp/sched_baseline.json

Checked per pending-count size: ``vectorized_arrivals_per_s`` must not
fall below ``baseline / max_ratio`` and ``next_batch_us`` must not exceed
``baseline * max_ratio``.  Speedup-vs-scalar ratios are *not* gated for
the scheduler sections (both paths slow down together on a loaded runner,
so the ratio is stable but uninformative about regressions).

The ``eventloop`` section (array engine vs the scalar oracle loop,
``benchmarks/queue_micro.py::eventloop_throughput``) is gated the other
way round: its *speedup* IS the claim — both engines replay the identical
trace in the same process, so their ratio is immune to runner load — and
must stay >= :data:`MIN_EVENTLOOP_SPEEDUP` at every size (the ISSUE-level
"≥5× end-to-end at 10⁴+ requests" floor).  ``array_events_per_s`` also
gets the loose absolute ratio band against the committed baseline.

The ``token_decode`` section (``queue_micro.py::token_decode``) gates
the continuous-batching decode-step hook per *call*: ``on_decode_step``
fires on every token boundary of a running decode batch, so unlike
``next_batch`` it has no batch of admissions to amortize against — its
cost multiplies into every generated token.  Both schedulers' measured
``decision_us`` must stay under :data:`MAX_DECODE_HOOK_US` absolutely
and within the ratio band of the committed baseline.

The ``residency`` section (``queue_micro.py::residency_churn``,
DESIGN.md §13) gates the multi-model weights-residency machinery both
ways: ``ResidencyState.acquire`` sits on the dispatch path of every
residency-managed batch and is budgeted absolutely per call
(:data:`MAX_ACQUIRE_US`, per eviction policy), and the end-to-end
events/s cost of running the event loop under a churning plan versus
residency-free on the same trace must stay under
:data:`MAX_RESIDENCY_SLOWDOWN` per engine (same-process ratio, immune
to runner load, like the fault slowdown cap).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Mapping

__all__ = [
    "check",
    "main",
    "MIN_EVENTLOOP_SPEEDUP",
    "MAX_FAULT_SLOWDOWN",
    "MAX_DECODE_HOOK_US",
    "MAX_ACQUIRE_US",
    "MAX_RESIDENCY_SLOWDOWN",
]

DEFAULT_MAX_RATIO = 2.5
# Absolute floor on the array engine's measured end-to-end speedup over
# the scalar loop.  Measured ~5.5x at 1e4 and ~8.3x at 1e5 requests on
# the benchmark's tick-quantized trace; 5.0 is the acceptance floor.
MIN_EVENTLOOP_SPEEDUP = 5.0
# Cap on the fault path's end-to-end cost (``eventloop_faults`` section):
# fault-free events/s over faulted events/s on the same trace, per
# engine.  The faulted replay does strictly more work (crash aborts,
# retry re-queues via the per-request object path, straggler draws), so
# the slowdown is structurally > 1 on the array engine, whose fault-free
# bulk paths it bypasses (measured ~2.1x there, ~1.0x on the scalar
# loop); the cap keeps the retry machinery from quietly bloating the
# engines (and since both modes run in one process, the ratio is immune
# to runner load, like the speedup floor above).
MAX_FAULT_SLOWDOWN = 3.0
# Absolute per-call budget on the token schedulers' metered decision
# time (``token_decode`` section, hook-dominated): the decode-step hook
# runs once per token step, so its cost is a floor under every TPOT the
# serving layer can deliver.  Measured ~180us/call for the length-aware
# scheduler (admission sort + feasibility sweep at ~0.8 load) and
# <1us/call for token FCFS; 500 gives ~2.8x headroom for loaded runners
# while still catching an accidentally quadratic hook.
MAX_DECODE_HOOK_US = 500.0
# Absolute per-call budget on ``ResidencyState.acquire`` (``residency``
# section): the acquire runs once per residency-managed batch dispatch,
# under churn (measured on a ~1-resident-model cache where most calls
# evict + load).  Measured ~0.6us/call for LRU and ~1.0us/call for the
# cost-aware policy (which scans the cache for the cheapest victim); 25
# gives wide runner headroom while catching an accidentally quadratic
# victim scan.
MAX_ACQUIRE_US = 25.0
# Cap on the residency tier's end-to-end cost (``residency`` section):
# residency-free events/s over residency-managed events/s on the same
# multi-model FIFO trace, per engine.  The managed replay does strictly
# more work (cache lookups, eviction, stall accounting on every batch),
# but all of it is dict-sized — measured ~1.04x on the array engine and
# ~1.02x on the scalar loop; 2.0 keeps the residency machinery from
# quietly growing into the dispatch hot path (same-process ratio, immune
# to runner load).
MAX_RESIDENCY_SLOWDOWN = 2.0


def check(
    baseline: Mapping, fresh: Mapping, max_ratio: float = DEFAULT_MAX_RATIO
) -> list[str]:
    """Compare two ``BENCH_sched.json`` documents; returns failure lines
    (empty = gate passes)."""
    if max_ratio < 1.0:
        raise ValueError(f"max_ratio must be >= 1, got {max_ratio}")
    fails: list[str] = []
    base_sizes = baseline.get("sizes") or {}
    fresh_sizes = fresh.get("sizes") or {}
    if not base_sizes:
        return ["baseline artifact has no 'sizes' section"]
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(f"n={size}: missing from the fresh artifact")
            continue
        b, f = base["vectorized_arrivals_per_s"], cur["vectorized_arrivals_per_s"]
        if f * max_ratio < b:
            fails.append(
                f"n={size}: arrival throughput {f:.0f}/s is more than "
                f"{max_ratio:g}x below the baseline {b:.0f}/s"
            )
        b_us, f_us = base["next_batch_us"], cur["next_batch_us"]
        if f_us > b_us * max_ratio:
            fails.append(
                f"n={size}: next_batch latency {f_us:.0f}us is more than "
                f"{max_ratio:g}x above the baseline {b_us:.0f}us"
            )
    fails.extend(_check_eventloop(baseline, fresh, max_ratio))
    fails.extend(_check_faults(baseline, fresh, max_ratio))
    fails.extend(_check_token_decode(baseline, fresh, max_ratio))
    fails.extend(_check_residency(baseline, fresh, max_ratio))
    return fails


def _check_eventloop(
    baseline: Mapping, fresh: Mapping, max_ratio: float
) -> list[str]:
    """Gate the ``eventloop`` section: the array/scalar speedup must hold
    the absolute :data:`MIN_EVENTLOOP_SPEEDUP` floor at every size, and
    ``array_events_per_s`` must stay within the ratio band of the
    committed baseline.  A baseline without the section (pre-array-engine
    artifacts) skips the gate entirely."""
    base_el = baseline.get("eventloop") or {}
    base_sizes = base_el.get("sizes") or {}
    if not base_sizes:
        return []
    fresh_sizes = (fresh.get("eventloop") or {}).get("sizes") or {}
    fails: list[str] = []
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(f"eventloop n={size}: missing from the fresh artifact")
            continue
        speedup = cur["speedup"]
        if speedup < MIN_EVENTLOOP_SPEEDUP:
            fails.append(
                f"eventloop n={size}: array/scalar speedup {speedup:.2f}x "
                f"is below the {MIN_EVENTLOOP_SPEEDUP:g}x floor"
            )
        b, f = base["array_events_per_s"], cur["array_events_per_s"]
        if f * max_ratio < b:
            fails.append(
                f"eventloop n={size}: array throughput {f:.0f} events/s is "
                f"more than {max_ratio:g}x below the baseline {b:.0f}/s"
            )
    return fails


def _check_faults(
    baseline: Mapping, fresh: Mapping, max_ratio: float
) -> list[str]:
    """Gate the ``eventloop_faults`` section: per engine and size the
    measured fault slowdown (fault-free over faulted events/s, same
    process, same trace) must stay under :data:`MAX_FAULT_SLOWDOWN`, and
    the faulted array throughput within the ratio band of the committed
    baseline.  A baseline without the section (pre-fault-tier artifacts)
    skips the gate entirely."""
    base_sizes = (baseline.get("eventloop_faults") or {}).get("sizes") or {}
    if not base_sizes:
        return []
    fresh_sizes = (fresh.get("eventloop_faults") or {}).get("sizes") or {}
    fails: list[str] = []
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(
                f"eventloop_faults n={size}: missing from the fresh artifact"
            )
            continue
        for engine in ("scalar", "array"):
            slowdown = cur[f"{engine}_fault_slowdown"]
            if slowdown > MAX_FAULT_SLOWDOWN:
                fails.append(
                    f"eventloop_faults n={size}: {engine} fault slowdown "
                    f"{slowdown:.2f}x exceeds the {MAX_FAULT_SLOWDOWN:g}x cap"
                )
        b = base["array_faulted_events_per_s"]
        f = cur["array_faulted_events_per_s"]
        if f * max_ratio < b:
            fails.append(
                f"eventloop_faults n={size}: faulted array throughput "
                f"{f:.0f} events/s is more than {max_ratio:g}x below the "
                f"baseline {b:.0f}/s"
            )
    return fails


def _check_token_decode(
    baseline: Mapping, fresh: Mapping, max_ratio: float
) -> list[str]:
    """Gate the ``token_decode`` section: per size and token scheduler,
    the measured per-decision time must stay under the absolute
    :data:`MAX_DECODE_HOOK_US` budget (the hook fires every token step;
    its cost floors the deliverable TPOT) and within the ratio band of
    the committed baseline.  A baseline without the section
    (pre-continuous-batching artifacts) skips the gate entirely."""
    base_sizes = (baseline.get("token_decode") or {}).get("sizes") or {}
    if not base_sizes:
        return []
    fresh_sizes = (fresh.get("token_decode") or {}).get("sizes") or {}
    fails: list[str] = []
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(
                f"token_decode n={size}: missing from the fresh artifact"
            )
            continue
        for system in ("token_fcfs", "token_orloj"):
            us = cur[f"{system}_decision_us"]
            if us > MAX_DECODE_HOOK_US:
                fails.append(
                    f"token_decode n={size}: {system} decision time "
                    f"{us:.0f}us exceeds the {MAX_DECODE_HOOK_US:g}us "
                    f"per-call budget"
                )
            b_us = base[f"{system}_decision_us"]
            if us > b_us * max_ratio:
                fails.append(
                    f"token_decode n={size}: {system} decision time "
                    f"{us:.1f}us is more than {max_ratio:g}x above the "
                    f"baseline {b_us:.1f}us"
                )
    return fails


def _check_residency(
    baseline: Mapping, fresh: Mapping, max_ratio: float
) -> list[str]:
    """Gate the ``residency`` section: per eviction policy the measured
    ``acquire`` cost must stay under the absolute :data:`MAX_ACQUIRE_US`
    per-call budget and within the ratio band of the committed baseline;
    per size and engine the end-to-end residency slowdown (residency-free
    over residency-managed events/s, same process, same trace) must stay
    under :data:`MAX_RESIDENCY_SLOWDOWN`, and the managed array
    throughput within the ratio band.  A baseline without the section
    (pre-multi-model artifacts) skips the gate entirely."""
    base_res = baseline.get("residency") or {}
    if not base_res:
        return []
    fresh_res = fresh.get("residency") or {}
    fails: list[str] = []
    base_acq = base_res.get("acquire") or {}
    fresh_acq = fresh_res.get("acquire") or {}
    for policy in ("lru", "cost_aware"):
        key = f"{policy}_acquire_us"
        if key not in base_acq:
            continue
        us = fresh_acq.get(key)
        if us is None:
            fails.append(f"residency acquire: {key} missing from the "
                         f"fresh artifact")
            continue
        if us > MAX_ACQUIRE_US:
            fails.append(
                f"residency acquire: {policy} cost {us:.1f}us exceeds the "
                f"{MAX_ACQUIRE_US:g}us per-call budget"
            )
        if us > base_acq[key] * max_ratio:
            fails.append(
                f"residency acquire: {policy} cost {us:.2f}us is more than "
                f"{max_ratio:g}x above the baseline {base_acq[key]:.2f}us"
            )
    base_sizes = base_res.get("sizes") or {}
    fresh_sizes = fresh_res.get("sizes") or {}
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(f"residency n={size}: missing from the fresh "
                         f"artifact")
            continue
        for engine in ("scalar", "array"):
            slowdown = cur[f"{engine}_residency_slowdown"]
            if slowdown > MAX_RESIDENCY_SLOWDOWN:
                fails.append(
                    f"residency n={size}: {engine} residency slowdown "
                    f"{slowdown:.2f}x exceeds the "
                    f"{MAX_RESIDENCY_SLOWDOWN:g}x cap"
                )
        b = base["array_managed_events_per_s"]
        f = cur["array_managed_events_per_s"]
        if f * max_ratio < b:
            fails.append(
                f"residency n={size}: managed array throughput {f:.0f} "
                f"events/s is more than {max_ratio:g}x below the baseline "
                f"{b:.0f}/s"
            )
    return fails


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_sched.json to gate against")
    ap.add_argument("--fresh", default="BENCH_sched.json",
                    help="freshly measured artifact (default: BENCH_sched.json)")
    ap.add_argument("--max-ratio", type=float, default=DEFAULT_MAX_RATIO,
                    help="tolerated regression ratio (default %(default)s)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    fails = check(baseline, fresh, args.max_ratio)
    for line in fails:
        print(f"FAIL {line}", file=sys.stderr)
    status = "FAIL" if fails else "PASS"
    print(f"sched gate: {status} ({args.fresh} vs {args.baseline}, "
          f"band {args.max_ratio:g}x)")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
