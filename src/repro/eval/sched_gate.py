"""CI regression gate over the ``BENCH_sched.json`` scheduler-throughput
artifact (ROADMAP "BENCH_sched.json regression gate" item).

``benchmarks/queue_micro.py::sched_throughput`` measures arrival-path
throughput and ``next_batch`` latency at 10²/10³/10⁴ pending and writes
them to ``BENCH_sched.json``.  This gate compares a freshly measured
artifact against the committed baseline and fails CI when the hot path
regresses beyond a *loose* ratio band — 3× by default, because absolute
rates swing widely across shared CI runners (DESIGN.md §8 documents the
band; tighten it once runner variance is characterized).

    # regenerate BENCH_sched.json in place, then compare to the committed one
    cp BENCH_sched.json /tmp/sched_baseline.json
    python -m benchmarks.run --only sched
    python -m repro.eval.sched_gate --baseline /tmp/sched_baseline.json

Checked per pending-count size: ``vectorized_arrivals_per_s`` must not
fall below ``baseline / max_ratio`` and ``next_batch_us`` must not exceed
``baseline * max_ratio``.  Speedup-vs-scalar ratios are *not* gated (both
paths slow down together on a loaded runner, so the ratio is stable but
uninformative about regressions).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Mapping

__all__ = ["check", "main"]

DEFAULT_MAX_RATIO = 3.0


def check(
    baseline: Mapping, fresh: Mapping, max_ratio: float = DEFAULT_MAX_RATIO
) -> list[str]:
    """Compare two ``BENCH_sched.json`` documents; returns failure lines
    (empty = gate passes)."""
    if max_ratio < 1.0:
        raise ValueError(f"max_ratio must be >= 1, got {max_ratio}")
    fails: list[str] = []
    base_sizes = baseline.get("sizes") or {}
    fresh_sizes = fresh.get("sizes") or {}
    if not base_sizes:
        return ["baseline artifact has no 'sizes' section"]
    for size, base in sorted(base_sizes.items(), key=lambda kv: int(kv[0])):
        cur = fresh_sizes.get(size)
        if cur is None:
            fails.append(f"n={size}: missing from the fresh artifact")
            continue
        b, f = base["vectorized_arrivals_per_s"], cur["vectorized_arrivals_per_s"]
        if f * max_ratio < b:
            fails.append(
                f"n={size}: arrival throughput {f:.0f}/s is more than "
                f"{max_ratio:g}x below the baseline {b:.0f}/s"
            )
        b_us, f_us = base["next_batch_us"], cur["next_batch_us"]
        if f_us > b_us * max_ratio:
            fails.append(
                f"n={size}: next_batch latency {f_us:.0f}us is more than "
                f"{max_ratio:g}x above the baseline {b_us:.0f}us"
            )
    return fails


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_sched.json to gate against")
    ap.add_argument("--fresh", default="BENCH_sched.json",
                    help="freshly measured artifact (default: BENCH_sched.json)")
    ap.add_argument("--max-ratio", type=float, default=DEFAULT_MAX_RATIO,
                    help="tolerated regression ratio (default %(default)s)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    fails = check(baseline, fresh, args.max_ratio)
    for line in fails:
        print(f"FAIL {line}", file=sys.stderr)
    status = "FAIL" if fails else "PASS"
    print(f"sched gate: {status} ({args.fresh} vs {args.baseline}, "
          f"band {args.max_ratio:g}x)")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
