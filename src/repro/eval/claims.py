"""Paper-claims conformance gates over an experiment result set.

The paper's core quantitative claim (Tables 2-5, Figs. 8-9) is an
*ordering*: under tight SLOs on high-variance workloads ORLOJ finishes
more requests than Clockwork/Nexus/Clipper, while staying comparable on
static workloads.  Absolute finish rates depend on hardware constants and
trace scaling, so the gate checks the orderings, not the magnitudes:

- ``tight-slo-dominance`` — on every dynamic workload case at SLO scale
  strictly below :data:`TIGHT_SLO_MAX`, ORLOJ's seed-averaged finish
  rate >= every baseline's (strict: no tolerance — the observed margins
  are the evidence, and they are reported per cell);
- ``nexus-slo2-gap`` — in the intermediate window
  :data:`NEXUS_SLO2_WINDOW` (≈2 x P99), where Nexus's fixed-batch plan
  is genuinely competitive in this repro, the seed-mean
  nexus-over-orloj gap stays under :data:`NEXUS_SLO2_BOUND` — the
  regime is *bounded*, not ordered (DESIGN.md §7);
- ``static-parity`` — on static workloads ORLOJ is within
  :data:`STATIC_NOISE_BAND` of the best baseline (on no-variance
  workloads all systems degenerate to near-identical batching; the band
  covers batching-order noise, sized from the observed seed-to-seed
  spread, ~1.5x the per-system std of 0.05);
- ``slo-monotonicity`` — relaxing the SLO never *costs* a system more
  than :data:`MONO_SLACK` finish rate (sanity: the grid is measuring SLO
  pressure, not an artifact).

- ``scale-out-dispatch`` — on multi-worker pools the distribution-aware
  ``jsq_work`` front-end never trails ``round_robin`` by more than
  :data:`SCALEOUT_SLACK` seed-mean finish rate (the §3.1 scale-out path:
  expected-work balancing must at least match blind rotation, and on
  heterogeneous pools it should win outright).  Evaluated only when the
  result set contains pool cells (the tiny grid has none);
- ``p2c-dispatch`` — same ordering for the two-probe ``p2c`` front-end
  vs ``round_robin`` within :data:`P2C_SLACK` (two load probes per
  arrival already recover most of the full-scan ordering; on the gated
  hetero cells p2c wins on every seed, mean margin +0.011);
- ``homog-pool-parity`` — on *homogeneous* pools every dispatch policy's
  seed-mean finish rate sits within :data:`HOMOG_BAND` of the best
  (identical replicas leave nothing for load-awareness to exploit;
  observed spread 0.0007, the band covers tie-break noise);
- ``cluster-wall-budget`` — every wall-budgeted cell (fleet-scale
  ``cluster`` grids, ``wall_budget_s > 0``) replays inside its budget —
  the array engine's performance contract, enforced in CI;
- ``array-scalar-equivalence`` — paired cells identical up to
  ``engine`` produce identical outcomes (finish counts, makespan,
  decision count, and under a fault plan the per-terminal-state counts):
  the fleet grids' correctness anchor to the scalar oracle loop;
- ``fault-free-noop`` — a cell carrying a *disabled*
  :class:`~repro.serving.faults.FaultPlan` is bitwise identical to the
  same cell with no plan at all (the fault hooks cost nothing
  observable — DESIGN.md §11);
- ``graceful-degradation`` — on the chaos grid's crash-severity ladder,
  per-system finish rates fall monotonically (within
  :data:`FAULT_RISE_SLACK`), never cliff by more than
  :data:`FAULT_CLIFF` between adjacent levels, and ORLOJ keeps its lead
  (within :data:`FAULT_DOMINANCE_SLACK`) at every level.

Truncated results (a ``wall_budget_s`` overrun cut the replay off) are
excluded from every outcome claim and failed by ``cluster-wall-budget``.

This layer is stage 4 of the grid-cell lifecycle (spec → seeded
RequestSet → result → claim, see :mod:`repro.eval.spec`): it consumes
:class:`ExperimentResult` values regardless of which substrate produced
them — engine-substrate results flow through the same claim functions
(cells from different substrates are never averaged together, because the
grouping label carries the substrate).

Aggregation is a plain mean over the grid's seeds, grouped per experiment
(workload case, utilization, n_requests, SLO scale, system) so cells from
different sweeps are never averaged together; every simulation is
deterministic, so a claim's verdict is reproducible bit-for-bit (engine
cells measure real hardware and are reproducible only up to timing noise).
The three paper claims only look at single-worker, default-config cells —
ablation and sensitivity sweeps (``sched_cfg``, ``time_scale``, overhead
charging) are excluded, and pool cells are the scale-out claim's domain.
"""

from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Any, Iterable, Mapping, Sequence

from ..serving.faults import FaultPlan
from .spec import ExperimentResult, ExperimentSpec
from .substrate import parse_substrate
from .workloads import DYNAMIC_FAMILIES

__all__ = [
    "STATIC_NOISE_BAND",
    "MONO_SLACK",
    "TIGHT_SLO_MAX",
    "SCALEOUT_SLACK",
    "P2C_SLACK",
    "HOMOG_BAND",
    "FAULT_RISE_SLACK",
    "FAULT_CLIFF",
    "FAULT_DOMINANCE_SLACK",
    "NEXUS_SLO2_WINDOW",
    "NEXUS_SLO2_BOUND",
    "TOKEN_TIGHT_SLO_MAX",
    "COLDSTART_SLACK",
    "ClaimResult",
    "claim_token_length_awareness",
    "claim_cold_start_dominance",
    "claim_single_model_noop",
    "claim_scaleout_dispatch",
    "claim_p2c_dispatch",
    "claim_homog_pool_parity",
    "claim_cluster_wall_budget",
    "claim_array_scalar_equivalence",
    "claim_fault_free_noop",
    "claim_graceful_degradation",
    "claim_nexus_slo2_gap",
    "evaluate_claims",
    "format_report",
]

# Documented gate constants (DESIGN.md §7).
# "Tight SLO" = scale strictly below 1.75 x P99.  The dominance regime
# this repro actually reproduces ends there: at scales 1.75-2.25 Nexus's
# fixed-batch plan is genuinely competitive (the slo2-bimodal diagnostic
# grid measures the gap and claim_nexus_slo2_gap *bounds* it instead of
# asserting an ordering the code does not reproduce — DESIGN.md §7).
TIGHT_SLO_MAX = 1.75
STATIC_NOISE_BAND = 0.08  # parity band on static workloads
MONO_SLACK = 0.05  # tolerated finish-rate dip when relaxing the SLO
# Tolerated jsq_work-vs-round_robin deficit on pool cells.  On the gated
# hetero pool cells jsq_work wins on every seed (seed-mean margin +0.035
# observed); the slack covers dispatch-tie-break noise only — about 10
# requests at the pool cells' n=500 — without masking a real ordering flip.
SCALEOUT_SLACK = 0.02
# Tolerated p2c-vs-round_robin deficit.  p2c probes only two pools/replicas
# per arrival, so its margin over blind rotation is smaller than jsq_work's
# full scan (hetero seed-mean +0.011 observed, positive on every seed); the
# same 0.02 slack covers probe-sampling noise without masking a flip.
P2C_SLACK = 0.02
# Parity band between dispatch policies on homogeneous pools: identical
# replicas leave load-awareness nothing to exploit, so every policy must
# land within the band of the best (observed spread 0.0007 across
# round_robin/jsq_work/p2c on the gated homog cells).
HOMOG_BAND = 0.02
# Graceful-degradation constants (chaos grid, DESIGN.md §11).  On the
# gated severity ladder (2-worker bimodal @ slo 1.5, MTTF levels
# off/mild/moderate/severe) the observed per-system seed-mean rises are
# <= 0.002, adjacent-level drops <= 0.035, and ORLOJ leads every
# baseline by >= 0.02 at every level.
FAULT_RISE_SLACK = 0.02  # tolerated finish-rate *rise* as severity grows
FAULT_CLIFF = 0.10  # max adjacent-severity-level finish-rate drop
FAULT_DOMINANCE_SLACK = 0.03  # orloj >= baseline - slack at each level
# Intermediate-SLO diagnostic window (slo2-bimodal grid): the SLO scales
# where Nexus is competitive in this repro.  The bounding claim caps the
# seed-mean nexus-over-orloj gap (observed max +0.035 at scale 2.25).
NEXUS_SLO2_WINDOW = (1.75, 2.25)
NEXUS_SLO2_BOUND = 0.06
# Token-mode tightness boundary (tokens grids, DESIGN.md §12): TPOT
# scales strictly below it are "tight" — the regime where admission that
# knows the output-length distributions must beat length-blind FCFS.
TOKEN_TIGHT_SLO_MAX = 1.75
# Cold-start dominance (multi-model grids, DESIGN.md §13): residency-aware
# dispatch must beat residency-blind round_robin outright on the gated
# memory-pressure cells (observed seed-mean margin +0.13 at worker_mem
# 3 GiB, where round_robin reloads weights on nearly every dispatch); the
# slack is zero — "beats" is the claim, not "roughly matches".
COLDSTART_SLACK = 0.0


@dataclasses.dataclass(frozen=True)
class ClaimResult:
    name: str
    description: str
    passed: bool
    margin: float  # worst-case slack; negative iff the claim failed
    cells: tuple[str, ...]  # per-cell evidence lines

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClaimResult":
        return cls(
            name=d["name"],
            description=d["description"],
            passed=d["passed"],
            margin=d["margin"],
            cells=tuple(d["cells"]),
        )


def _case_label(spec: ExperimentSpec) -> str:
    """Grouping key for seed averaging.  Includes the load parameters
    (utilization, n_requests) so cells from different sweeps — e.g. a
    combined small-grid + legacy-table result set — are never averaged
    into one mean as if they measured the same experiment.  Engine cells
    carry their substrate in the label for the same reason: a measured
    finish rate and a simulated one are different experiments."""
    params = json.dumps(spec.workload_params, sort_keys=True)
    label = f"{spec.workload}{params}@u{spec.utilization:g}/n{spec.n_requests}"
    if spec.substrate != "sim":
        # Canonicalize: "engine" and "engine:orloj_gpt" are the same
        # experiment and must seed-average together.
        try:
            kind, model = parse_substrate(spec.substrate)
            label += f"/{kind}:{model}"
        except ValueError:  # unknown spelling: keep cells apart, not crash
            label += f"/{spec.substrate}"
    if spec.faults:
        # Defensive: faulted cells are excluded from the paper-claim
        # domains (_eligible), but if one ever reaches a grouping it must
        # not seed-average with fault-free cells of the same case.
        label += "/faults" + json.dumps(spec.faults, sort_keys=True)
    if spec.n_models > 1:
        # Multi-model cells replay a different experiment (Zipf-assigned
        # models, residency stalls) and must never seed-average with
        # single-model cells of the same workload case.
        label += (
            f"/mm{spec.n_models}x{spec.model_skew:g}"
            f"/mem{spec.worker_mem:g}/{spec.residency_policy}"
        )
    return label


def _eligible(r: ExperimentResult) -> bool:
    s = r.spec
    return (
        s.n_workers == 1
        and not s.sched_cfg
        and not s.charge_overhead
        and s.time_scale == 1.0
        and not s.hetero
        # chaos cells (even ones whose plan is disabled) feed the
        # robustness claims only, never the paper orderings
        and not s.faults
        # multi-model cells feed the residency claims only (their finish
        # rates carry cold-start stalls the paper orderings never priced)
        and s.n_models == 1
        and not r.truncated
    )


def _seed_means(
    results: Iterable[ExperimentResult],
) -> dict[tuple[str, str, float, str], float]:
    """(case, family, slo, system) -> finish rate averaged over seeds."""
    acc: dict[tuple, list[float]] = defaultdict(list)
    for r in results:
        if _eligible(r):
            key = (_case_label(r.spec), r.spec.workload, r.spec.slo_scale, r.spec.system)
            acc[key].append(r.finish_rate)
    return {k: sum(v) / len(v) for k, v in acc.items()}


def _fail(name: str, description: str, why: str) -> ClaimResult:
    # Finite sentinel margin: finish-rate margins live in [-1, 1], and
    # -inf would serialize as the non-standard JSON token ``-Infinity``.
    return ClaimResult(name, description, False, -1.0, (why,))


def claim_tight_slo_dominance(
    results: Sequence[ExperimentResult], max_slo: float = TIGHT_SLO_MAX
) -> ClaimResult:
    desc = (
        f"ORLOJ's seed-mean finish rate >= every baseline's on each dynamic "
        f"workload at SLO scale < {max_slo:g}"
    )
    means = _seed_means(results)
    by_cell: dict[tuple[str, float], dict[str, float]] = defaultdict(dict)
    for (case, family, slo, system), fr in means.items():
        if family in DYNAMIC_FAMILIES and slo < max_slo:
            by_cell[(case, slo)][system] = fr
    cells, worst = [], float("inf")
    for (case, slo), per_sys in sorted(by_cell.items()):
        if "orloj" not in per_sys or len(per_sys) < 2:
            continue
        orloj = per_sys["orloj"]
        for system, fr in sorted(per_sys.items()):
            if system == "orloj":
                continue
            margin = orloj - fr
            worst = min(worst, margin)
            cells.append(
                f"{case}@slo{slo:g}: orloj {orloj:.3f} vs {system} {fr:.3f} "
                f"({margin:+.3f})"
            )
    if not cells:
        return _fail(
            "tight-slo-dominance", desc, "no eligible dynamic cells at tight SLO"
        )
    return ClaimResult("tight-slo-dominance", desc, worst >= 0.0, worst, tuple(cells))


def claim_static_parity(
    results: Sequence[ExperimentResult], band: float = STATIC_NOISE_BAND
) -> ClaimResult:
    desc = (
        f"ORLOJ within {band:g} of the best baseline's seed-mean finish rate "
        f"on static workloads"
    )
    means = _seed_means(results)
    by_cell: dict[tuple[str, float], dict[str, float]] = defaultdict(dict)
    for (case, family, slo, system), fr in means.items():
        if family == "static":
            by_cell[(case, slo)][system] = fr
    cells, worst = [], float("inf")
    for (case, slo), per_sys in sorted(by_cell.items()):
        if "orloj" not in per_sys or len(per_sys) < 2:
            continue
        orloj = per_sys["orloj"]
        best_sys, best = max(
            ((s, fr) for s, fr in per_sys.items() if s != "orloj"),
            key=lambda kv: kv[1],
        )
        margin = band + (orloj - best)
        worst = min(worst, margin)
        cells.append(
            f"{case}@slo{slo:g}: orloj {orloj:.3f}, best baseline {best_sys} "
            f"{best:.3f} (gap {orloj - best:+.3f}, band {band:g})"
        )
    if not cells:
        return _fail("static-parity", desc, "no eligible static cells")
    return ClaimResult("static-parity", desc, worst >= 0.0, worst, tuple(cells))


def claim_slo_monotonicity(
    results: Sequence[ExperimentResult], slack: float = MONO_SLACK
) -> ClaimResult:
    desc = (
        f"per system and workload, relaxing the SLO never drops the seed-mean "
        f"finish rate by more than {slack:g}"
    )
    means = _seed_means(results)
    by_series: dict[tuple[str, str], list[tuple[float, float]]] = defaultdict(list)
    for (case, family, slo, system), fr in means.items():
        by_series[(case, system)].append((slo, fr))
    cells, worst = [], float("inf")
    for (case, system), pts in sorted(by_series.items()):
        pts.sort()
        if len(pts) < 2:
            continue
        for (slo_a, fr_a), (slo_b, fr_b) in zip(pts, pts[1:]):
            margin = fr_b - fr_a + slack
            worst = min(worst, margin)
            if margin < 0.0:
                cells.append(
                    f"{case}/{system}: slo{slo_a:g}->{slo_b:g} fell "
                    f"{fr_a:.3f}->{fr_b:.3f} (dip {fr_a - fr_b:.3f} > {slack:g})"
                )
        cells.append(
            f"{case}/{system}: "
            + " -> ".join(f"{fr:.3f}@{slo:g}" for slo, fr in pts)
        )
    if worst == float("inf"):
        return _fail("slo-monotonicity", desc, "no series with >= 2 SLO scales")
    return ClaimResult("slo-monotonicity", desc, worst >= 0.0, worst, tuple(cells))


def _pool_policy_means(
    results: Iterable[ExperimentResult],
) -> dict[tuple, dict[str, float]]:
    """(case, slo, pool) -> {policy: seed-mean finish rate} over *flat*
    pool cells: ORLOJ multi-worker runs with default scheduler config and
    a single pool (fleet cells with ``n_pools > 1`` route through
    hierarchical dispatch, where the policy name means something else —
    they never mix into the flat-dispatch orderings)."""
    acc: dict[tuple, list[float]] = defaultdict(list)
    for r in results:
        s = r.spec
        if (
            s.n_workers > 1
            and s.n_pools == 1
            and s.system == "orloj"
            and not s.sched_cfg
            and not s.charge_overhead
            and s.time_scale == 1.0
            and not s.faults  # chaos cells never feed dispatch orderings
            # multi-model cells compare dispatch under residency stalls —
            # the cold-start-dominance claim's domain, not this one's (a
            # residency-vs-round_robin pair would blow HOMOG_BAND by design)
            and s.n_models == 1
            and not r.truncated
        ):
            pool = f"r{s.n_workers}{'-hetero' if s.hetero else ''}"
            acc[(_case_label(s), s.slo_scale, pool, s.policy)].append(
                r.finish_rate
            )
    means = {k: sum(v) / len(v) for k, v in acc.items()}
    by_cell: dict[tuple, dict[str, float]] = defaultdict(dict)
    for (case, slo, pool, policy), fr in means.items():
        by_cell[(case, slo, pool)][policy] = fr
    return by_cell


def _dispatch_ordering(
    name: str,
    desc: str,
    winner: str,
    by_cell: Mapping[tuple, Mapping[str, float]],
    slack: float,
) -> ClaimResult:
    """Generic per-pool-cell ordering: ``winner``'s seed-mean finish rate
    >= ``round_robin``'s within ``slack``."""
    cells, worst = [], float("inf")
    for (case, slo, pool), per_pol in sorted(by_cell.items()):
        if winner not in per_pol or "round_robin" not in per_pol:
            continue
        win, rr = per_pol[winner], per_pol["round_robin"]
        margin = win - rr + slack
        worst = min(worst, margin)
        cells.append(
            f"{case}@slo{slo:g}/{pool}: {winner} {win:.3f} vs "
            f"round_robin {rr:.3f} ({win - rr:+.3f}, slack {slack:g})"
        )
    if not cells:
        return _fail(
            name, desc, f"no pool cells with both {winner} and round_robin"
        )
    return ClaimResult(name, desc, worst >= 0.0, worst, tuple(cells))


def claim_scaleout_dispatch(
    results: Sequence[ExperimentResult], slack: float = SCALEOUT_SLACK
) -> ClaimResult:
    """§3.1 scale-out ordering: distribution-aware ``jsq_work`` dispatch
    >= ``round_robin`` (within ``slack``) per pool cell, seed-averaged.

    Pool cells are ORLOJ multi-worker runs with default scheduler config;
    homogeneous and heterogeneous pools are separate cells (the claim is
    strongest on hetero pools, where blind rotation overloads the slow
    half)."""
    desc = (
        f"on multi-worker pools, jsq_work dispatch's seed-mean finish rate "
        f">= round_robin's within {slack:g}"
    )
    return _dispatch_ordering(
        "scale-out-dispatch", desc, "jsq_work", _pool_policy_means(results), slack
    )


def claim_p2c_dispatch(
    results: Sequence[ExperimentResult], slack: float = P2C_SLACK
) -> ClaimResult:
    """Two-probe power-of-two-choices dispatch >= ``round_robin`` (within
    ``slack``) per pool cell: two backlog probes per arrival already
    recover the load-aware ordering, which is what makes p2c the fleet
    front-end default (it never scans the whole pool)."""
    desc = (
        f"on multi-worker pools, p2c dispatch's seed-mean finish rate "
        f">= round_robin's within {slack:g}"
    )
    return _dispatch_ordering(
        "p2c-dispatch", desc, "p2c", _pool_policy_means(results), slack
    )


def claim_homog_pool_parity(
    results: Sequence[ExperimentResult], band: float = HOMOG_BAND
) -> ClaimResult:
    """On homogeneous pools every dispatch policy lands within ``band`` of
    the best policy's seed-mean finish rate: identical replicas leave
    load-awareness nothing to exploit, so any larger spread means a
    dispatch policy is broken, not that the workload prefers one."""
    desc = (
        f"on homogeneous pools every dispatch policy's seed-mean finish "
        f"rate is within {band:g} of the best policy's"
    )
    cells, worst = [], float("inf")
    for (case, slo, pool), per_pol in sorted(_pool_policy_means(results).items()):
        if "-hetero" in pool or len(per_pol) < 2:
            continue
        best_pol, best = max(per_pol.items(), key=lambda kv: kv[1])
        for policy, fr in sorted(per_pol.items()):
            if policy == best_pol:
                continue
            margin = band + (fr - best)
            worst = min(worst, margin)
            cells.append(
                f"{case}@slo{slo:g}/{pool}: {policy} {fr:.3f} vs best "
                f"{best_pol} {best:.3f} (gap {fr - best:+.3f}, band {band:g})"
            )
    if not cells:
        return _fail(
            "homog-pool-parity", desc, "no homogeneous pool cells with >= 2 policies"
        )
    return ClaimResult("homog-pool-parity", desc, worst >= 0.0, worst, tuple(cells))


def claim_cluster_wall_budget(
    results: Sequence[ExperimentResult],
) -> ClaimResult:
    """Every wall-budgeted cell replayed inside its budget.  This is the
    fleet grids' performance gate: the budgets are sized from measured
    array-engine replays with generous CI headroom (a 10^5-request,
    100-worker cell runs ~70 s locally against a 300 s budget), so
    breaching one means the event engine regressed, not that the machine
    was slow.  Margin is the worst-case fraction of budget left."""
    desc = "every wall-budgeted cell (wall_budget_s > 0) finishes inside its budget"
    cells, worst = [], float("inf")
    for r in results:
        budget = r.spec.wall_budget_s
        if budget <= 0.0:
            continue
        if r.truncated:
            # The loop cut the replay off AT the budget, so wall_s alone
            # would read as a hairline pass — a truncated budgeted cell
            # is a budget breach by definition.
            worst = min(worst, -1.0)
            cells.append(
                f"{r.spec.tag or _case_label(r.spec)}: TRUNCATED at "
                f"budget {budget:g}s ({r.n_unserved} unserved)"
            )
            continue
        margin = (budget - r.wall_s) / budget
        worst = min(worst, margin)
        cells.append(
            f"{r.spec.tag or _case_label(r.spec)}: wall {r.wall_s:.1f}s / "
            f"budget {budget:g}s ({margin:+.2f} of budget left)"
        )
    if not cells:
        return _fail("cluster-wall-budget", desc, "no wall-budgeted cells")
    return ClaimResult("cluster-wall-budget", desc, worst >= 0.0, worst, tuple(cells))


# Outcome fields two engines must agree on exactly.  Everything here is
# deterministic given the spec (TIMING_FIELDS are excluded by design);
# finish counts are the ISSUE-level contract, makespan/decision counts
# catch divergence that happens to preserve the counts.
_EQUIV_FIELDS = (
    "n_total",
    "n_finished_ok",
    "n_finished_late",
    "n_dropped",
    "n_unserved",
    "n_rejected",
    "n_failed",
    "n_retried",
    "n_decisions",
    "makespan_ms",
    "latency_p99_ms",
    "n_model_loads",
    "n_model_evicts",
    "model_load_ms",
)


def claim_array_scalar_equivalence(
    results: Sequence[ExperimentResult],
) -> ClaimResult:
    """Cells whose specs are identical up to ``engine`` must produce
    identical outcomes — the array engine's anchor to the scalar oracle
    loop.  Margin is the worst-case finish-count discrepancy as a
    fraction of the cell's requests (0.0 when everything matches)."""
    desc = (
        "paired cells identical up to `engine` agree exactly on "
        + ", ".join(_EQUIV_FIELDS)
    )
    by_pair: dict[str, dict[str, ExperimentResult]] = defaultdict(dict)
    for r in results:
        d = r.spec.to_dict()
        engine = d.pop("engine")
        d.pop("tag")
        by_pair[json.dumps(d, sort_keys=True)][engine] = r
    cells, worst = [], float("inf")
    for key, per_engine in sorted(by_pair.items()):
        if len(per_engine) < 2:
            continue
        base_engine, base = sorted(per_engine.items())[0]
        label = base.spec.tag or _case_label(base.spec)
        for engine, r in sorted(per_engine.items()):
            if engine == base_engine:
                continue
            diffs = [
                f"{f}: {getattr(base, f)!r} vs {getattr(r, f)!r}"
                for f in _EQUIV_FIELDS
                if getattr(base, f) != getattr(r, f)
            ]
            count_gap = sum(
                abs(getattr(base, f) - getattr(r, f))
                for f in ("n_finished_ok", "n_finished_late", "n_dropped", "n_unserved")
            ) / max(base.n_total, 1)
            margin = -count_gap if diffs else 0.0
            worst = min(worst, margin)
            if diffs:
                cells.append(
                    f"{label}: {base_engine} != {engine} — " + "; ".join(diffs)
                )
            else:
                cells.append(
                    f"{label}: {base_engine} == {engine} "
                    f"({base.n_finished_ok}+{base.n_finished_late} finished)"
                )
    if not cells:
        return _fail(
            "array-scalar-equivalence", desc, "no spec paired across engines"
        )
    return ClaimResult(
        "array-scalar-equivalence", desc, worst >= 0.0, worst, tuple(cells)
    )


# Outcome fields a disabled fault plan must leave bitwise unchanged
# relative to running with no plan at all (the noop contract covers the
# per-state counts and the rate/latency aggregates derived from them).
_NOOP_FIELDS = _EQUIV_FIELDS + (
    "finish_rate",
    "utilization",
    "latency_p50_ms",
)


def _noop_groups(
    results: Sequence[ExperimentResult],
) -> dict[str, dict[str, ExperimentResult]]:
    """Group cells identical up to (faults, tag); within each group keep
    the bare cell (no faults dict) and every *disabled*-plan variant.
    Cells with active plans never enter (they are supposed to differ)."""
    groups: dict[str, dict[str, ExperimentResult]] = defaultdict(dict)
    for r in results:
        f = r.spec.faults
        if f and FaultPlan.from_dict(f).enabled():
            continue
        d = r.spec.to_dict()
        d.pop("tag")
        faults = d.pop("faults")
        variant = "bare" if not faults else "disabled:" + json.dumps(
            faults, sort_keys=True
        )
        groups[json.dumps(d, sort_keys=True)][variant] = r
    return groups


def claim_fault_free_noop(
    results: Sequence[ExperimentResult],
) -> ClaimResult:
    """Threading a *disabled* :class:`FaultPlan` through the engine hooks
    changes nothing observable: cells identical up to the faults dict —
    one with no plan at all, one with every knob off — agree bitwise on
    every outcome field.  This is what licenses keeping the fault hooks
    in the hot loop: with no plan (or a disabled one) the pre-existing
    grid outcomes are unchanged."""
    desc = (
        "cells identical up to a *disabled* faults dict agree exactly on "
        + ", ".join(_NOOP_FIELDS)
    )
    cells, worst = [], float("inf")
    for key, variants in sorted(_noop_groups(results).items()):
        if "bare" not in variants or len(variants) < 2:
            continue
        base = variants["bare"]
        label = base.spec.tag or _case_label(base.spec)
        for variant, r in sorted(variants.items()):
            if variant == "bare":
                continue
            diffs = [
                f"{f}: {getattr(base, f)!r} vs {getattr(r, f)!r}"
                for f in _NOOP_FIELDS
                if getattr(base, f) != getattr(r, f)
            ]
            margin = -1.0 if diffs else 0.0
            worst = min(worst, margin)
            if diffs:
                cells.append(f"{label}: bare != {variant} — " + "; ".join(diffs))
            else:
                cells.append(
                    f"{label}: disabled plan is a noop "
                    f"({base.n_finished_ok}+{base.n_finished_late} finished)"
                )
    if not cells:
        return _fail(
            "fault-free-noop", desc, "no cell paired bare vs disabled-plan"
        )
    return ClaimResult("fault-free-noop", desc, worst >= 0.0, worst, tuple(cells))


def _severity_series(
    results: Sequence[ExperimentResult],
) -> dict[tuple[str, float], dict[str, list[tuple[float, float]]]]:
    """(case-sans-faults, slo) -> system -> [(severity-sorted mttf level,
    seed-mean finish rate)] over the chaos degradation cells (flat pools,
    default config, non-truncated).  Severity orders levels from
    fault-free (mttf 0, disabled plan) to harshest (smallest mttf)."""
    acc: dict[tuple, list[float]] = defaultdict(list)
    for r in results:
        s = r.spec
        if (
            not s.faults
            or r.truncated
            or s.n_pools != 1
            or s.sched_cfg
            or s.charge_overhead
            or s.time_scale != 1.0
        ):
            continue
        plan = FaultPlan.from_dict(s.faults)
        if plan.enabled() and plan.mttf_ms <= 0.0:
            continue  # not a crash-severity cell (timeout/straggler-only)
        base = dict(s.to_dict())
        base.pop("tag")
        base.pop("faults")  # the level is identified by the plan's mttf
        base.pop("seed")
        base.pop("engine", None)
        key = (
            json.dumps(base | {"system": ""}, sort_keys=True),
            s.slo_scale,
            s.system,
            plan.mttf_ms,
        )
        acc[key].append(r.finish_rate)
    series: dict[tuple[str, float], dict[str, list[tuple[float, float]]]] = (
        defaultdict(lambda: defaultdict(list))
    )
    for (case, slo, system, mttf), rates in acc.items():
        series[(case, slo)][system].append((mttf, sum(rates) / len(rates)))
    for per_sys in series.values():
        for pts in per_sys.values():
            # fault-free (mttf 0) first, then descending MTTF = rising severity
            pts.sort(key=lambda p: (0, 0.0) if p[0] == 0.0 else (1, -p[0]))
    return series


def claim_graceful_degradation(
    results: Sequence[ExperimentResult],
    rise_slack: float = FAULT_RISE_SLACK,
    cliff: float = FAULT_CLIFF,
    dominance_slack: float = FAULT_DOMINANCE_SLACK,
) -> ClaimResult:
    """Crash severity degrades finish rates *gracefully*: per system the
    seed-mean finish rate falls (within ``rise_slack``) as MTTF shrinks,
    never by more than ``cliff`` between adjacent levels, and ORLOJ stays
    within ``dominance_slack`` of the top at every level (crashes must
    not invert the paper's ordering)."""
    desc = (
        f"per system, finish rate under rising crash severity is monotone "
        f"(within {rise_slack:g}) with no adjacent-level drop > {cliff:g}, "
        f"and orloj >= every baseline - {dominance_slack:g} at each level"
    )
    cells, worst = [], float("inf")
    for (case, slo), per_sys in sorted(_severity_series(results).items()):
        for system, pts in sorted(per_sys.items()):
            if len(pts) < 2:
                continue
            for (m_a, fr_a), (m_b, fr_b) in zip(pts, pts[1:]):
                lvl = f"mttf{m_a:g}->mttf{m_b:g}"
                rise_margin = rise_slack - (fr_b - fr_a)
                cliff_margin = cliff - (fr_a - fr_b)
                worst = min(worst, rise_margin, cliff_margin)
                if rise_margin < 0.0 or cliff_margin < 0.0:
                    cells.append(
                        f"slo{slo:g}/{system} {lvl}: {fr_a:.3f}->{fr_b:.3f} "
                        f"(rise margin {rise_margin:+.3f}, "
                        f"cliff margin {cliff_margin:+.3f})"
                    )
            cells.append(
                f"slo{slo:g}/{system}: "
                + " -> ".join(f"{fr:.3f}@mttf{m:g}" for m, fr in pts)
            )
        if "orloj" in per_sys:
            orloj_by_lvl = dict(per_sys["orloj"])
            for system, pts in sorted(per_sys.items()):
                if system == "orloj":
                    continue
                for m, fr in pts:
                    if m not in orloj_by_lvl:
                        continue
                    margin = orloj_by_lvl[m] - fr + dominance_slack
                    worst = min(worst, margin)
                    if margin < 0.0:
                        cells.append(
                            f"slo{slo:g}@mttf{m:g}: orloj "
                            f"{orloj_by_lvl[m]:.3f} < {system} {fr:.3f} "
                            f"- slack ({margin:+.3f})"
                        )
    if worst == float("inf"):
        return _fail(
            "graceful-degradation", desc, "no crash-severity series with >= 2 levels"
        )
    return ClaimResult(
        "graceful-degradation", desc, worst >= 0.0, worst, tuple(cells)
    )


def claim_token_length_awareness(
    results: Sequence[ExperimentResult], max_slo: float = TOKEN_TIGHT_SLO_MAX
) -> ClaimResult:
    """Token-mode ordering (DESIGN.md §12): under tight TPOT SLOs,
    admission driven by the learned output-length distributions
    (``token_orloj``: shortest-expected-first with per-step conditional
    remaining-length feasibility and early dropping) finishes at least as
    many requests as length-blind FCFS continuous batching
    (``token_fcfs``) — strict, no tolerance, per token case and tight
    scale, seed-averaged.  The token-mode analogue of
    ``tight-slo-dominance``: knowing the length distribution is what buys
    predictability when per-request work is hidden until EOS."""
    desc = (
        f"token_orloj's seed-mean finish rate >= token_fcfs's on each "
        f"tokens case at TPOT scale < {max_slo:g}"
    )
    means = _seed_means(results)
    by_cell: dict[tuple[str, float], dict[str, float]] = defaultdict(dict)
    for (case, family, slo, system), fr in means.items():
        if family == "tokens" and slo < max_slo:
            by_cell[(case, slo)][system] = fr
    cells, worst = [], float("inf")
    for (case, slo), per_sys in sorted(by_cell.items()):
        if "token_orloj" not in per_sys or "token_fcfs" not in per_sys:
            continue
        aware, blind = per_sys["token_orloj"], per_sys["token_fcfs"]
        margin = aware - blind
        worst = min(worst, margin)
        cells.append(
            f"{case}@slo{slo:g}: token_orloj {aware:.3f} vs token_fcfs "
            f"{blind:.3f} ({margin:+.3f})"
        )
    if not cells:
        return _fail(
            "token-length-awareness",
            desc,
            "no tokens cells pairing token_orloj with token_fcfs at tight TPOT",
        )
    return ClaimResult(
        "token-length-awareness", desc, worst >= 0.0, worst, tuple(cells)
    )


def claim_nexus_slo2_gap(
    results: Sequence[ExperimentResult],
    window: tuple[float, float] = NEXUS_SLO2_WINDOW,
    bound: float = NEXUS_SLO2_BOUND,
) -> ClaimResult:
    """The intermediate-SLO regime is *bounded*, not ordered: at SLO
    scales in ``window`` Nexus's fixed-batch plan is genuinely
    competitive in this repro (DESIGN.md §7 — ORLOJ's probabilistic
    early dropping sheds a few requests Nexus goes on to finish), and
    this claim caps the seed-mean gap at ``bound`` so a regression that
    *widens* the regime still fails CI."""
    lo, hi = window
    desc = (
        f"seed-mean nexus-over-orloj finish-rate gap <= {bound:g} at SLO "
        f"scales in [{lo:g}, {hi:g}]"
    )
    means = _seed_means(results)
    by_cell: dict[tuple[str, float], dict[str, float]] = defaultdict(dict)
    for (case, family, slo, system), fr in means.items():
        if family in DYNAMIC_FAMILIES and lo <= slo <= hi:
            by_cell[(case, slo)][system] = fr
    cells, worst = [], float("inf")
    for (case, slo), per_sys in sorted(by_cell.items()):
        if "orloj" not in per_sys or "nexus" not in per_sys:
            continue
        gap = per_sys["nexus"] - per_sys["orloj"]
        margin = bound - gap
        worst = min(worst, margin)
        cells.append(
            f"{case}@slo{slo:g}: nexus {per_sys['nexus']:.3f} vs orloj "
            f"{per_sys['orloj']:.3f} (gap {gap:+.3f}, bound {bound:g})"
        )
    if not cells:
        return _fail(
            "nexus-slo2-gap", desc, "no orloj/nexus pairs in the SLO window"
        )
    return ClaimResult("nexus-slo2-gap", desc, worst >= 0.0, worst, tuple(cells))


# Multi-model spec knobs that must be observably inert at n_models == 1
# (no residency plan is built, no model assignment happens), plus their
# defaults — the single-model-noop pairing key.
_MM_KNOB_DEFAULTS = {
    "n_models": 1,
    "model_skew": 1.1,
    "worker_mem": 0.0,
    "residency_policy": "lru",
}


def _mm_noop_groups(
    results: Sequence[ExperimentResult],
) -> dict[str, dict[str, ExperimentResult]]:
    """Group ``n_models == 1`` cells identical up to (multi-model knobs,
    tag); within each group keep the all-defaults cell ("bare") and every
    knobs-set-but-inert variant.  Cells with ``n_models > 1`` never enter
    (they are supposed to differ)."""
    groups: dict[str, dict[str, ExperimentResult]] = defaultdict(dict)
    for r in results:
        if r.spec.n_models != 1:
            continue
        d = r.spec.to_dict()
        d.pop("tag")
        knobs = {k: d.pop(k) for k in _MM_KNOB_DEFAULTS}
        variant = (
            "bare"
            if knobs == _MM_KNOB_DEFAULTS
            else "inert:" + json.dumps(knobs, sort_keys=True)
        )
        groups[json.dumps(d, sort_keys=True)][variant] = r
    return groups


def claim_single_model_noop(
    results: Sequence[ExperimentResult],
) -> ClaimResult:
    """The multi-model tier is completely inert at ``n_models == 1``:
    cells identical up to the multi-model knobs — one with every knob at
    its default, one with skew/memory/policy set but n_models still 1 —
    agree bitwise on every outcome field (and their residency counters
    are zero).  This is what licenses threading the residency hooks
    through the event engines: every pre-multi-model grid cell replays
    unchanged (DESIGN.md §13)."""
    desc = (
        "n_models=1 cells identical up to the multi-model knobs agree "
        "exactly on " + ", ".join(_NOOP_FIELDS)
    )
    cells, worst = [], float("inf")
    for key, variants in sorted(_mm_noop_groups(results).items()):
        if "bare" not in variants or len(variants) < 2:
            continue
        base = variants["bare"]
        label = base.spec.tag or _case_label(base.spec)
        for variant, r in sorted(variants.items()):
            if variant == "bare":
                continue
            diffs = [
                f"{f}: {getattr(base, f)!r} vs {getattr(r, f)!r}"
                for f in _NOOP_FIELDS
                if getattr(base, f) != getattr(r, f)
            ]
            if base.n_model_loads or r.n_model_loads:
                diffs.append(
                    f"n_model_loads nonzero: {base.n_model_loads} / "
                    f"{r.n_model_loads}"
                )
            margin = -1.0 if diffs else 0.0
            worst = min(worst, margin)
            if diffs:
                cells.append(f"{label}: bare != {variant} — " + "; ".join(diffs))
            else:
                cells.append(
                    f"{label}: multi-model knobs are a noop at n_models=1 "
                    f"({base.n_finished_ok}+{base.n_finished_late} finished)"
                )
    if not cells:
        return _fail(
            "single-model-noop", desc, "no cell paired bare vs inert-knobs"
        )
    return ClaimResult("single-model-noop", desc, worst >= 0.0, worst, tuple(cells))


def _mm_policy_means(
    results: Iterable[ExperimentResult],
) -> dict[tuple, dict[str, float]]:
    """(case, slo, pool) -> {policy: seed-mean finish rate} over the
    multi-model pool cells (``n_models > 1``, flat orloj pools, default
    scheduler config) — the cold-start-dominance domain.  The case label
    carries the multi-model knobs, so cells at different memory budgets
    or eviction policies are never averaged together."""
    acc: dict[tuple, list[float]] = defaultdict(list)
    for r in results:
        s = r.spec
        if (
            s.n_models > 1
            and s.n_workers > 1
            and s.n_pools == 1
            and s.system == "orloj"
            and not s.sched_cfg
            and not s.charge_overhead
            and s.time_scale == 1.0
            and not s.faults
            and not r.truncated
        ):
            pool = f"r{s.n_workers}{'-hetero' if s.hetero else ''}"
            acc[(_case_label(s), s.slo_scale, pool, s.policy)].append(
                r.finish_rate
            )
    means = {k: sum(v) / len(v) for k, v in acc.items()}
    by_cell: dict[tuple, dict[str, float]] = defaultdict(dict)
    for (case, slo, pool, policy), fr in means.items():
        by_cell[(case, slo, pool)][policy] = fr
    return by_cell


def claim_cold_start_dominance(
    results: Sequence[ExperimentResult], slack: float = COLDSTART_SLACK
) -> ClaimResult:
    """Multi-model ordering (DESIGN.md §13): under memory pressure,
    residency-aware dispatch (place on a worker already holding the
    model's weights, falling back to least backlog) finishes at least as
    many requests as residency-blind ``round_robin``, which pays a PCIe
    weight load on nearly every dispatch — per multi-model pool cell,
    seed-averaged.  The multi-model analogue of ``tight-slo-dominance``:
    knowing where the weights live is what buys predictability when a
    cold start costs hundreds of milliseconds."""
    desc = (
        f"on multi-model pools under memory pressure, residency dispatch's "
        f"seed-mean finish rate >= round_robin's within {slack:g}"
    )
    return _dispatch_ordering(
        "cold-start-dominance", desc, "residency", _mm_policy_means(results), slack
    )


def evaluate_claims(
    results: Sequence[ExperimentResult],
    *,
    tight_slo_max: float = TIGHT_SLO_MAX,
    static_band: float = STATIC_NOISE_BAND,
    mono_slack: float = MONO_SLACK,
    scaleout_slack: float = SCALEOUT_SLACK,
    p2c_slack: float = P2C_SLACK,
    homog_band: float = HOMOG_BAND,
) -> list[ClaimResult]:
    """Assemble the claim set a result set can actually support.  Each
    claim is *stated* only when its domain is populated — the fleet-scale
    ``cluster`` grids contain no single-worker conformance cells, and the
    paper grids contain no wall-budgeted ones; a grid is never failed on
    a claim it was not designed to exercise.  Within a stated claim an
    empty domain still fails (that is a broken grid, not a missing one).

    Truncated cells (``wall_budget_s`` overrun) are *skipped* by every
    outcome claim — their stats are partial — and reported through
    ``cluster-wall-budget``, which fails them outright."""
    live = [r for r in results if not r.truncated]
    claims = []
    # The paper claims need single-worker default-config cells; each is
    # stated only when *its own* domain is populated, so a focused
    # diagnostic grid (e.g. slo2-bimodal, all-dynamic at intermediate
    # scales) is not failed on claims whose cells it never carried.
    eligible = [r for r in results if _eligible(r)]
    if any(
        r.spec.workload in DYNAMIC_FAMILIES and r.spec.slo_scale < tight_slo_max
        for r in eligible
    ):
        claims.append(claim_tight_slo_dominance(results, tight_slo_max))
    if any(r.spec.workload == "static" for r in eligible):
        claims.append(claim_static_parity(results, static_band))
    slos_per_series: dict[tuple, set] = defaultdict(set)
    for r in eligible:
        slos_per_series[(_case_label(r.spec), r.spec.system)].add(
            r.spec.slo_scale
        )
    if any(len(s) >= 2 for s in slos_per_series.values()):
        claims.append(claim_slo_monotonicity(results, mono_slack))
    # The intermediate-SLO bounding claim (slo2-bimodal grid): stated
    # whenever eligible orloj/nexus pairs land inside the window.
    lo, hi = NEXUS_SLO2_WINDOW
    slo2_systems: dict[tuple, set] = defaultdict(set)
    for r in results:
        if _eligible(r) and lo <= r.spec.slo_scale <= hi:
            slo2_systems[(_case_label(r.spec), r.spec.slo_scale)].add(
                r.spec.system
            )
    if any({"orloj", "nexus"} <= s for s in slo2_systems.values()):
        claims.append(claim_nexus_slo2_gap(results))
    # Token-mode ordering (tokens grids): stated when eligible tight-TPOT
    # cells pair the length-aware scheduler with length-blind FCFS.
    token_systems: dict[tuple, set] = defaultdict(set)
    for r in eligible:
        if r.spec.workload == "tokens" and r.spec.slo_scale < TOKEN_TIGHT_SLO_MAX:
            token_systems[(_case_label(r.spec), r.spec.slo_scale)].add(
                r.spec.system
            )
    if any({"token_orloj", "token_fcfs"} <= s for s in token_systems.values()):
        claims.append(claim_token_length_awareness(results))
    # Dispatch-ordering claims need flat pool cells with the compared
    # policies; grids without them (tiny, the legacy table sweeps, the
    # fleet grids) simply don't state them rather than failing on
    # "no cells".
    pool_means = _pool_policy_means(live)
    pool_policies = {p for per_pol in pool_means.values() for p in per_pol}
    if {"jsq_work", "round_robin"} <= pool_policies:
        claims.append(claim_scaleout_dispatch(live, scaleout_slack))
    if {"p2c", "round_robin"} <= pool_policies:
        claims.append(claim_p2c_dispatch(live, p2c_slack))
    if any(
        "-hetero" not in pool and len(per_pol) >= 2
        for (_case, _slo, pool), per_pol in pool_means.items()
    ):
        claims.append(claim_homog_pool_parity(live, homog_band))
    # Fleet-grid gates: wall budgets and scalar/array outcome equivalence.
    # The budget claim alone sees truncated cells (and fails them).
    if any(r.spec.wall_budget_s > 0.0 for r in results):
        claims.append(claim_cluster_wall_budget(results))
    engines_by_pair: dict[str, set] = defaultdict(set)
    for r in live:
        d = r.spec.to_dict()
        engine = d.pop("engine")
        d.pop("tag")
        engines_by_pair[json.dumps(d, sort_keys=True)].add(engine)
    if any(len(e) >= 2 for e in engines_by_pair.values()):
        claims.append(claim_array_scalar_equivalence(live))
    # Chaos-grid gates: the disabled-plan noop contract and the crash
    # severity ladder (DESIGN.md §11).
    if any(
        "bare" in v and len(v) >= 2 for v in _noop_groups(live).values()
    ):
        claims.append(claim_fault_free_noop(live))
    # Multi-model gates (DESIGN.md §13): the inert-knobs noop contract
    # and the residency-vs-blind dispatch ordering under memory pressure.
    if any(
        "bare" in v and len(v) >= 2 for v in _mm_noop_groups(live).values()
    ):
        claims.append(claim_single_model_noop(live))
    if any(
        {"residency", "round_robin"} <= set(per_pol)
        for per_pol in _mm_policy_means(live).values()
    ):
        claims.append(claim_cold_start_dominance(live))
    if any(
        len(pts) >= 2
        for per_sys in _severity_series(live).values()
        for pts in per_sys.values()
    ):
        claims.append(claim_graceful_degradation(live))
    return claims


def format_report(claims: Sequence[ClaimResult], verbose: bool = False) -> str:
    lines = []
    for c in claims:
        status = "PASS" if c.passed else "FAIL"
        lines.append(f"[{status}] {c.name} (worst margin {c.margin:+.3f})")
        lines.append(f"       {c.description}")
        # Evidence lines: always on failure, on request otherwise.
        if verbose or not c.passed:
            for cell in c.cells:
                lines.append(f"         {cell}")
    ok = all(c.passed for c in claims)
    lines.append(f"conformance: {'PASS' if ok else 'FAIL'} "
                 f"({sum(c.passed for c in claims)}/{len(claims)} claims)")
    return "\n".join(lines)
