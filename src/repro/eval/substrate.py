"""Engine substrate: run an :class:`~repro.eval.spec.ExperimentSpec`
against the real JAX serving engine instead of the Eq.-3 simulator.

This module is the bridge between the two halves of the codebase: the
scheduling/eval stack (``repro.core``, ``repro.eval``) and the JAX model
stack (``repro.models``, ``repro.serving.engine``).  A spec with
``substrate="engine"`` (or ``"engine:<model>"``) runs the *same* grid-cell
lifecycle as a sim cell — seeded request set, unified event loop, one
:class:`~repro.eval.spec.ExperimentResult` — except that every batch is a
real jitted forward pass and the virtual clock advances by the *measured*
wall-clock of that pass (DESIGN.md §8).

Sim↔engine mapping
------------------
Workload families are specified as *alone-time* distributions in ms at the
paper's reference constants (``c0=25, c1=1``).  On an XLA backend a
request's intrinsic size is its padded token count, so the mapping
rescales each family's alone-times onto the engine's bucket grid:

1. a fixed-seed calibration pass samples the family and anchors its
   ~P99.5 alone-time at the largest sequence bucket (a shape-preserving
   multiplicative rescale; consequently the Fig.-14 ``time_scale`` knob
   would be cancelled bit-for-bit by the calibration and is *rejected* on
   this substrate — real execution times cannot be shrunk);
2. each request's scaled length is snapped to its sequence bucket — the
   shape the hardware actually runs — and ``true_time`` carries that
   bucketed token count, so Eq. 3 with the engine's *profiled* ``(c0,
   c1)`` predicts measured batch latency;
3. SLOs and arrival rates are then derived exactly as in
   :func:`~repro.serving.trace.generate_requests`, but from the profiled
   latency curve, so "utilization 0.85" means the same thing relative to
   the real hardware as it does relative to the simulated worker.

Requests are bit-for-bit reproducible given the spec seed *and* the
profiled constants (cached per process); measured durations are not —
engine outcomes are real measurements.  Each engine cell also replays the
identical request set against an Eq.-3 *sim twin* (same k-padding, same
bucketing, predicted time instead of measured), and the per-cell drift
between the two is reported in ``substrate_meta`` and aggregated into the
``engine_drift`` section of ``BENCH_eval.json``.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from ..core.eventloop import run_event_loop
from ..core.request import Request
from ..serving.batcher import bucket_for, padded_batch_size
from ..serving.trace import (
    RequestSet,
    TraceConfig,
    azure_like_arrivals,
    offered_rate,
    sample_alone_times,
)
from .spec import ExperimentResult, ExperimentSpec
from .workloads import build_workload

if TYPE_CHECKING:  # pragma: no cover - typing only, keeps jax out of import
    from ..core.distributions import BatchLatencyModel
    from ..core.eventloop import Worker
    from ..serving.engine import ServingEngine

__all__ = [
    "DEFAULT_ENGINE_MODEL",
    "ENGINE_MODELS",
    "EngineModelSpec",
    "build_engine_request_set",
    "drift_report",
    "engine_available",
    "parse_substrate",
    "run_engine_spec",
]


# ---------------------------------------------------------------- registry


@dataclasses.dataclass(frozen=True)
class EngineModelSpec:
    """One servable model the engine substrate can instantiate.

    ``arch`` names a module in ``repro.configs``; ``toy`` serves its
    ``reduced()`` smoke variant (CPU-runnable) with ``config_overrides``
    applied on top.  ``buckets``/``batch_sizes`` default to the config
    module's ``SERVE_BUCKETS``/``SERVE_BATCH_SIZES`` when ``None``."""

    arch: str
    toy: bool = True
    config_overrides: tuple[tuple[str, object], ...] = ()
    buckets: tuple[int, ...] | None = None
    batch_sizes: tuple[int, ...] | None = None
    profile_reps: int = 2
    init_seed: int = 0


DEFAULT_ENGINE_MODEL = "orloj_gpt"

# name -> servable profile.  ``orloj_gpt`` is the paper's GPT-class example
# model at toy sizes (the engine-smoke grid's workhorse); ``orloj_gpt_paper``
# is the full ~100M configuration for opt-in paper-scale engine runs.
ENGINE_MODELS: dict[str, EngineModelSpec] = {
    "orloj_gpt": EngineModelSpec(
        arch="orloj_gpt",
        toy=True,
        config_overrides=(
            ("d_model", 64),
            ("n_heads", 4),
            ("n_kv_heads", 4),
            ("d_ff", 128),
            ("vocab_size", 256),
        ),
        buckets=(8, 16, 24, 32),
        batch_sizes=(1, 2, 4),
    ),
    "orloj_gpt_paper": EngineModelSpec(arch="orloj_gpt", toy=False),
}


def parse_substrate(substrate: str) -> tuple[str, str]:
    """``"sim"`` → ``("sim", "")``; ``"engine"``/``"engine:<model>"`` →
    ``("engine", model)``.  Raises ``ValueError`` on anything else."""
    if substrate == "sim":
        return "sim", ""
    kind, _, model = substrate.partition(":")
    model = model or DEFAULT_ENGINE_MODEL
    if kind != "engine":
        raise ValueError(
            f"unknown substrate {substrate!r}; expected 'sim', 'engine' or "
            f"'engine:<model>'"
        )
    if model not in ENGINE_MODELS:
        raise ValueError(
            f"unknown engine model {model!r}; known: {sorted(ENGINE_MODELS)}"
        )
    return kind, model


# ----------------------------------------------------------- availability


def _engine_import_error() -> str | None:
    """Why the JAX model stack cannot be imported, or ``None`` if it can.
    Kept as a hook point: tests monkeypatch this to simulate a bare env."""
    try:
        importlib.import_module("jax")
    except Exception as e:  # pragma: no cover - depends on environment
        return f"{type(e).__name__}: {e}"
    return None


def engine_available() -> bool:
    """True iff ``substrate="engine"`` cells can run in this environment."""
    return _engine_import_error() is None


# Engines are expensive to build (model init + per-shape compilation +
# latency-curve profiling), so one per registry model is cached per process
# and shared across cells; the compiled-program cache makes cell N of an
# engine grid much cheaper than cell 1.
_ENGINE_CACHE: dict[str, tuple["ServingEngine", "BatchLatencyModel"]] = {}


def _get_engine(model: str) -> tuple["ServingEngine", "BatchLatencyModel"]:
    if model in _ENGINE_CACHE:
        return _ENGINE_CACHE[model]
    err = _engine_import_error()
    if err is not None:
        raise RuntimeError(
            f"substrate 'engine' needs the JAX model stack, which failed to "
            f"import ({err}); install the 'jax' dependency or run the cell "
            f"with substrate='sim'"
        )
    from ..serving.engine import EngineConfig, ServingEngine  # imports jax

    entry = ENGINE_MODELS[model]
    mod = importlib.import_module(f"..configs.{entry.arch}", __package__)
    cfg = mod.CONFIG.reduced(**dict(entry.config_overrides)) if entry.toy else mod.CONFIG
    engine = ServingEngine(
        cfg,
        EngineConfig(
            buckets=entry.buckets or mod.SERVE_BUCKETS,
            batch_sizes=entry.batch_sizes or mod.SERVE_BATCH_SIZES,
            profile_reps=entry.profile_reps,
        ),
        seed=entry.init_seed,
    )
    lm = engine.profile_latency_model()
    _ENGINE_CACHE[model] = (engine, lm)
    return engine, lm


# -------------------------------------------------------- request mapping

# The alone-time→token calibration must not drift with the trace seed (two
# seeds of one cell must measure the same workload), hence its own fixed
# seed; payload token values get an offset stream so they never correlate
# with the trace draws.
_CALIBRATION_SEED = 0x5EED_CAB
_PAYLOAD_SEED_OFFSET = 7_654_321
_CALIBRATION_SAMPLES = 512
_HISTORY_PER_APP = 256


def _snap_lengths(
    alone_ms: np.ndarray, tokens_per_ms: float, buckets: tuple[int, ...]
) -> tuple[np.ndarray, np.ndarray]:
    """Map alone-times to (payload lengths, bucketed sizes) on the grid."""
    lengths = np.clip(
        np.rint(alone_ms * tokens_per_ms), 1, buckets[-1]
    ).astype(np.int64)
    sizes = np.array([bucket_for(int(n), buckets) for n in lengths], np.float64)
    return lengths, sizes


def build_engine_request_set(
    spec: ExperimentSpec,
    buckets: tuple[int, ...],
    batch_sizes: tuple[int, ...],
    lm: "BatchLatencyModel",
    vocab_size: int,
) -> RequestSet:
    """The engine-side analogue of :func:`~repro.serving.trace
    .generate_requests`: same §5.2 methodology (per-app sampling, SLO =
    ``slo_scale``×P99-alone, MAF-like arrivals at a capacity-relative
    rate), except that sizes are token counts snapped to the engine's
    sequence buckets and every request carries a real token payload.

    Deterministic given ``(spec, buckets, batch_sizes, lm)``; the profiled
    ``lm`` only affects alone-times/SLO/arrival pacing, never which token
    lengths are drawn."""
    apps = build_workload(spec.workload, spec.workload_params, spec.time_scale)

    # 1. calibration: anchor the family's ~P99.5 alone-time at the largest
    # bucket (shape-preserving rescale into the representable range).
    crng = np.random.default_rng(_CALIBRATION_SEED)
    calib = np.concatenate([a.sample(crng, _CALIBRATION_SAMPLES) for a in apps])
    ref = float(np.quantile(calib, 0.995))
    tokens_per_ms = buckets[-1] / max(ref, 1e-9)

    # 2. the seeded trace draw (shared §5.2 sampling with generate_requests).
    rng = np.random.default_rng(spec.seed)
    n = spec.n_requests
    which, alone_ms = sample_alone_times(apps, rng, n)
    lengths, sizes = _snap_lengths(alone_ms, tokens_per_ms, buckets)

    alone = lm.c0 + lm.c1 * sizes
    p99 = float(np.quantile(alone, 0.99))
    slo = spec.slo_scale * p99

    # 3. arrival pacing relative to the *profiled* capacity (Eq. 4 E[max]
    # straggler inflation at the largest supported batch).
    rate = offered_rate(sizes, lm, spec.utilization, batch_sizes[-1], rng)
    cfg = TraceConfig(
        n_requests=n, utilization=spec.utilization, seed=spec.seed
    )
    arrivals = azure_like_arrivals(rate, n, cfg, rng)

    prng = np.random.default_rng(spec.seed + _PAYLOAD_SEED_OFFSET)
    reqs = [
        Request(
            app_id=apps[w].app_id,
            release=float(at),
            slo=slo,
            true_time=float(s),
            payload=prng.integers(1, vocab_size, size=int(L)).astype(np.int32),
        )
        for w, at, s, L in zip(which, arrivals, sizes, lengths)
    ]
    history = {}
    for app in apps:
        _, szs = _snap_lengths(
            app.sample(rng, _HISTORY_PER_APP), tokens_per_ms, buckets
        )
        history[app.app_id] = szs
    return RequestSet(requests=reqs, p99_alone=p99, app_history=history)


# ------------------------------------------------------------- execution


@dataclasses.dataclass
class _PredictedExecutor:
    """Eq.-3 twin of :class:`~repro.serving.engine.JaxExecutor`: identical
    k-padding and sequence bucketing, predicted time instead of measured.
    The drift between a cell served by this and by the real executor is
    pure modelling error + hardware noise — the quantity ``engine_drift``
    reports."""

    lm: "BatchLatencyModel"
    buckets: tuple[int, ...]
    batch_sizes: tuple[int, ...]

    def __call__(self, batch, now: float) -> float:
        k = padded_batch_size(len(batch.requests), self.batch_sizes)
        size = bucket_for(
            int(math.ceil(max(r.true_time for r in batch.requests))), self.buckets
        )
        return self.lm.c0 + self.lm.c1 * k * size


def _pool(
    spec: ExperimentSpec,
    lm: "BatchLatencyModel",
    rs: RequestSet,
    engine: "ServingEngine",
    batch_sizes: tuple[int, ...],
    *,
    predicted: bool,
) -> "list[Worker]":
    """Build the worker pool for one engine cell (or its sim twin) — same
    shared pool builder as the sim substrate, with the executor swapped."""
    from .runner import _build_pool

    if predicted:
        ex_for = lambda i, wlm, slow: _PredictedExecutor(  # noqa: E731
            wlm, engine.cfg.buckets, batch_sizes
        )
    else:
        ex_for = lambda i, wlm, slow: engine.executor_for(  # noqa: E731
            2.0 if slow else 1.0
        )
    return _build_pool(spec, lm, rs, ex_for, batch_sizes=batch_sizes)


def run_engine_spec(spec: ExperimentSpec) -> ExperimentResult:
    """Run one ``substrate="engine"`` cell and fold the measured replay
    into the standard :class:`ExperimentResult` schema (so the claims
    layer consumes it unmodified)."""
    t_wall = time.perf_counter()  # simlint: ignore[R1] -- wall_time_s metadata column; engine cells measure real hardware by design
    kind, model = parse_substrate(spec.substrate)
    if kind != "engine":
        raise ValueError(f"run_engine_spec got a {kind!r} spec: {spec}")
    if spec.time_scale != 1.0:
        # The Fig.-14 shrink knob is sim-only: the engine's execution
        # times are real, and the calibration rescale would cancel a
        # scaled workload back out bit-for-bit — a silent no-op is worse
        # than an error.
        raise ValueError(
            f"time_scale={spec.time_scale:g} is not supported on the engine "
            f"substrate (execution times are measured, not modelled); run "
            f"the shrink sweep with substrate='sim'"
        )
    engine, lm = _get_engine(model)
    batch_sizes = engine.cfg.batch_sizes
    rs = build_engine_request_set(
        spec, engine.cfg.buckets, batch_sizes, lm, engine.model.cfg.vocab_size
    )
    loop_seed = spec.seed if spec.loop_seed is None else spec.loop_seed

    engine.executor.drain_measured()
    served = rs.fresh()
    # The real replay goes through the engine's own pool entry point; the
    # per-replica executors come from its factory (scaled-slow for the
    # hetero back half).
    workers = _pool(spec, lm, rs, engine, batch_sizes, predicted=False)
    res = engine.serve_pool(
        served,
        [w.scheduler for w in workers],
        policy=spec.policy,
        seed=loop_seed,
        charge_scheduler_overhead=spec.charge_overhead,
        executors=[w.executor for w in workers],
    )
    measured = engine.executor.drain_measured()

    # Per-batch predicted-vs-measured drift of the executed shapes (MAPE
    # convention: error relative to the *measured* value).
    err = np.array(
        [abs(ms - (lm.c0 + lm.c1 * k * b)) for k, b, ms in measured]
    )
    meas = np.array([ms for _, _, ms in measured])

    # Sim twin: the identical request set under the Eq.-3 executor, with
    # every knob (including overhead charging) matching the real run so
    # the drift is modelling error + hardware noise, nothing else.
    twin = run_event_loop(
        rs.fresh(),
        _pool(spec, lm, rs, engine, batch_sizes, predicted=True),
        policy=spec.policy,
        charge_scheduler_overhead=spec.charge_overhead,
        seed=loop_seed,
    )

    meta = {
        "model": model,
        "model_name": engine.model.cfg.name,
        "c0_ms": lm.c0,
        "c1_ms_per_token": lm.c1,
        "buckets": list(engine.cfg.buckets),
        "batch_sizes": list(batch_sizes),
        "n_batches": res.n_batches,
        # The executor's measured log is a bounded ring; if a paper-scale
        # cell overflows it, the drift stats cover only the most recent
        # MEASURED_LOG_CAP batches — flagged so the artifact never claims
        # more coverage than it has.
        "batch_log_truncated": len(measured) < res.n_batches,
        "batch_abs_err_p50_ms": float(np.median(err)) if len(err) else 0.0,
        "batch_mape": float(np.mean(err / meas)) if len(err) else 0.0,
        # Finish set by request *index* in generation order (rids are a
        # process-global counter and not stable across runs).
        "finish_idx": [i for i, r in enumerate(served) if r.ok],
        "sim_twin": {
            "finish_rate": twin.finish_rate,
            "n_finished_ok": twin.n_finished_ok,
            "n_dropped": twin.n_dropped,
            "latency_p50_ms": float(np.quantile(twin.latencies, 0.5))
            if len(twin.latencies)
            else 0.0,
        },
        "finish_rate_drift": res.finish_rate - twin.finish_rate,
    }
    from .runner import _fold_result

    return _fold_result(
        # simlint: ignore[R1] -- wall_time_s metadata column; engine cells measure real hardware by design
        spec, rs, res, time.perf_counter() - t_wall, substrate_meta=meta
    )


def drift_report(results: Sequence[ExperimentResult]) -> dict | None:
    """Aggregate the per-cell sim-vs-engine drift of a result set into the
    ``engine_drift`` artifact section; ``None`` when there are no engine
    cells."""
    cells = []
    for r in results:
        m = r.substrate_meta
        if r.spec.substrate == "sim" or "sim_twin" not in m:
            continue
        cells.append(
            {
                "tag": r.spec.tag,
                "model": m["model"],
                "finish_rate_engine": r.finish_rate,
                "finish_rate_sim_twin": m["sim_twin"]["finish_rate"],
                "finish_rate_drift": m["finish_rate_drift"],
                "batch_mape": m["batch_mape"],
                "n_batches": m["n_batches"],
            }
        )
    if not cells:
        return None
    drifts = np.array([abs(c["finish_rate_drift"]) for c in cells])
    mapes = np.array([c["batch_mape"] for c in cells])
    return {
        "n_cells": len(cells),
        "mean_abs_finish_rate_drift": float(drifts.mean()),
        "max_abs_finish_rate_drift": float(drifts.max()),
        "mean_batch_mape": float(mapes.mean()),
        "cells": cells,
    }
