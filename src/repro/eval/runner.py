"""Run experiment specs: one seeded replay per cell, processes fanned out.

This is stage 2 of the grid-cell lifecycle (spec → seeded RequestSet →
result → claim, see :mod:`repro.eval.spec`).  Every cell is
self-contained — ``run_spec`` regenerates the request set from the spec's
seed (bit-for-bit, see the replay-fairness test) and replays it through
the unified event loop — so the grid parallelizes with no shared state:
serial and parallel execution produce identical outcome fields.

Substrates: ``substrate="sim"`` cells replay against the Eq.-3
:class:`~repro.core.eventloop.ModelExecutor` and fan out over a process
pool.  ``substrate="engine"`` cells (:mod:`repro.eval.substrate`) run the
real JAX engine and always execute serially in the host process — the
engine's model parameters, compiled programs and profiled latency curve
are cached per process, and re-paying model init + XLA compilation in
every pool worker would dwarf the cells themselves.

``write_artifact`` persists a result set as ``BENCH_eval.json`` next to
``BENCH_sched.json``.
"""

from __future__ import annotations

import json
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Iterable, Sequence

import numpy as np

from ..core import (
    BASELINES,
    BatchLatencyModel,
    ModelExecutor,
    MultiModelOrlojScheduler,
    OrlojScheduler,
    SchedulerConfig,
    Worker,
    run_event_loop,
)
from ..core.eventloop import DecodeModelExecutor, Executor, SimResult
from ..core.tokensched import (
    FcfsTokenScheduler,
    LengthAwareTokenScheduler,
    TokenSchedConfig,
)
from ..serving.faults import FaultPlan
from ..serving.residency import ResidencyPlan, latency_scales, model_roster
from ..serving.trace import (
    RequestSet,
    TraceConfig,
    generate_requests,
    generate_token_requests,
)
from .spec import ExperimentResult, ExperimentSpec
from .workloads import build_workload

__all__ = [
    "run_spec",
    "run_specs",
    "write_artifact",
    "read_artifact",
    "token_sched_config",
    "generate_token_set",
    "DEFAULT_ARTIFACT",
]

DEFAULT_ARTIFACT = "BENCH_eval.json"


def _make_scheduler(
    spec: ExperimentSpec,
    lm: BatchLatencyModel,
    rs: RequestSet,
    batch_sizes: tuple[int, ...] | None = None,
):
    """Instantiate the spec's scheduler.  ``batch_sizes`` pins the
    supported batch grid (the engine substrate passes its executor's
    supported sizes so the scheduler never plans an unservable batch);
    an explicit ``sched_cfg`` entry still wins."""
    if spec.system == "orloj":
        cfg_kw = dict(spec.sched_cfg)
        if batch_sizes is not None:
            cfg_kw.setdefault("batch_sizes", tuple(batch_sizes))
        cfg = SchedulerConfig(**cfg_kw)
        if spec.n_models > 1:
            # One BinScoreModel per zoo model: each model's alone-time
            # distributions are the base trace dists scaled by its
            # latency ladder (the same scaling _assign_models applied to
            # true_time), so the priors match the replayed traffic.
            base = rs.initial_dists()
            dists = {
                m: {a: d.affine(s, 0.0) for a, d in base.items()}
                for m, s in zip(
                    model_roster(spec.n_models), latency_scales(spec.n_models)
                )
            }
            return MultiModelOrlojScheduler(lm, dists, cfg=cfg)
        return OrlojScheduler(lm, cfg=cfg, initial_dists=rs.initial_dists())
    if spec.n_models > 1:
        raise ValueError(
            "multi-model cells support system='orloj' only: baselines "
            "have no per-model distribution state to key batches by "
            "(DESIGN.md §13)"
        )
    try:
        cls = BASELINES[spec.system]
    except KeyError:
        raise ValueError(
            f"unknown system {spec.system!r}; known: "
            f"{['orloj', *sorted(BASELINES)]}"
        ) from None
    kw = {} if batch_sizes is None else {"batch_sizes": tuple(batch_sizes)}
    # Baselines are warm-started from the same historical samples ORLOJ's
    # initial distributions are built from (§5.2 fairness).
    return cls(lm, init_samples=rs.warm_samples(), **kw)


def _slow_lm(lm: BatchLatencyModel) -> BatchLatencyModel:
    """The heterogeneous-pool convention, shared by both substrates: the
    back half of the pool runs a 2x-slower latency curve (and, on the
    engine substrate, a 2x-scaled executor)."""
    return BatchLatencyModel(c0=2.0 * lm.c0, c1=2.0 * lm.c1)


def _build_pool(
    spec: ExperimentSpec,
    lm: BatchLatencyModel,
    rs: RequestSet,
    executor_for: Callable[[int, BatchLatencyModel, bool], Executor],
    batch_sizes: tuple[int, ...] | None = None,
) -> list[Worker]:
    """Assemble the spec's worker pool — the one place the heterogeneous
    convention (back half of the pool 2x slower) lives, shared by the sim
    substrate, the engine substrate and its sim twin.  ``executor_for(i,
    wlm, slow)`` supplies each replica's executor."""
    slow = _slow_lm(lm)
    workers = []
    for i in range(spec.n_workers):
        is_slow = spec.hetero and i >= spec.n_workers // 2
        wlm = slow if is_slow else lm
        workers.append(
            Worker(
                _make_scheduler(spec, wlm, rs, batch_sizes=batch_sizes),
                executor_for(i, wlm, is_slow),
            )
        )
    return workers


def _token_metrics(reqs: Sequence) -> dict:
    """TTFT/TPOT quantiles + token throughput from a replayed token-mode
    request list (``first_token``/``tokens_done`` are object state written
    identically by both engines, so these fold bit-identically)."""
    ttfts: list[float] = []
    tpots: list[float] = []
    n_tok = 0
    for r in reqs:
        n_tok += r.tokens_done
        if r.first_token is not None:
            ttfts.append(r.first_token - r.release)
        if r.finished is not None and r.tokens_done > 1:
            tpots.append((r.finished - r.first_token) / (r.tokens_done - 1))

    def q(xs: list[float], p: float) -> float:
        return float(np.quantile(np.asarray(xs), p)) if xs else 0.0

    return dict(
        ttft_p50_ms=q(ttfts, 0.5),
        ttft_p99_ms=q(ttfts, 0.99),
        tpot_p50_ms=q(tpots, 0.5),
        tpot_p99_ms=q(tpots, 0.99),
        n_tokens_out=n_tok,
    )


def _fold_result(
    spec: ExperimentSpec,
    rs: RequestSet,
    res: SimResult,
    wall_s: float,
    substrate_meta: dict | None = None,
    token_metrics: dict | None = None,
) -> ExperimentResult:
    """Fold one replay's :class:`~repro.core.eventloop.SimResult` into the
    :class:`ExperimentResult` schema — the single mapping both substrates
    go through, so engine and sim results can never diverge field-wise."""
    lat = res.latencies
    return ExperimentResult(
        spec=spec,
        finish_rate=res.finish_rate,
        n_total=res.n_total,
        n_finished_ok=res.n_finished_ok,
        n_finished_late=res.n_finished_late,
        n_dropped=res.n_dropped,
        n_unserved=res.n_unserved,
        n_rejected=res.n_rejected,
        n_failed=res.n_failed,
        n_retried=res.n_retried,
        n_model_loads=res.n_model_loads,
        n_model_evicts=res.n_model_evicts,
        model_load_ms=res.model_load_ms,
        truncated=res.truncated,
        utilization=res.utilization,
        makespan_ms=res.makespan_ms,
        p99_alone_ms=rs.p99_alone,
        latency_p50_ms=float(np.quantile(lat, 0.5)) if len(lat) else 0.0,
        latency_p99_ms=float(np.quantile(lat, 0.99)) if len(lat) else 0.0,
        n_decisions=res.n_decisions,
        sched_time_ms=res.sched_time_ms,
        sched_us_per_request=res.sched_us_per_request,
        wall_s=wall_s,
        substrate_meta=substrate_meta or {},
        **(token_metrics or {}),
    )


def token_sched_config(spec: ExperimentSpec) -> TokenSchedConfig:
    """The spec's token-mode scheduler config (DESIGN.md §12).  The
    spec's Eq.-3 constants double as the decode-step cost model
    (``d0 + d1·k`` per step) and ``slo_scale`` is the TPOT tightness
    axis: ``tpot = slo_scale × (d0 + d1·reference_batch)`` — scale 1
    means "exactly one reference-batch step per token", so scales just
    above 1 bind hard and large scales are loose.  TTFT rides along at
    ``ttft_mult`` TPOTs."""
    p = spec.workload_params
    d0, d1 = spec.lm_c0, spec.lm_c1
    k_ref = int(p.get("reference_batch", 8))
    tpot = spec.slo_scale * (d0 + d1 * k_ref)
    return TokenSchedConfig(
        max_batch=int(p.get("max_batch", 16)),
        ttft_slo_ms=float(p.get("ttft_mult", 8.0)) * tpot,
        tpot_slo_ms=tpot,
        d0=d0,
        d1=d1,
        prefill_per_token=float(p.get("prefill_per_token", 0.02)),
    )


def generate_token_set(spec: ExperimentSpec) -> RequestSet:
    """Regenerate a ``tokens`` spec's seeded request set (bit-for-bit,
    same replay-fairness contract as :func:`generate_requests`)."""
    cfg = token_sched_config(spec)
    apps = build_workload(spec.workload, spec.workload_params, spec.time_scale)
    return generate_token_requests(
        apps,
        d0=cfg.d0,
        d1=cfg.d1,
        prefill_per_token=cfg.prefill_per_token,
        ttft_slo_ms=cfg.ttft_slo_ms,
        tpot_slo_ms=cfg.tpot_slo_ms,
        cfg=TraceConfig(
            n_requests=spec.n_requests,
            utilization=spec.utilization,
            reference_batch=int(spec.workload_params.get("reference_batch", 8)),
            seed=spec.seed,
            tick_ms=spec.tick_ms,
        ),
    )


def _run_token_spec(spec: ExperimentSpec) -> ExperimentResult:
    """Replay one ``tokens`` cell: a token scheduler driving resumable
    decode batches through the event loop (DESIGN.md §12).  Token cells
    are sim-substrate, single-worker, fault-free by construction — the
    real-engine decode path is exercised by ``ServingEngine.serve_tokens``
    under the slow test tier, not by grid cells."""
    if spec.substrate != "sim":
        raise ValueError(
            "tokens cells run on the sim substrate only; the real decode "
            "path is ServingEngine.serve_tokens (slow test tier)"
        )
    if spec.n_workers != 1 or spec.n_pools != 1:
        raise ValueError(
            "tokens cells are single-worker: one continuous batch per "
            "replica is the unit the token schedulers reason about"
        )
    if spec.faults:
        raise ValueError("decode (token-level) cells do not support fault plans")
    if spec.n_models > 1:
        raise ValueError(
            "decode (token-level) cells do not support multi-model "
            "serving (DESIGN.md §13)"
        )
    if spec.sched_cfg:
        raise ValueError(
            "tokens cells configure schedulers via workload_params "
            "(max_batch, ttft_mult, ...), not sched_cfg"
        )
    t_wall = time.perf_counter()  # simlint: ignore[R1] -- wall_time_s metadata column; the replay itself is virtual-time
    cfg = token_sched_config(spec)
    rs = generate_token_set(spec)
    if spec.system == "token_orloj":
        sched = LengthAwareTokenScheduler(
            cfg, initial_len_dists=rs.initial_dists(n_bins=cfg.n_bins)
        )
    elif spec.system == "token_fcfs":
        sched = FcfsTokenScheduler(cfg)
    else:
        raise ValueError(
            f"unknown token system {spec.system!r}; "
            f"known: ['token_fcfs', 'token_orloj']"
        )
    reqs = rs.fresh()
    res = run_event_loop(
        reqs,
        [Worker(sched, DecodeModelExecutor(cfg.d0, cfg.d1, cfg.prefill_per_token))],
        charge_scheduler_overhead=spec.charge_overhead,
        seed=spec.seed if spec.loop_seed is None else spec.loop_seed,
        engine=spec.engine,
        wall_budget_s=spec.wall_budget_s,
    )
    return _fold_result(
        spec, rs, res,
        time.perf_counter() - t_wall,  # simlint: ignore[R1] -- wall_s metadata column; the replay itself is virtual-time
        token_metrics=_token_metrics(reqs),
    )


def run_spec(spec: ExperimentSpec) -> ExperimentResult:
    """Regenerate the spec's seeded request set and replay it once (on the
    spec's substrate)."""
    if spec.workload == "tokens":
        return _run_token_spec(spec)
    if spec.n_models > 1 and spec.substrate != "sim":
        raise ValueError(
            "multi-model cells run on the sim substrate only: the engine "
            "substrate serves one compiled model per process (DESIGN.md §13)"
        )
    if spec.substrate != "sim":
        # Deferred import: the engine substrate pulls in the JAX model
        # stack only when an engine cell actually runs, so sim-only
        # environments (the bare-env CI job) never touch it.
        from .substrate import run_engine_spec

        return run_engine_spec(spec)
    t_wall = time.perf_counter()  # simlint: ignore[R1] -- wall_time_s metadata column; the replay itself is virtual-time
    lm = BatchLatencyModel(c0=spec.lm_c0, c1=spec.lm_c1)
    apps = build_workload(spec.workload, spec.workload_params, spec.time_scale)
    rs = generate_requests(
        apps,
        lm,
        slo_scale=spec.slo_scale,
        cfg=TraceConfig(
            n_requests=spec.n_requests,
            utilization=spec.utilization,
            seed=spec.seed,
            tick_ms=spec.tick_ms,
            n_models=spec.n_models,
            model_skew=spec.model_skew,
        ),
    )
    residency = None
    if spec.n_models > 1:
        if spec.worker_mem <= 0:
            raise ValueError(
                "multi-model cells must set worker_mem (cache capacity "
                "in bytes; DESIGN.md §13)"
            )
        residency = ResidencyPlan.from_zoo(
            model_roster(spec.n_models),
            worker_mem=spec.worker_mem,
            policy=spec.residency_policy,
        )
    policy: str | Callable = spec.policy
    if spec.n_pools > 1:
        # Fleet mode: the spec's policy routes BETWEEN pools, intra_policy
        # places within the winning pool (serving.cluster).
        from ..serving.cluster import hierarchical_policy

        policy = hierarchical_policy(
            spec.n_workers,
            spec.n_pools,
            inter=spec.policy,
            intra=spec.intra_policy,
            seed=spec.seed if spec.loop_seed is None else spec.loop_seed,
        )
    # Fault plan: spec.faults is a plain dict (artifact-serializable);
    # an *empty* dict means no plan at all, while a populated-but-disabled
    # dict still threads a FaultPlan through the engine hooks — that
    # distinction is what makes the fault-free-noop claim non-vacuous.
    faults = FaultPlan.from_dict(spec.faults) if spec.faults else None
    res = run_event_loop(
        rs.fresh(),
        _build_pool(spec, lm, rs, lambda i, wlm, slow: ModelExecutor(wlm, seed=i)),
        policy=policy,
        charge_scheduler_overhead=spec.charge_overhead,
        seed=spec.seed if spec.loop_seed is None else spec.loop_seed,
        engine=spec.engine,
        faults=faults,
        residency=residency,
        wall_budget_s=spec.wall_budget_s,
    )
    # simlint: ignore[R1] -- wall_time_s metadata column; the replay itself is virtual-time
    return _fold_result(spec, rs, res, time.perf_counter() - t_wall)


def run_specs(
    specs: Sequence[ExperimentSpec], jobs: int = 1
) -> list[ExperimentResult]:
    """Run a grid; results come back in spec order.

    ``jobs > 1`` fans cells out over a process pool (each cell regenerates
    its own request set, so there is nothing to share); ``jobs <= 0`` means
    one process per CPU.
    """
    specs = list(specs)
    if jobs <= 0:
        jobs = os.cpu_count() or 1
    # Engine cells always run serially in the host process: the engine's
    # compiled programs and profiled latency curve are cached per process,
    # and every pool worker would re-pay model init + XLA compilation.
    sim_idx = [i for i, s in enumerate(specs) if s.substrate == "sim"]
    if jobs == 1 or len(sim_idx) <= 1:
        return [run_spec(s) for s in specs]
    results: list[ExperimentResult | None] = [None] * len(specs)
    chunk = max(1, len(sim_idx) // (4 * jobs))
    # Spawn, not fork: the host process may have JAX's threads running
    # (e.g. under pytest after real-engine tests), and forking a
    # multithreaded process can deadlock.  Workers only import numpy-level
    # code, so the spawn import cost is small and paid once per worker.
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as pool:
        sim_results = pool.map(
            run_spec, [specs[i] for i in sim_idx], chunksize=chunk
        )
        # Engine cells run in the host while the pool churns through the
        # sim cells: mixed grids cost max(sim, engine) wall, not the sum.
        for i, s in enumerate(specs):
            if s.substrate != "sim":
                results[i] = run_spec(s)
        for i, r in zip(sim_idx, sim_results):
            results[i] = r
    return results  # type: ignore[return-value]


def write_artifact(
    path: str,
    results: Iterable[ExperimentResult],
    grid: str = "",
    claims: Sequence | None = None,
    extra: dict | None = None,
) -> dict:
    """Write the trajectory artifact (atomically) and return the document.

    ``extra`` merges additional top-level sections into the document (e.g.
    the ``engine_drift`` report of an engine-substrate grid)."""
    results = list(results)
    doc: dict = {
        "schema": 1,
        "grid": grid,
        "n_results": len(results),
        "results": [r.to_dict() for r in results],
    }
    if claims is not None:
        doc["claims"] = [c.to_dict() for c in claims]
        doc["passed"] = all(c.passed for c in claims)
    if extra:
        reserved = {"schema", "grid", "n_results", "results", "claims", "passed"}
        clash = reserved & extra.keys()
        if clash:
            raise ValueError(
                f"extra sections would overwrite reserved artifact keys: "
                f"{sorted(clash)}"
            )
        doc.update(extra)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return doc


def read_artifact(path: str) -> tuple[dict, list[ExperimentResult]]:
    with open(path) as f:
        doc = json.load(f)
    return doc, [ExperimentResult.from_dict(d) for d in doc["results"]]
