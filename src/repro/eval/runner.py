"""Run experiment specs: one seeded replay per cell, processes fanned out.

Every cell is self-contained — ``run_spec`` regenerates the request set
from the spec's seed (bit-for-bit, see the replay-fairness test) and
replays it through the unified event loop — so the grid parallelizes with
no shared state: serial and parallel execution produce identical outcome
fields.  ``write_artifact`` persists a result set as ``BENCH_eval.json``
next to ``BENCH_sched.json``.
"""

from __future__ import annotations

import json
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Iterable, Sequence

import numpy as np

from ..core import (
    BASELINES,
    BatchLatencyModel,
    ModelExecutor,
    OrlojScheduler,
    SchedulerConfig,
    Worker,
    run_event_loop,
)
from ..serving.trace import RequestSet, TraceConfig, generate_requests
from .spec import ExperimentResult, ExperimentSpec
from .workloads import build_workload

__all__ = [
    "run_spec",
    "run_specs",
    "write_artifact",
    "read_artifact",
    "DEFAULT_ARTIFACT",
]

DEFAULT_ARTIFACT = "BENCH_eval.json"


def _make_scheduler(spec: ExperimentSpec, lm: BatchLatencyModel, rs: RequestSet):
    if spec.system == "orloj":
        cfg = SchedulerConfig(**spec.sched_cfg)
        return OrlojScheduler(lm, cfg=cfg, initial_dists=rs.initial_dists())
    try:
        cls = BASELINES[spec.system]
    except KeyError:
        raise ValueError(
            f"unknown system {spec.system!r}; known: "
            f"{['orloj', *sorted(BASELINES)]}"
        ) from None
    # Baselines are warm-started from the same historical samples ORLOJ's
    # initial distributions are built from (§5.2 fairness).
    return cls(lm, init_samples=rs.warm_samples())


def run_spec(spec: ExperimentSpec) -> ExperimentResult:
    """Regenerate the spec's seeded request set and replay it once."""
    t_wall = time.perf_counter()
    lm = BatchLatencyModel(c0=spec.lm_c0, c1=spec.lm_c1)
    apps = build_workload(spec.workload, spec.workload_params, spec.time_scale)
    rs = generate_requests(
        apps,
        lm,
        slo_scale=spec.slo_scale,
        cfg=TraceConfig(
            n_requests=spec.n_requests,
            utilization=spec.utilization,
            seed=spec.seed,
        ),
    )
    slow_lm = BatchLatencyModel(c0=2.0 * spec.lm_c0, c1=2.0 * spec.lm_c1)
    workers = []
    for i in range(spec.n_workers):
        # Heterogeneous pools: the back half of the pool is 2x slower.
        wlm = slow_lm if (spec.hetero and i >= spec.n_workers // 2) else lm
        workers.append(
            Worker(_make_scheduler(spec, wlm, rs), ModelExecutor(wlm, seed=i))
        )
    res = run_event_loop(
        rs.fresh(),
        workers,
        policy=spec.policy,
        charge_scheduler_overhead=spec.charge_overhead,
        seed=spec.seed if spec.loop_seed is None else spec.loop_seed,
    )
    lat = res.latencies
    wall = time.perf_counter() - t_wall
    return ExperimentResult(
        spec=spec,
        finish_rate=res.finish_rate,
        n_total=res.n_total,
        n_finished_ok=res.n_finished_ok,
        n_finished_late=res.n_finished_late,
        n_dropped=res.n_dropped,
        n_unserved=res.n_unserved,
        utilization=res.utilization,
        makespan_ms=res.makespan,
        p99_alone_ms=rs.p99_alone,
        latency_p50_ms=float(np.quantile(lat, 0.5)) if len(lat) else 0.0,
        latency_p99_ms=float(np.quantile(lat, 0.99)) if len(lat) else 0.0,
        n_decisions=res.n_decisions,
        sched_time_ms=res.sched_time_ms,
        sched_us_per_request=res.sched_us_per_request,
        wall_s=wall,
    )


def run_specs(
    specs: Sequence[ExperimentSpec], jobs: int = 1
) -> list[ExperimentResult]:
    """Run a grid; results come back in spec order.

    ``jobs > 1`` fans cells out over a process pool (each cell regenerates
    its own request set, so there is nothing to share); ``jobs <= 0`` means
    one process per CPU.
    """
    specs = list(specs)
    if jobs <= 0:
        jobs = os.cpu_count() or 1
    if jobs == 1 or len(specs) <= 1:
        return [run_spec(s) for s in specs]
    chunk = max(1, len(specs) // (4 * jobs))
    # Spawn, not fork: the host process may have JAX's threads running
    # (e.g. under pytest after real-engine tests), and forking a
    # multithreaded process can deadlock.  Workers only import numpy-level
    # code, so the spawn import cost is small and paid once per worker.
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as pool:
        return list(pool.map(run_spec, specs, chunksize=chunk))


def write_artifact(
    path: str,
    results: Iterable[ExperimentResult],
    grid: str = "",
    claims: Sequence | None = None,
) -> dict:
    """Write the trajectory artifact (atomically) and return the document."""
    results = list(results)
    doc: dict = {
        "schema": 1,
        "grid": grid,
        "n_results": len(results),
        "results": [r.to_dict() for r in results],
    }
    if claims is not None:
        doc["claims"] = [c.to_dict() for c in claims]
        doc["passed"] = all(c.passed for c in claims)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return doc


def read_artifact(path: str) -> tuple[dict, list[ExperimentResult]]:
    with open(path) as f:
        doc = json.load(f)
    return doc, [ExperimentResult.from_dict(d) for d in doc["results"]]
