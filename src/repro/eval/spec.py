"""Typed experiment grid cells: :class:`ExperimentSpec` in,
:class:`ExperimentResult` out.

A spec is the complete, JSON-serializable recipe for one run:
workload family + params, SLO scale, offered utilization, trace seed,
compared system, pool shape, execution substrate, and the knobs the
sensitivity/ablation studies sweep.  Everything a worker process needs to
regenerate the seeded request set and replay it — no shared state, so a
grid of specs fans out across processes trivially.

**Grid-cell lifecycle** (the contract every module in ``repro.eval``
implements one stage of):

1. a grid constructor (:mod:`repro.eval.grid`) builds a list of specs;
2. the runner (:mod:`repro.eval.runner`) regenerates each spec's *seeded*
   :class:`~repro.serving.trace.RequestSet` — bit-for-bit reproducible
   from ``(workload, workload_params, slo_scale, utilization, n_requests,
   seed)`` — and replays it through the unified event loop on the spec's
   ``substrate``;
3. the replay folds into an :class:`ExperimentResult` (same schema for
   both substrates);
4. the claims layer (:mod:`repro.eval.claims`) aggregates results into
   paper-claim verdicts, and ``repro.eval.run`` persists everything as
   ``BENCH_eval.json``.

``substrate`` selects the execution layer under the replay: ``"sim"``
(default) uses the Eq.-3 :class:`~repro.core.eventloop.ModelExecutor`;
``"engine"`` (optionally ``"engine:<model>"``, see
:mod:`repro.eval.substrate`) drives the real JAX
:class:`~repro.serving.engine.ServingEngine` with measured batch times.

Results split into *outcome* fields (deterministic given the spec on the
``sim`` substrate — finish counts, utilization, latency quantiles) and
*timing* fields (measured wall-clock — scheduler decision time, run wall
time).  Determinism comparisons go through
:meth:`ExperimentResult.stable_dict`, which drops the timing fields.  On
the ``engine`` substrate the outcome fields are real measurements and
therefore machine-dependent; engine provenance (profiled constants,
predicted-vs-measured drift, the finish set) travels in
``substrate_meta``, which is likewise excluded from stable comparisons.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

__all__ = ["ExperimentSpec", "ExperimentResult", "TIMING_FIELDS"]


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One grid cell.  ``workload_params`` / ``sched_cfg`` are plain JSON
    objects (lists instead of tuples) so a spec round-trips losslessly."""

    workload: str  # family key in repro.eval.workloads.FAMILIES
    slo_scale: float
    workload_params: dict = dataclasses.field(default_factory=dict)
    utilization: float = 0.85
    n_requests: int = 300
    seed: int = 0
    system: str = "orloj"  # "orloj" or a repro.core.baselines.BASELINES key
    # Execution layer: "sim" replays against the Eq.-3 ModelExecutor;
    # "engine" (or "engine:<registry model>") drives the real JAX
    # ServingEngine with measured batch times (repro.eval.substrate).
    substrate: str = "sim"
    n_workers: int = 1
    policy: str = "round_robin"  # front-end dispatch for n_workers > 1
    hetero: bool = False  # half the pool runs a 2x-slower latency model
    # Event-loop implementation (repro.core.eventloop.ENGINES): "scalar"
    # is the oracle heapq loop, "array" the RequestStore/EventWheel engine
    # (bit-identical observable behaviour, built for 10^5+ requests).
    engine: str = "scalar"
    # Fleet mode: n_pools > 1 partitions the pool into contiguous pools
    # and dispatches hierarchically — ``policy`` becomes the inter-pool
    # (front-end) policy, ``intra_policy`` places within the winning pool
    # (serving.cluster.hierarchical_policy).
    n_pools: int = 1
    intra_policy: str = "round_robin"
    # Arrival quantization tick (TraceConfig.tick_ms); 0 = raw timestamps.
    tick_ms: float = 0.0
    # Wall-clock budget (s) for this cell; 0 = unbudgeted.  Budgeted cells
    # feed the cluster-wall-budget claim: the replay (wall_s) must finish
    # inside the budget, which is what gates the fleet-scale grids.  An
    # overrun is graceful: the event loop cuts the replay off and the
    # result comes back ``truncated`` with partial stats (everything
    # unresolved counted unserved) instead of hanging the grid.
    wall_budget_s: float = 0.0
    # Fault plan for this cell as a plain JSON object (the kwargs of
    # :class:`repro.serving.faults.FaultPlan`).  Empty dict = no plan at
    # all; a populated dict with every knob off is a *disabled* plan that
    # still threads through the engine hooks (the fault-free-noop claim's
    # domain).  DESIGN.md §11.
    faults: dict = dataclasses.field(default_factory=dict)
    # Multi-model serving (DESIGN.md §13): n_models > 1 assigns each
    # request a zoo model (Zipf-skewed by model_skew) and threads a
    # weights-residency cache of ``worker_mem`` bytes through the event
    # loop — cold batches stall for the PCIe load before executing.
    # Defaults keep the tier fully inert: n_models=1 cells are bitwise
    # identical to pre-multi-model cells (the single-model-noop claim).
    n_models: int = 1
    model_skew: float = 1.1
    worker_mem: float = 0.0  # bytes; 0 with n_models=1 means "no cache"
    residency_policy: str = "lru"  # eviction: "lru" or "cost_aware"
    sched_cfg: dict = dataclasses.field(default_factory=dict)  # orloj only
    lm_c0: float = 25.0  # Eq.-3 batch latency model of the serving hardware
    lm_c1: float = 1.0
    time_scale: float = 1.0  # Fig. 14: shrink every alone-time uniformly
    charge_overhead: bool = False  # bill decision time to the virtual clock
    # Event-loop RNG seed (dispatch-policy tie-breaks/sampling).  None means
    # "follow the trace seed"; the legacy cluster sweeps pin it separately.
    loop_seed: int | None = None
    tag: str = ""  # display label used by the legacy CSV formatters

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


# Fields of ExperimentResult that carry measured wall-clock (or, for
# ``substrate_meta``, profiled hardware constants and measured drift) and
# therefore legitimately differ between two runs of the same spec.
TIMING_FIELDS = frozenset(
    {"sched_time_ms", "sched_us_per_request", "wall_s", "substrate_meta"}
)


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    spec: ExperimentSpec
    # -- outcome (deterministic given the spec) -----------------------------
    finish_rate: float
    n_total: int
    n_finished_ok: int
    n_finished_late: int
    n_dropped: int
    n_unserved: int
    utilization: float
    makespan_ms: float
    p99_alone_ms: float  # P99 of the set's alone-times (the SLO anchor)
    latency_p50_ms: float
    latency_p99_ms: float
    n_decisions: int
    # -- timing (machine-dependent) -----------------------------------------
    sched_time_ms: float
    sched_us_per_request: float
    wall_s: float
    # -- fault-tier terminal states (outcome fields; zero when no plan;
    # defaulted so pre-fault artifacts still parse — DESIGN.md §11) --------
    n_rejected: int = 0
    n_failed: int = 0
    n_retried: int = 0
    # True when the replay was cut off at ``spec.wall_budget_s`` — partial
    # outcome fields; ordering claims exclude truncated cells.
    truncated: bool = False
    # Token-mode outcome fields (DESIGN.md §12; zero for atomic-batch
    # cells, defaulted so pre-token artifacts still parse).  TTFT is
    # first-token-time minus release; TPOT the per-token rate of the
    # remaining decode (finish − first_token)/(tokens − 1), both over
    # finished requests only.
    ttft_p50_ms: float = 0.0
    ttft_p99_ms: float = 0.0
    tpot_p50_ms: float = 0.0
    tpot_p99_ms: float = 0.0
    n_tokens_out: int = 0
    # Multi-model residency counters (DESIGN.md §13; zero for
    # single-model cells, defaulted so pre-multi-model artifacts still
    # parse).  model_load_ms is virtual stall time — deterministic given
    # the spec, so it stays in stable_dict.
    n_model_loads: int = 0
    n_model_evicts: int = 0
    model_load_ms: float = 0.0
    # Engine-substrate provenance (empty for sim cells): registry model,
    # profiled Eq.-3 constants, predicted-vs-measured batch-time drift, the
    # sim-twin comparison and the finish set (repro.eval.substrate).
    substrate_meta: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["spec"] = self.spec.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentResult":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known and k != "spec"}
        return cls(spec=ExperimentSpec.from_dict(d["spec"]), **kw)

    def stable_dict(self) -> dict[str, Any]:
        """Everything two runs of the same spec must agree on bit-for-bit
        (serial vs parallel execution included)."""
        d = self.to_dict()
        for k in TIMING_FIELDS:
            d.pop(k, None)
        return d
