"""``repro.eval`` — the typed evaluation subsystem (§5 methodology as code).

Promotes the print-CSV benchmarks into a structured pipeline around one
grid-cell lifecycle — spec → seeded RequestSet → result → claim (see
:mod:`repro.eval.spec` for the stage-by-stage contract):

- :mod:`repro.eval.spec` — :class:`ExperimentSpec` (one grid cell: workload
  family, SLO scale, utilization, seed, system, pool shape, substrate) and
  :class:`ExperimentResult`, both JSON round-trippable;
- :mod:`repro.eval.workloads` — JSON-addressable workload families;
- :mod:`repro.eval.grid` — the conformance grids (``tiny``/``small``/
  ``full``/``engine-smoke``) plus spec constructors for every legacy
  benchmark table;
- :mod:`repro.eval.runner` — seeded per-cell replay, process fan-out,
  the ``BENCH_eval.json`` artifact;
- :mod:`repro.eval.substrate` — the real-engine tier: ``substrate="engine"``
  cells served by the actual JAX model with measured batch times, plus the
  sim-vs-engine drift report (DESIGN.md §8);
- :mod:`repro.eval.claims` — the paper-claims conformance gate;
- :mod:`repro.eval.sched_gate` — the ``BENCH_sched.json`` CI ratio check;
- :mod:`repro.eval.run` — ``python -m repro.eval.run --grid small``.
"""

from .claims import (
    MONO_SLACK,
    SCALEOUT_SLACK,
    STATIC_NOISE_BAND,
    TIGHT_SLO_MAX,
    ClaimResult,
    evaluate_claims,
    format_report,
)
from .grid import GRIDS, SYSTEMS
from .runner import (
    DEFAULT_ARTIFACT,
    read_artifact,
    run_spec,
    run_specs,
    write_artifact,
)
from .spec import TIMING_FIELDS, ExperimentResult, ExperimentSpec
from .substrate import ENGINE_MODELS, engine_available, parse_substrate
from .workloads import FAMILIES, build_workload

__all__ = [
    "MONO_SLACK",
    "SCALEOUT_SLACK",
    "STATIC_NOISE_BAND",
    "TIGHT_SLO_MAX",
    "ClaimResult",
    "evaluate_claims",
    "format_report",
    "GRIDS",
    "SYSTEMS",
    "DEFAULT_ARTIFACT",
    "read_artifact",
    "run_spec",
    "run_specs",
    "write_artifact",
    "TIMING_FIELDS",
    "ExperimentResult",
    "ExperimentSpec",
    "ENGINE_MODELS",
    "engine_available",
    "parse_substrate",
    "FAMILIES",
    "build_workload",
]
