"""Grid runner CLI: run a conformance grid, write ``BENCH_eval.json``,
and gate on the paper's qualitative claims.

    PYTHONPATH=src python -m repro.eval.run --grid small|full|engine-smoke
        [--jobs N] [--out BENCH_eval.json] [--no-gate] [--verbose]

Exit status is 0 iff every conformance claim passed, with two exceptions:
``--no-gate`` always exits 0, and *ungated* grids (``engine-smoke``) are
tracked rather than failed — their claim verdicts and the sim-vs-engine
``engine_drift`` section are recorded in the artifact, but real-substrate
finish rates are measurements and CI-runner timing variance is not yet
characterized (DESIGN.md §8).
"""

from __future__ import annotations

import argparse
import sys
import time

from .claims import evaluate_claims, format_report
from .grid import GRIDS
from .runner import DEFAULT_ARTIFACT, run_specs, write_artifact
from .substrate import drift_report

# Grids whose claim verdicts are recorded but never fail the exit status.
UNGATED_GRIDS = frozenset({"engine-smoke"})


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="small", choices=sorted(GRIDS))
    ap.add_argument(
        "--jobs",
        type=int,
        default=0,
        help="worker processes (0 = one per CPU, 1 = serial); engine cells "
        "always run serially in the host process",
    )
    ap.add_argument("--out", default=DEFAULT_ARTIFACT)
    ap.add_argument(
        "--no-gate",
        action="store_true",
        help="record claim verdicts in the artifact but always exit 0",
    )
    ap.add_argument(
        "--verbose", action="store_true", help="print per-cell claim evidence"
    )
    args = ap.parse_args(argv)

    specs = GRIDS[args.grid]()
    t0 = time.time()  # simlint: ignore[R1] -- CLI progress banner, reporting only
    print(f"# grid {args.grid}: {len(specs)} cells, jobs={args.jobs or 'auto'}",
          file=sys.stderr, flush=True)
    results = run_specs(specs, jobs=args.jobs)
    claims = evaluate_claims(results)
    drift = drift_report(results)
    extra = {"engine_drift": drift} if drift else None
    write_artifact(args.out, results, grid=args.grid, claims=claims, extra=extra)
    # simlint: ignore[R1] -- CLI progress banner, reporting only
    print(f"# {len(results)} results -> {args.out} ({time.time() - t0:.1f}s)",
          file=sys.stderr)
    print(format_report(claims, verbose=args.verbose))
    if drift:
        print(
            f"engine drift: {drift['n_cells']} cells, "
            f"|finish-rate drift| mean {drift['mean_abs_finish_rate_drift']:.3f} "
            f"max {drift['max_abs_finish_rate_drift']:.3f}, "
            f"batch-time MAPE {drift['mean_batch_mape']:.3f}"
        )
    if args.no_gate:
        return 0
    if args.grid in UNGATED_GRIDS:
        print(f"# grid {args.grid!r} is tracked, not gated (DESIGN.md §8)",
              file=sys.stderr)
        return 0
    return 0 if all(c.passed for c in claims) else 1


if __name__ == "__main__":
    raise SystemExit(main())
