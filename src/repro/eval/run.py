"""Grid runner CLI: run a conformance grid, write ``BENCH_eval.json``,
and gate on the paper's qualitative claims.

    PYTHONPATH=src python -m repro.eval.run --grid small [--jobs N]
        [--out BENCH_eval.json] [--no-gate] [--verbose]

Exit status is 0 iff every conformance claim passed (or ``--no-gate``).
"""

from __future__ import annotations

import argparse
import sys
import time

from .claims import evaluate_claims, format_report
from .grid import GRIDS
from .runner import DEFAULT_ARTIFACT, run_specs, write_artifact


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", default="small", choices=sorted(GRIDS))
    ap.add_argument(
        "--jobs",
        type=int,
        default=0,
        help="worker processes (0 = one per CPU, 1 = serial)",
    )
    ap.add_argument("--out", default=DEFAULT_ARTIFACT)
    ap.add_argument(
        "--no-gate",
        action="store_true",
        help="record claim verdicts in the artifact but always exit 0",
    )
    ap.add_argument(
        "--verbose", action="store_true", help="print per-cell claim evidence"
    )
    args = ap.parse_args(argv)

    specs = GRIDS[args.grid]()
    t0 = time.time()
    print(f"# grid {args.grid}: {len(specs)} cells, jobs={args.jobs or 'auto'}",
          file=sys.stderr, flush=True)
    results = run_specs(specs, jobs=args.jobs)
    claims = evaluate_claims(results)
    write_artifact(args.out, results, grid=args.grid, claims=claims)
    print(f"# {len(results)} results -> {args.out} ({time.time() - t0:.1f}s)",
          file=sys.stderr)
    print(format_report(claims, verbose=args.verbose))
    if args.no_gate:
        return 0
    return 0 if all(c.passed for c in claims) else 1


if __name__ == "__main__":
    raise SystemExit(main())
