"""JSON-addressable workload families for the experiment grid.

An :class:`~repro.eval.spec.ExperimentSpec` names its workload as
``(family, params)`` where ``params`` is a plain JSON object — so a grid
cell can be serialized, shipped to another process, and regenerated there
bit-for-bit.  Each family maps onto one of the §5 synthesis helpers in
:mod:`repro.serving.workload`.
"""

from __future__ import annotations

from typing import Callable, Mapping

import numpy as np

from ..serving.workload import (
    AppWorkload,
    bimodal,
    k_modal,
    real_task,
    static,
    unequal_bimodal,
)

__all__ = ["FAMILIES", "build_workload"]


def _bimodal(params: Mapping) -> list[AppWorkload]:
    std = params.get("std", 1.0)
    if isinstance(std, (list, tuple)):  # JSON carries tuples as lists
        std = tuple(float(s) for s in std)
    return bimodal(std)


def _unequal_bimodal(params: Mapping) -> list[AppWorkload]:
    return unequal_bimodal(params.get("more", "short"), params.get("std", 1.0))


def _k_modal(params: Mapping) -> list[AppWorkload]:
    return k_modal(
        int(params["k"]),
        std=params.get("std", 1.0),
        lo=params.get("lo", 30.0),
        hi=params.get("hi", 200.0),
    )


def _static(params: Mapping) -> list[AppWorkload]:
    return static(params.get("mean", 10.0), params.get("jitter", 0.02))


def _real(params: Mapping) -> list[AppWorkload]:
    return real_task(params["name"])


def _tokens(params: Mapping) -> list[AppWorkload]:
    """Token-mode family (DESIGN.md §12): the samplers draw *output
    lengths in tokens* (geometric, the memoryless EOS model), not
    alone-times in ms — :func:`repro.serving.trace.generate_token_requests`
    interprets them accordingly.  Bimodal by default: a short-form app
    (chat-style) and a long-form app (summarization-style)."""

    def geometric(mean: float) -> Callable[[np.random.Generator, int], np.ndarray]:
        p = 1.0 / max(mean, 1.0)

        def f(rng: np.random.Generator, n: int) -> np.ndarray:
            return rng.geometric(p, size=n).astype(np.float64)

        return f

    w_short = float(params.get("short_weight", 0.5))
    return [
        AppWorkload("short", geometric(float(params.get("short_mean", 8.0))), w_short),
        AppWorkload(
            "long", geometric(float(params.get("long_mean", 64.0))), 1.0 - w_short
        ),
    ]


FAMILIES: dict[str, Callable[[Mapping], list[AppWorkload]]] = {
    "bimodal": _bimodal,
    "unequal_bimodal": _unequal_bimodal,
    "k_modal": _k_modal,
    "static": _static,
    "real": _real,
    "tokens": _tokens,
}

# Families with data-dependent execution-time variance — the regime where
# the paper claims dominance under tight SLOs; ``static`` is the
# no-variance control where parity is the claim (Tables 2–5).  ``tokens``
# is deliberately absent: token cells compare token schedulers against
# each other (claim ``token-length-awareness``), never against the
# atomic-batch systems the paper orderings are about.
DYNAMIC_FAMILIES = frozenset({"bimodal", "unequal_bimodal", "k_modal", "real"})


def _scaled_app(app: AppWorkload, scale: float) -> AppWorkload:
    sampler = app.sampler

    def f(rng: np.random.Generator, n: int) -> np.ndarray:
        return sampler(rng, n) * scale

    return type(app)(app.app_id, f, app.weight)


def build_workload(
    family: str, params: Mapping, time_scale: float = 1.0
) -> list[AppWorkload]:
    """Materialize the per-app samplers for a spec's ``(family, params)``.

    ``time_scale`` multiplies every sampled alone-time — the Fig.-14
    shrinking-execution-time study, applied uniformly so the workload's
    *shape* is preserved.
    """
    try:
        apps = FAMILIES[family](params)
    except KeyError:
        raise ValueError(
            f"unknown workload family {family!r}; known: {sorted(FAMILIES)}"
        ) from None
    if time_scale != 1.0:
        apps = [_scaled_app(a, time_scale) for a in apps]
    return apps
