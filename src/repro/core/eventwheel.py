"""Bucketed calendar-queue event wheel (the dynamic half of the array
engine's event sourcing; DESIGN.md §10).

The array-backed event loop splits the classic event heap in two:

- the *static* half — every ARRIVAL is known up front, so arrivals live
  in the :class:`~repro.core.requeststore.RequestStore` as sorted numpy
  columns with precomputed same-timestamp group boundaries and never
  touch a priority queue at all;
- the *dynamic* half — DONE/WAKE events created while the simulation
  runs.  That is this module.  At any instant the loop holds at most a
  couple of live events per worker (one in-flight batch, one live wake,
  plus superseded wakes waiting to fire as no-ops), so the wheel is
  engineered for *cheap steady-state churn*, not capacity.

Design (a classic calendar queue, Brown 1988, adapted):

- events hash into fixed-width time buckets ``floor(t / bucket_ms)``;
  buckets are a sparse ``dict`` keyed by integer bucket index, plus a
  lazy min-heap of nonempty bucket indices (a popped index may be stale
  — re-checked against the dict, exactly like tombstoned heap entries);
- :meth:`pop_bucket` drains one whole bucket at a time, sorted by
  ``(time, seq)`` — the pop-all-events-in-a-bucket operation the array
  loop's batched DONE/WAKE processing is built on;
- total order across buckets and within a bucket is identical to a
  ``heapq`` over ``(time, seq)`` tuples (property-tested, including
  same-timestamp coalescing and bucket-boundary edges);
- **heapq fallback for pathological spreads**: an event whose timestamp
  cannot be bucketed meaningfully — non-finite, or so far from the
  current window that its bucket index overflows :data:`MAX_BUCKET_SPAN`
  buckets — goes to an overflow heap that is merged back in timestamp
  order on pop.  A wheel constructed with ``bucket_ms=None`` degenerates
  entirely to that heap (used when the caller has no spread estimate).

``seq`` is the caller-supplied tiebreaker: the array loop numbers
arrivals ``0..n-1`` at build time and keeps counting for DONE/WAKE
pushes, so at equal timestamps arrivals always precede the dynamic
events pushed later — the same total order the scalar loop's
``(time, seq, kind, payload)`` heap produces.
"""

from __future__ import annotations

import heapq
import math
from typing import Any, Iterator

__all__ = ["EventWheel", "MAX_BUCKET_SPAN"]

# An event farther than this many buckets from the current cursor is
# "pathologically spread" and goes to the overflow heap instead of a
# dict entry (keeps the bucket-index heap small when a trace mixes
# ms-scale churn with, say, an hours-away timeout).
MAX_BUCKET_SPAN = 1 << 20

_Event = tuple[float, int, int, Any]  # (time, seq, kind, payload)


class EventWheel:
    """Calendar queue over ``(time, seq, kind, payload)`` events.

    ``bucket_ms`` is the bucket width; ``None`` means pure-heapq mode.
    Pops must be non-decreasing in time (discrete-event contract); pushes
    may land in the current bucket at or after the last popped time —
    pushing strictly *before* the last pop is a caller bug and raises.
    """

    __slots__ = ("bucket_ms", "_buckets", "_bucket_heap", "_overflow",
                 "_cursor", "_last_time", "_n")

    def __init__(self, bucket_ms: float | None = None) -> None:
        if bucket_ms is not None and not (bucket_ms > 0.0):
            raise ValueError(f"bucket_ms must be positive, got {bucket_ms}")
        self.bucket_ms = bucket_ms
        self._buckets: dict[int, list[_Event]] = {}
        self._bucket_heap: list[int] = []  # lazy: may hold stale indices
        self._overflow: list[_Event] = []  # heapq fallback
        self._cursor = 0  # bucket index of the last pop (window anchor)
        self._last_time = -math.inf
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    # ------------------------------------------------------------- push
    def push(self, time: float, seq: int, kind: int, payload: Any) -> None:
        if time < self._last_time:
            raise ValueError(
                f"event at t={time} pushed before the wheel's last pop "
                f"t={self._last_time} (discrete-event order violated)"
            )
        ev = (time, seq, kind, payload)
        self._n += 1
        if self.bucket_ms is not None and math.isfinite(time):
            idx = int(time // self.bucket_ms)
            if abs(idx - self._cursor) <= MAX_BUCKET_SPAN:
                got = self._buckets.get(idx)
                if got is None:
                    self._buckets[idx] = [ev]
                    heapq.heappush(self._bucket_heap, idx)
                else:
                    got.append(ev)
                return
        heapq.heappush(self._overflow, ev)  # pathological spread / no width

    # ------------------------------------------------------------- peek
    def _min_bucket(self) -> int | None:
        """Smallest nonempty bucket index (drops stale heap entries)."""
        heap = self._bucket_heap
        while heap:
            idx = heap[0]
            if idx in self._buckets:
                return idx
            heapq.heappop(heap)  # stale: bucket already drained
        return None

    def peek_time(self) -> float:
        """Earliest event timestamp (``inf`` when empty)."""
        return self.peek_key()[0]

    def peek_key(self) -> tuple[float, int]:
        """``(time, seq)`` of the earliest event (``(inf, -1)`` when empty).

        The caller's merge key: the array loop compares this against the
        head of its in-hand bucket batch and against the next arrival
        group to keep the global ``(time, seq)`` order while events pushed
        *during* a batch land back in the wheel."""
        best: _Event | None = None
        idx = self._min_bucket()
        if idx is not None:
            # seqs are unique, so min() never compares beyond (time, seq)
            best = min(self._buckets[idx])
        if self._overflow:
            o = self._overflow[0]
            if best is None or o < best:
                best = o
        if best is None:
            return (math.inf, -1)
        return (best[0], best[1])

    # -------------------------------------------------------------- pop
    def pop_bucket(self) -> list[_Event]:
        """Drain the earliest nonempty bucket, sorted by ``(time, seq)``.

        The returned batch is exactly the events of one calendar bucket
        (overflow events that fall inside that bucket's window included),
        so the caller amortizes its per-event bookkeeping over the whole
        bucket.  Raises ``IndexError`` when empty.

        ``_last_time`` advances to the *first* event of the batch, not the
        last: while the caller works through the batch its handlers may
        push fresh events timestamped between the remaining batch entries
        (a DONE handler arming a WAKE inside the same bucket window) —
        those re-enter the wheel, recreate the drained bucket index if
        needed, and surface through :meth:`peek_key` so the caller's merge
        keeps the global order.
        """
        if self._n == 0:
            raise IndexError("pop from an empty EventWheel")
        idx = self._min_bucket()
        batch: list[_Event]
        if idx is None:
            # heap-only mode (or everything in overflow): one timestamp's
            # worth of events forms the "bucket".
            batch = [heapq.heappop(self._overflow)]
            t0 = batch[0][0]
            while self._overflow and self._overflow[0][0] == t0:
                batch.append(heapq.heappop(self._overflow))
        else:
            batch = self._buckets.pop(idx)
            heapq.heappop(self._bucket_heap)  # idx is the live minimum
            # merge overflow events that belong to this bucket's window
            assert self.bucket_ms is not None
            end = (idx + 1) * self.bucket_ms
            while self._overflow and self._overflow[0][0] < end:
                batch.append(heapq.heappop(self._overflow))
            batch.sort()
            self._cursor = idx
        self._n -= len(batch)
        self._last_time = batch[0][0]
        return batch

    def pop(self) -> _Event:
        """Pop the single earliest event — total order ≡ ``heapq`` over
        ``(time, seq)``.  Implemented as a tiny front-buffer over
        :meth:`pop_bucket`-style draining so mixed pop/pop_bucket use is
        still globally ordered."""
        if self._n == 0:
            raise IndexError("pop from an empty EventWheel")
        idx = self._min_bucket()
        if idx is not None:
            bucket = self._buckets[idx]
            ev = min(bucket)
            if self._overflow and self._overflow[0] < ev:
                ev = heapq.heappop(self._overflow)
            else:
                bucket.remove(ev)
                if not bucket:
                    del self._buckets[idx]
                else:
                    self._cursor = idx
        else:
            ev = heapq.heappop(self._overflow)
        self._n -= 1
        self._last_time = ev[0]
        return ev

    def drain(self) -> Iterator[_Event]:
        """Pop everything in order (test/debug helper)."""
        while self._n:
            yield from self.pop_bucket()
