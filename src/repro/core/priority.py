"""Time-varying batch-aware priority score (paper §4.1, Eq. 2; §4.4).

For a request with deadline ``D``, miss cost ``c`` and (batch) execution-time
histogram bins ``[l1, l2)`` with frequency ``h``, the per-bin score is

             ⎧ (hc / (E[L] b)) (e^{b l2} − e^{b l1}) e^{−bD} e^{bt}   t < D − l2
    p_i(t) = ⎨ hc/(E[L] b) − (hc/(E[L] b)) e^{b l1} e^{−bD} e^{bt}   D−l2 ≤ t < D−l1
             ⎩ 0                                                     D−l1 ≤ t

so every bin (and hence the request) is of the form ``p(t) = α e^{bt} + β``
(§4.4), with regime changes ("milestones") at ``D − l2`` and ``D − l1``.

Overflow handling (§4.4): ``D`` and ``t`` are measured relative to a sliding
*base time*.  With millisecond resolution and ``b = 1e-4`` the exponentials
stay in float64 range for ~1000 s of scheduling before the base must be
reset (and all scores recomputed — Algorithm 1 lines 2–4).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .distributions import EmpiricalDistribution
from .request import PiecewiseStepCost, Request

__all__ = ["BinScoreModel", "Score", "DEFAULT_B", "RESET_EXPONENT"]

DEFAULT_B = 1e-4  # per millisecond, paper §4.4 / §5.6
# Reset the base time when b·(t − base) exceeds this (e^60 ≈ 1e26; products
# of two such terms stay well inside float64 range ~1e308).
RESET_EXPONENT = 60.0


@dataclasses.dataclass(frozen=True)
class Score:
    """A request's priority at some instant: ``p(t) = α e^{b(t−base)} + β``.

    ``milestone`` is the next absolute time at which (α, β) change.
    """

    alpha: float
    beta: float
    milestone: float

    def value(self, t: float, base: float, b: float) -> float:
        return self.alpha * np.exp(b * (t - base)) + self.beta


class BinScoreModel:
    """Priority computation for one batch-execution-time histogram.

    One instance exists per (model, batch size): the histogram is the
    distribution of ``L_B`` for that batch size derived from the mixture of
    all app distributions (§4.3), so it is shared by all requests and can be
    precomputed off the critical path.
    """

    def __init__(self, batch_dist: EmpiricalDistribution, b: float = DEFAULT_B):
        self.b = float(b)
        self.l1 = batch_dist.edges[:-1].copy()
        self.l2 = batch_dist.edges[1:].copy()
        self.h = batch_dist.probs.copy()
        self.e_l = batch_dist.mean()
        if self.e_l <= 0:
            raise ValueError("batch execution time must have positive mean")
        # Precompute bin exponentials: e^{b l1}, e^{b l2} (l in ms; b·l ≪ 1
        # for realistic latencies so these never overflow).
        self._ebl1 = np.exp(self.b * self.l1)
        self._ebl2 = np.exp(self.b * self.l2)
        self._k = 1.0 / (self.e_l * self.b)  # hc/(E[L] b) sans h·c

    # ------------------------------------------------------------------
    def _score_single_step(
        self, deadline: float, cost: float, t: float, base: float
    ) -> tuple[float, float, float]:
        """(α, β, next_milestone) for a single-step cost at time ``t``."""
        d_rel = deadline - base
        ebD = np.exp(-self.b * d_rel)
        coef = self._k * cost * self.h  # hc/(E[L] b) per bin

        m_hi = deadline - self.l2  # regime A→B milestones (absolute)
        m_lo = deadline - self.l1  # regime B→C milestones (absolute)

        in_a = t < m_hi
        in_b = (~in_a) & (t < m_lo)

        alpha = float(
            np.sum(np.where(in_a, coef * (self._ebl2 - self._ebl1) * ebD, 0.0))
            + np.sum(np.where(in_b, -coef * self._ebl1 * ebD, 0.0))
        )
        beta = float(np.sum(np.where(in_b, coef, 0.0)))

        future = np.concatenate([m_hi[m_hi > t], m_lo[m_lo > t]])
        milestone = float(future.min()) if future.size else np.inf
        return alpha, beta, milestone

    def score(self, req: Request, t: float, base: float) -> Score:
        """Priority of ``req`` at time ``t`` (supports piecewise-step costs
        via the Appendix-B decomposition)."""
        cost_fn = req.cost_fn()
        steps = cost_fn.steps() if isinstance(cost_fn, PiecewiseStepCost) else [cost_fn]
        alpha = beta = 0.0
        milestone = np.inf
        for step in steps:
            a, b_, m = self._score_single_step(step.deadline, step.cost, t, base)
            alpha += a
            beta += b_
            milestone = min(milestone, m)
        return Score(alpha, beta, milestone)

    def value(self, req: Request, t: float, base: float) -> float:
        """Direct evaluation of p(t) — used by tests as the oracle."""
        s = self.score(req, t, base)
        return s.value(t, base, self.b)

    def value_reference(self, req: Request, t: float, base: float) -> float:
        """Literal Eq. 2 evaluation, bin by bin, no (α, β) folding."""
        cost_fn = req.cost_fn()
        steps = (
            cost_fn.steps() if isinstance(cost_fn, PiecewiseStepCost) else [cost_fn]
        )
        total = 0.0
        for step in steps:
            d_rel = step.deadline - base
            t_rel = t - base
            for l1, l2, h in zip(self.l1, self.l2, self.h):
                k = h * step.cost / (self.e_l * self.b)
                if t_rel < d_rel - l2:
                    total += (
                        k
                        * (np.exp(self.b * l2) - np.exp(self.b * l1))
                        * np.exp(-self.b * d_rel)
                        * np.exp(self.b * t_rel)
                    )
                elif t_rel < d_rel - l1:
                    total += k - k * np.exp(self.b * l1) * np.exp(
                        -self.b * d_rel
                    ) * np.exp(self.b * t_rel)
        return float(total)
