"""Time-varying batch-aware priority score (paper §4.1, Eq. 2; §4.4).

For a request with deadline ``D``, miss cost ``c`` and (batch) execution-time
histogram bins ``[l1, l2)`` with frequency ``h``, the per-bin score is

             ⎧ (hc / (E[L] b)) (e^{b l2} − e^{b l1}) e^{−bD} e^{bt}   t < D − l2
    p_i(t) = ⎨ hc/(E[L] b) − (hc/(E[L] b)) e^{b l1} e^{−bD} e^{bt}   D−l2 ≤ t < D−l1
             ⎩ 0                                                     D−l1 ≤ t

so every bin (and hence the request) is of the form ``p(t) = α e^{bt} + β``
(§4.4), with regime changes ("milestones") at ``D − l2`` and ``D − l1``.

Overflow handling (§4.4): ``D`` and ``t`` are measured relative to a sliding
*base time*.  With millisecond resolution and ``b = 1e-4`` the exponentials
stay in float64 range for ~1000 s of scheduling before the base must be
reset (and all scores recomputed — Algorithm 1 lines 2–4).

Hot path (DESIGN.md §Hot-path): the bin edges are sorted, so the three
regimes partition the bins into a prefix (A: ``l2 < D − t``), a middle run
(B: ``l1 < D − t ≤ l2``) and a suffix (C).  With per-bin prefix cumulative
sums precomputed in :class:`BinScoreModel`, one score is two
``searchsorted`` lookups plus O(1) arithmetic, and :meth:`score_many`
evaluates N (deadline, cost) steps in a single vectorized pass.  The
scalar :meth:`score` is a thin wrapper over the same code path, so the two
agree bit for bit; :meth:`value_reference` remains the literal-Eq.-2 test
oracle.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .distributions import EmpiricalDistribution
from .request import PiecewiseStepCost, Request

__all__ = [
    "BinScoreModel",
    "Score",
    "DEFAULT_B",
    "RESET_EXPONENT",
    "aggregate_steps",
]

DEFAULT_B = 1e-4  # per millisecond, paper §4.4 / §5.6
# Reset the base time when b·(t − base) exceeds this (e^60 ≈ 1e26; products
# of two such terms stay well inside float64 range ~1e308).
RESET_EXPONENT = 60.0


@dataclasses.dataclass(frozen=True)
class Score:
    """A request's priority at some instant: ``p(t) = α e^{b(t−base)} + β``.

    ``milestone`` is the next absolute time at which (α, β) change.
    """

    alpha: float
    beta: float
    milestone: float

    def value(self, t: float, base: float, b: float) -> float:
        return self.alpha * math.exp(b * (t - base)) + self.beta


class BinScoreModel:
    """Priority computation for one batch-execution-time histogram.

    One instance exists per (model, batch size): the histogram is the
    distribution of ``L_B`` for that batch size derived from the mixture of
    all app distributions (§4.3), so it is shared by all requests and can be
    precomputed off the critical path.
    """

    def __init__(self, batch_dist: EmpiricalDistribution, b: float = DEFAULT_B):
        self.b = float(b)
        self.l1 = batch_dist.edges[:-1].copy()
        self.l2 = batch_dist.edges[1:].copy()
        self.h = batch_dist.probs.copy()
        self.e_l = batch_dist.mean()
        if self.e_l <= 0:
            raise ValueError("batch execution time must have positive mean")
        # Precompute bin exponentials: e^{b l1}, e^{b l2} (l in ms; b·l ≪ 1
        # for realistic latencies so these never overflow).
        self._ebl1 = np.exp(self.b * self.l1)
        self._ebl2 = np.exp(self.b * self.l2)
        self._k = 1.0 / (self.e_l * self.b)  # hc/(E[L] b) sans h·c
        # Prefix cumulative sums over the sorted bins (leading 0 so that
        # P[j] − P[i] sums bins [i, j)): with them a score is two
        # searchsorted lookups plus O(1) arithmetic instead of an O(bins)
        # masked reduction (DESIGN.md §Hot-path).
        self._p_gap = np.concatenate(
            [[0.0], np.cumsum(self.h * (self._ebl2 - self._ebl1))]
        )
        self._p_el1 = np.concatenate([[0.0], np.cumsum(self.h * self._ebl1)])
        self._p_h = np.concatenate([[0.0], np.cumsum(self.h)])

    # ------------------------------------------------------------------
    def score_many(
        self,
        deadlines: np.ndarray,
        costs: np.ndarray,
        t: float,
        base: float,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized Eq.-2 scoring of N single-step (deadline, cost) pairs.

        Returns ``(alpha, beta, milestone)`` arrays; ``milestone`` is the
        next absolute regime-change time (``inf`` when none remains).
        Piecewise-step costs decompose into flat step arrays (Appendix B);
        fold the per-step rows back with :func:`aggregate_steps`.

        Closed form: the bins are sorted, so at slack ``s = D − t`` the
        regime-A bins are the prefix ``l2 < s`` (count ``iA``) and the
        regime-B bins are the run ``[iA, iB)`` with ``iB = #{l1 < s}``:

            α = (hc/(E[L]b)) e^{−bD} (P_gap[iA] − (P_el1[iB] − P_el1[iA]))
            β = (hc/(E[L]b)) (P_h[iB] − P_h[iA])
        """
        d = np.asarray(deadlines, dtype=np.float64)
        c = np.asarray(costs, dtype=np.float64)
        s = d - t  # slack until each step's deadline
        i_a = np.searchsorted(self.l2, s, side="left")
        i_b = np.searchsorted(self.l1, s, side="left")
        ebD = np.exp(-self.b * (d - base))
        kc = self._k * c
        alpha = kc * ebD * (
            self._p_gap[i_a] - (self._p_el1[i_b] - self._p_el1[i_a])
        )
        beta = kc * (self._p_h[i_b] - self._p_h[i_a])
        # Next milestone: the regime-A bins' D − l2 are decreasing in the
        # bin index, so the nearest future one is bin iA−1; likewise D − l1
        # at iB−1.  Regimes are tested in slack space (l2 < D − t) but
        # milestones are emitted in time space (D − l2); when the time-space
        # float rounds down the candidate can land AT t — re-scoring at
        # exactly that instant (the event loop wakes there) would see its
        # own wake time again and a naive `> now` filter would drop every
        # later milestone with it.  Advance such candidates to the next
        # strictly-future edge instead (the scores are continuous across a
        # regime change, so the ulp-late attribution is harmless).
        m_a = self._next_future(self.l2, i_a, d, t)
        m_b = self._next_future(self.l1, i_b, d, t)
        return alpha, beta, np.minimum(m_a, m_b)

    @staticmethod
    def _next_future(
        edges: np.ndarray, idx: np.ndarray, d: np.ndarray, t: float
    ) -> np.ndarray:
        """min of {d − edges[j] : j < idx} that is strictly > t (else inf).

        ``d − edges[j]`` decreases in j, so the candidate is j = idx−1,
        stepping left only in the ulp-coincidence case above."""
        i = idx
        m = np.where(i > 0, d - edges[np.maximum(i - 1, 0)], np.inf)
        stale = (i > 0) & (m <= t)
        while np.any(stale):
            i = np.where(stale, i - 1, i)
            m = np.where(i > 0, d - edges[np.maximum(i - 1, 0)], np.inf)
            stale = (i > 0) & (m <= t)
        return m

    def score(self, req: Request, t: float, base: float) -> Score:
        """Priority of ``req`` at time ``t`` (supports piecewise-step costs
        via the Appendix-B decomposition).  Thin wrapper over
        :meth:`score_many` so scalar and vectorized paths agree bit for
        bit."""
        cost_fn = req.cost_fn()
        if isinstance(cost_fn, PiecewiseStepCost):
            steps = cost_fn.steps()
            d = np.array([s.deadline for s in steps])
            c = np.array([s.cost for s in steps])
            alpha, beta, milestone = aggregate_steps(
                *self.score_many(d, c, t, base), np.array([0])
            )
        else:
            alpha, beta, milestone = self.score_many(
                np.array([cost_fn.deadline]), np.array([cost_fn.cost]), t, base
            )
        return Score(float(alpha[0]), float(beta[0]), float(milestone[0]))

    def value(self, req: Request, t: float, base: float) -> float:
        """Direct evaluation of p(t) — used by tests as the oracle."""
        s = self.score(req, t, base)
        return s.value(t, base, self.b)

    def value_reference(self, req: Request, t: float, base: float) -> float:
        """Literal Eq. 2 evaluation, bin by bin, no (α, β) folding."""
        cost_fn = req.cost_fn()
        steps = (
            cost_fn.steps() if isinstance(cost_fn, PiecewiseStepCost) else [cost_fn]
        )
        total = 0.0
        for step in steps:
            d_rel = step.deadline - base
            t_rel = t - base
            for l1, l2, h in zip(self.l1, self.l2, self.h):
                k = h * step.cost / (self.e_l * self.b)
                if t_rel < d_rel - l2:
                    total += (
                        k
                        * (math.exp(self.b * l2) - math.exp(self.b * l1))
                        * math.exp(-self.b * d_rel)
                        * math.exp(self.b * t_rel)
                    )
                elif t_rel < d_rel - l1:
                    total += k - k * math.exp(self.b * l1) * math.exp(
                        -self.b * d_rel
                    ) * math.exp(self.b * t_rel)
        return float(total)


def aggregate_steps(
    alpha: np.ndarray,
    beta: np.ndarray,
    milestone: np.ndarray,
    seg_starts: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fold per-step :meth:`BinScoreModel.score_many` rows into per-request
    rows: segment ``i`` spans ``seg_starts[i] : seg_starts[i+1]`` (Appendix-B
    sum of single-step scores; milestones take the segment min).  Both the
    scalar and the batched scheduler paths aggregate through this helper, so
    multi-step requests score identically everywhere."""
    return (
        np.add.reduceat(alpha, seg_starts),
        np.add.reduceat(beta, seg_starts),
        np.minimum.reduceat(milestone, seg_starts),
    )
