"""Unified multi-worker discrete-event engine (paper §5 methodology, §3.1
scale-out).

One event loop drives both the single-worker evaluation harness (§5: one
non-preemptive worker executing one batch at a time, ground-truth batch
latency ``l_B = c0 + c1·k·max_r l_r`` per Eq. 3–4) and the replica-pool
setting (§3.1: "different models and their replicas can use ORLOJ in
parallel").  The 1-worker case *is* the classic ``simulate`` loop; the
N-worker case adds a front-end dispatch policy that assigns each arriving
request to a replica scheduler.

Design points, each of which previously existed in only one of the two
diverged copies of this loop:

- **per-worker wake dedup** — a scheduler that returns a wake-up time gets
  at most one *live* ``WAKE`` event per worker: a wake is pushed only when
  it is earlier than the worker's pending wake (a superseded later wake
  lingers in the heap as a no-op until it fires, so the bound is amortized,
  not hard: arrivals + in-flight batches + live wakes + not-yet-fired
  superseded wakes).  The pre-unification cluster loop pushed a wake on
  *every* idle dispatch attempt and flooded the heap under light load;
- **scheduler-overhead charging** — optionally bill the measured wall-clock
  cost of each scheduling decision to the virtual clock (the Fig.-14
  overhead study);
- **horizon** — stop observing at a fixed virtual time: the reported
  makespan is clamped to the horizon, busy time is credited only inside
  the window, and the rest of the trace (including any in-flight batch)
  counts as unserved;
- **heterogeneous replicas** — each :class:`Worker` pairs its own scheduler
  with its own executor, so a pool can mix fast and slow replicas or
  different :class:`~repro.core.distributions.BatchLatencyModel` s;
- **honest accounting** — :class:`SimResult` carries an explicit
  ``n_workers`` and per-pool ``utilization = worker_busy / (makespan ·
  n_workers)`` instead of corrupting ``makespan`` to fake it.

Front-end dispatch policies (pluggable via :data:`DISPATCH_POLICIES` or any
callable ``(request, now, pool) -> worker_index``):

- ``round_robin`` — baseline;
- ``least_loaded`` — fewest pending requests, ties broken randomly (the
  standard full-information serving-tier balancer);
- ``jsq_work`` — least *expected work* queued (Σ per-request E[alone]),
  distribution-aware: reuses the same per-app means ORLOJ tracks;
- ``p2c`` — power-of-two-choices: sample two replicas, send to the one
  with less expected queued work.  Distribution-aware like ``jsq_work``
  but needs only two load probes per arrival, the classic trade-off for
  front-ends that cannot snapshot every replica.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time as _time
from typing import Callable, Protocol, Sequence

import numpy as np

from .distributions import BatchLatencyModel
from .eventwheel import EventWheel
from .request import Request
from .requeststore import RequestStore
from .scheduler import Batch

__all__ = [
    "DISPATCH_POLICIES",
    "ENGINES",
    "DecodeExecutorLike",
    "DecodeModelExecutor",
    "Executor",
    "ModelExecutor",
    "SchedulerLike",
    "SimResult",
    "TokenSchedulerLike",
    "Worker",
    "run_event_loop",
    "simulate",
]


class Executor(Protocol):
    def __call__(self, batch: Batch, now: float) -> float:
        """Return the batch execution time in ms."""


class SchedulerLike(Protocol):
    """The contract the event loop drives (Orloj and every baseline).

    ``on_arrivals`` (bulk delivery) is optional — the loop probes for it
    with ``getattr`` and falls back to per-request ``on_arrival``."""

    def on_arrival(self, req: Request, now: float) -> None: ...

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]: ...

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None: ...


class TokenSchedulerLike(SchedulerLike, Protocol):
    """The extra hook a token-mode scheduler implements (DESIGN.md §12).

    A scheduler opts into iteration-level (continuous) batching by
    returning ``Batch(decode=True)`` from ``next_batch``.  The loop then
    calls ``on_decode_step`` once per decode iteration — after EOS
    removals, before the next step is armed — and the scheduler answers
    with the requests to admit into the running batch at this token
    boundary (possibly none).  ``on_batch_done`` is never called for
    decode batches."""

    def on_decode_step(
        self, finished: Sequence[Request], n_active: int, now: float
    ) -> list[Request]: ...


class DecodeExecutorLike(Protocol):
    """Executor contract for resumable decode executions.

    ``active`` is the continuous batch *after* this step's joins;
    ``joined`` are the members whose prompt prefill is folded into this
    step (Orca-style piggybacked prefill).  At initial dispatch both are
    the full batch.  Returns the step duration in ms."""

    def step_time(
        self,
        active: Sequence[Request],
        joined: Sequence[Request],
        now: float,
    ) -> float: ...


class FaultPlanLike(Protocol):
    """Duck-typed fault plan (:class:`repro.serving.faults.FaultPlan`).

    The core engine never imports the serving layer — it only needs the
    plan to materialize per-run state with seeded rng streams and the
    gate/retry/straggler hooks the loops call."""

    @property
    def restart_delay_ms(self) -> float: ...

    @property
    def admission_floor(self) -> float: ...

    @property
    def batch_timeout_ms(self) -> float: ...

    def enabled(self) -> bool: ...

    def start(self, n_workers: int) -> "FaultStateLike": ...


class FaultStateLike(Protocol):
    plan: "FaultPlanLike"
    crashes: bool

    def next_crash(self, w: int, up_since: float) -> float: ...

    def straggle(self, dur: float) -> float: ...

    def admit(
        self,
        scheduler: "SchedulerLike",
        req: Request,
        now: float,
        queued_ahead: int = 0,
    ) -> bool: ...

    def retry_decision(
        self, scheduler: "SchedulerLike", req: Request, now: float
    ) -> tuple[bool, float]: ...


class ResidencyPlanLike(Protocol):
    """Duck-typed weights-residency plan
    (:class:`repro.serving.residency.ResidencyPlan`).  As with faults, the
    core engine never imports the serving layer — it only needs
    ``start(n_workers)`` to mint the per-run cache state."""

    def start(self, n_workers: int) -> "ResidencyStateLike": ...


class ResidencyStateLike(Protocol):
    """Per-run residency state: deterministic (no rng, virtual time only),
    so both engines charging the same dispatch order stay bit-identical."""

    n_loads: int
    n_evicts: int
    load_ms_total: float

    def resident(self, w: int, model_id: str) -> bool: ...

    def acquire(self, w: int, model_id: str, now: float) -> float: ...


@dataclasses.dataclass
class ModelExecutor:
    """Ground-truth execution following the paper's padding model."""

    latency_model: BatchLatencyModel
    jitter: float = 0.0  # multiplicative noise std (hardware non-determinism)
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def __call__(self, batch: Batch, now: float) -> float:
        t = self.latency_model.batch_time([r.true_time for r in batch.requests])
        if self.jitter > 0:
            t *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        return t


@dataclasses.dataclass
class DecodeModelExecutor:
    """Ground-truth token-level execution (the Eq.-3 analogue per decode
    iteration): one step over a continuous batch of ``k`` requests costs
    ``d0 + d1·k`` ms (every member produces one token; max_r l_r is one
    token-time), plus ``prefill_per_token`` ms for each prompt token of
    the members whose prefill piggybacks on this step — the concrete
    source of prefill/decode interference.  Deterministic by construction,
    so both engines replay identical step timestamps."""

    d0: float = 2.0
    d1: float = 0.25
    prefill_per_token: float = 0.02

    def step_time(
        self,
        active: Sequence[Request],
        joined: Sequence[Request],
        now: float,
    ) -> float:
        t = self.d0 + self.d1 * len(active)
        if joined:
            t += self.prefill_per_token * sum(r.prompt_tokens for r in joined)
        return t

    def __call__(self, batch: Batch, now: float) -> float:
        raise TypeError(
            "DecodeModelExecutor serves resumable decode batches only; "
            "atomic batches need a ModelExecutor"
        )


class _DecodeRun:
    """Mutable state of one resumable decode execution — one per
    dispatched ``decode=True`` batch, threaded through the re-armed
    ``_STEP`` events.  ``rows`` (array engine only) tracks each active
    request's store row, aligned with ``active``."""

    __slots__ = ("batch", "active", "rows")

    def __init__(
        self, batch: Batch, active: list[Request], rows: list[int] | None
    ) -> None:
        self.batch = batch
        self.active = active
        self.rows = rows


def _advance_decode(
    run: _DecodeRun, now: float
) -> tuple[list[Request], list[int]]:
    """Advance every active request by one produced token and split off
    those hitting EOS this step.  The single token-accounting path both
    engines share, so ``tokens_done``/``first_token``/EOS timestamps are
    bit-identical by construction.  Returns ``(finished, finished_rows)``;
    rows are tracked only when the run carries them (array engine)."""
    rows = run.rows
    finished: list[Request] = []
    fin_rows: list[int] = []
    still: list[Request] = []
    still_rows: list[int] = []
    for i, r in enumerate(run.active):
        r.tokens_done += 1
        if r.first_token is None:
            r.first_token = now
        if r.tokens_done >= r.out_tokens:
            finished.append(r)
            if rows is not None:
                fin_rows.append(rows[i])
        else:
            still.append(r)
            if rows is not None:
                still_rows.append(rows[i])
    run.active = still
    if rows is not None:
        run.rows = still_rows
    return finished, fin_rows


def _decode_step_dur(
    executor: Executor,
    active: Sequence[Request],
    joined: Sequence[Request],
    now: float,
) -> float:
    """One decode-step duration via the executor's ``step_time`` hook,
    with an actionable error for executors that only run atomic batches."""
    step = getattr(executor, "step_time", None)
    if step is None:
        raise TypeError(
            f"scheduler returned a decode batch but executor "
            f"{type(executor).__name__} has no step_time (token mode "
            f"needs a DecodeExecutorLike, e.g. DecodeModelExecutor)"
        )
    return step(active, joined, now)


@dataclasses.dataclass
class SimResult:
    n_total: int
    n_finished_ok: int
    n_finished_late: int
    n_dropped: int
    n_unserved: int
    worker_busy: float  # summed busy time across the pool
    makespan_ms: float  # virtual time (ms) of the last processed event
    latencies: np.ndarray
    n_workers: int = 1
    peak_heap_size: int = 0  # high-water mark of the event heap
    # Measured wall-clock spent inside scheduler hooks (``on_arrival(s)``,
    # ``next_batch``, ``on_batch_done``), separated from the simulation's
    # own bookkeeping so per-request overhead columns charge the scheduler
    # for its decisions only — not for the event loop that replays them.
    sched_time_ms: float = 0.0
    n_decisions: int = 0  # number of ``next_batch`` calls
    # Batches actually executed (DONE events inside the horizon).  The
    # real-engine eval tier pairs this with the executor's measured-batch
    # log to attribute predicted-vs-measured drift per executed batch.
    n_batches: int = 0
    # Fault-tier terminal-state accounting (DESIGN.md §11): admission
    # rejections, retry-exhausted failures after crash/timeout aborts,
    # and the total number of retry dispatches (a request retried twice
    # counts twice).
    n_rejected: int = 0
    n_failed: int = 0
    n_retried: int = 0
    # True when the run was cut off by ``wall_budget_s`` — partial stats,
    # everything unresolved counted as unserved.
    truncated: bool = False
    # Multi-model residency accounting (DESIGN.md §13): weight loads,
    # evictions, and the total virtual ms of load/evict stall charged to
    # the clock.  All zero when no residency plan is active.
    n_model_loads: int = 0
    n_model_evicts: int = 0
    model_load_ms: float = 0.0

    @property
    def conserved(self) -> bool:
        """Hard conservation invariant: every request reaches exactly one
        terminal state — finished (ok|late), dropped, rejected, failed —
        or none (unserved).  The fault tier property-tests this across
        engines and fleet mode."""
        return (
            self.n_finished_ok + self.n_finished_late + self.n_dropped
            + self.n_unserved + self.n_rejected + self.n_failed
            == self.n_total
        )

    @property
    def sched_us_per_request(self) -> float:
        """Scheduler decision time per request (µs) — the overhead column."""
        return self.sched_time_ms * 1e3 / max(1, self.n_total)

    @property
    def finish_rate(self) -> float:
        return self.n_finished_ok / max(1, self.n_total)

    @property
    def utilization(self) -> float:
        """Pool utilization: busy time over total worker-time available."""
        return self.worker_busy / max(self.makespan_ms * self.n_workers, 1e-9)

    def summary(self) -> str:
        return (
            f"finish_rate={self.finish_rate:.3f} ok={self.n_finished_ok} "
            f"late={self.n_finished_late} dropped={self.n_dropped} "
            f"unserved={self.n_unserved} util={self.utilization:.2f}"
        )


@dataclasses.dataclass
class Worker:
    """One replica: its scheduler plus the executor that runs its batches.

    Executors may be shared between workers (homogeneous pool, one measured
    backend) or distinct (heterogeneous pool of fast/slow replicas)."""

    scheduler: SchedulerLike
    executor: Executor


def _expected_alone(scheduler: SchedulerLike, req: Request) -> float:
    """E[alone] of ``req`` under the scheduler's learned app distribution
    (falls back to its scalar estimator, then to a unit cost)."""
    dists = getattr(scheduler, "_app_dists", None)
    if dists and req.app_id in dists:
        return float(dists[req.app_id].mean())
    est = getattr(scheduler, "est", None)
    if est is not None:
        return float(est.value())
    return 1.0


class _Pool:
    """Dispatch-time view of the pool handed to policy callables.

    ``queued_work`` is an incremental ledger of per-request charges
    (E[alone] under the scheduler's app distribution *at arrival time*).
    Each charge is recorded per rid and the **same recorded value** is
    subtracted when the request leaves — never re-evaluated, since the
    scheduler may swap in a new profiler snapshot in between and a
    re-evaluated decrement would make the ledger drift (even negative).
    Requests the scheduler drops are swept from the ledger lazily after
    each scheduling decision.

    The ledger is maintained only when ``track_work`` — i.e. when the
    dispatch policy actually reads ``queued_work`` (``jsq_work``, ``p2c``,
    or any user callable); count-based policies and 1-worker runs skip the
    bookkeeping entirely."""

    __slots__ = ("workers", "busy", "queued_work", "rng", "track_work",
                 "pending_offset", "_charges", "_swept_timeouts", "residency")

    def __init__(
        self,
        workers: Sequence[Worker],
        rng: np.random.Generator,
        track_work: bool = True,
    ):
        self.workers = list(workers)
        self.busy = [False] * len(self.workers)
        self.queued_work = [0.0] * len(self.workers)
        # Weights-residency state (multi-model runs only, DESIGN.md §13):
        # set by run_event_loop so residency-aware dispatch policies can
        # probe which workers hold a request's model.  None otherwise.
        self.residency: "ResidencyStateLike | None" = None
        # Same-timestamp arrivals routed to a worker but not yet delivered
        # to its scheduler (the coalescing window): count-based policies add
        # this so a burst does not all land on one replica.
        self.pending_offset = [0] * len(self.workers)
        self.rng = rng
        self.track_work = track_work
        # per-worker rid -> (request, charged amount)
        self._charges: list[dict[int, tuple[Request, float]]] = [
            {} for _ in self.workers
        ]
        # per-worker scheduler timeout count at the last sweep
        self._swept_timeouts = [0] * len(self.workers)

    def __len__(self) -> int:
        return len(self.workers)

    def charge(self, w: int, req: Request) -> None:
        if not self.track_work:
            return
        amount = _expected_alone(self.workers[w].scheduler, req)
        self._charges[w][req.rid] = (req, amount)
        self.queued_work[w] += amount

    def discharge(self, w: int, rid: int) -> None:
        if not self.track_work:
            return
        got = self._charges[w].pop(rid, None)
        if got is not None:
            self.queued_work[w] -= got[1]

    def sweep_dropped(self, w: int) -> None:
        """Remove charges for requests the scheduler timed out (they will
        never be dispatched, so nothing else would ever discharge them).
        Scans only when the scheduler's timeout counter moved since the
        last sweep (schedulers without a counter are always scanned)."""
        if not self.track_work:
            return
        n_timed_out = getattr(self.workers[w].scheduler, "n_timed_out", None)
        if n_timed_out is not None:
            if n_timed_out == self._swept_timeouts[w]:
                return
            self._swept_timeouts[w] = n_timed_out
        ch = self._charges[w]
        stale = [rid for rid, (req, _) in ch.items() if req.dropped is not None]
        for rid in stale:
            self.queued_work[w] -= ch.pop(rid)[1]

    def backlog(self, w: int) -> tuple[float, float]:
        """(expected queued work, queue length) — the policy sort key."""
        sched = self.workers[w].scheduler
        return (
            self.queued_work[w],
            getattr(sched, "n_pending", 0) + self.busy[w]
            + self.pending_offset[w],
        )


# A dispatch policy: (request, now, pool) -> worker index.
_PickFn = Callable[[Request, float, _Pool], int]


def _round_robin(workers: Sequence[Worker], rng: np.random.Generator) -> _PickFn:
    it = itertools.cycle(range(len(workers)))
    return lambda req, now, pool: next(it)


def _least_loaded(workers: Sequence[Worker], rng: np.random.Generator) -> _PickFn:
    def pick(req: Request, now: float, pool: _Pool) -> int:
        loads = np.array(
            [
                getattr(w.scheduler, "n_pending", 0) + pool.busy[i]
                + pool.pending_offset[i]
                for i, w in enumerate(pool.workers)
            ]
        )
        cands = np.flatnonzero(loads == loads.min())
        return int(rng.choice(cands))

    return pick


def _jsq_work(workers: Sequence[Worker], rng: np.random.Generator) -> _PickFn:
    return lambda req, now, pool: int(np.argmin(pool.queued_work))


def _p2c(workers: Sequence[Worker], rng: np.random.Generator) -> _PickFn:
    n = len(workers)

    def pick(req: Request, now: float, pool: _Pool) -> int:
        if n == 1:
            return 0
        i, j = rng.choice(n, size=2, replace=False)
        return int(i) if pool.backlog(int(i)) <= pool.backlog(int(j)) else int(j)

    return pick


def _residency_aware(
    workers: Sequence[Worker], rng: np.random.Generator
) -> _PickFn:
    """Residency before backlog (DESIGN.md §13): among workers already
    holding the request's model weights, pick the least loaded; only when
    nobody holds them fall back to least-loaded overall.  The fallback
    creates natural model→worker affinity — once a model is loaded
    somewhere, its traffic sticks there instead of spraying cold starts
    across the pool the way residency-blind policies do.  Fully
    deterministic (ties break on worker index, no rng), so the policy
    cannot perturb engine bit-identity."""

    def pick(req: Request, now: float, pool: _Pool) -> int:
        res = pool.residency
        best, best_key = 0, None
        for i, w in enumerate(pool.workers):
            load = (
                getattr(w.scheduler, "n_pending", 0) + pool.busy[i]
                + pool.pending_offset[i]
            )
            hit = (
                res is not None
                and req.model_id is not None
                and res.resident(i, req.model_id)
            )
            key = (not hit, load, i)  # resident first, then backlog
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    return pick


# name -> factory(workers, rng) -> pick(request, now, pool) -> worker index
DISPATCH_POLICIES: dict[str, Callable] = {
    "round_robin": _round_robin,
    "least_loaded": _least_loaded,
    "jsq_work": _jsq_work,
    "p2c": _p2c,
    "residency": _residency_aware,
}

_ARRIVAL, _DONE, _WAKE = 0, 1, 2
# Fault-tier event kinds (DESIGN.md §11): worker crash / worker restart /
# deadline-aware retry of an aborted request / batch-timeout abort.
_CRASH, _RESTART, _RETRY, _ABORT = 3, 4, 5, 6
# Token-mode event kind (DESIGN.md §12): one decode iteration of a
# resumable execution — a DONE that may re-arm itself.
_STEP = 7

# Array-loop merge sources (where the next dynamic event comes from).
_TAKE_BUF, _TAKE_BUCKET, _TAKE_ONE = 1, 2, 3
_NO_EVENT = (math.inf, -1)

# Event-loop implementations.  ``scalar`` is the original heapq loop and
# stays the oracle; ``array`` is the array-backed engine (RequestStore +
# EventWheel, DESIGN.md §10) whose observable behaviour — every scheduler
# hook call, timestamp, rng draw and result field — is bit-identical to
# the oracle (regression-tested over the full small grid).
ENGINES = ("scalar", "array")


def run_event_loop(
    requests: Sequence[Request],
    workers: Sequence[Worker],
    *,
    policy: str | Callable = "least_loaded",
    horizon: float | None = None,
    charge_scheduler_overhead: bool = False,
    seed: int = 0,
    engine: str = "scalar",
    faults: "FaultPlanLike | None" = None,
    residency: "ResidencyPlanLike | None" = None,
    wall_budget_s: float = 0.0,
) -> SimResult:
    """Drive ``workers`` replica schedulers against one arrival stream.

    Runs until every request is resolved (finished/dropped) or, with
    ``horizon``, until the virtual clock passes it.  ``policy`` is a name
    from :data:`DISPATCH_POLICIES` or a callable
    ``(request, now, pool) -> worker_index``.

    Custom callables should measure load via ``pool.backlog(w)`` (or add
    ``pool.pending_offset[w]`` to any direct ``n_pending`` read): during a
    coalesced same-timestamp burst, arrivals routed to a busy worker are
    buffered and only delivered to its scheduler after routing, so its raw
    ``n_pending`` lags by the buffered count.

    ``charge_scheduler_overhead=True`` bills the *measured wall-clock* cost
    of each scheduler decision to the virtual clock (used by the Fig.-14
    overhead study: with ms-scale requests, scheduling time itself starts
    to matter).

    ``engine`` picks the implementation (:data:`ENGINES`): ``"scalar"`` is
    the original heapq loop (the oracle); ``"array"`` sources arrivals from
    a :class:`~repro.core.requeststore.RequestStore` and DONE/WAKE events
    from an :class:`~repro.core.eventwheel.EventWheel` — same observable
    behaviour, built for 10⁵–10⁶-request traces.  ``peak_heap_size`` is the
    one intentionally engine-specific field: both report peak *pending
    events*, but the scalar heap retains superseded-wake tombstones
    slightly differently than the wheel, so only the bound (not the exact
    value) is comparable.

    ``faults`` is an optional :class:`~repro.serving.faults.FaultPlan`
    (anything exposing ``start(n_workers)``): worker crashes, stragglers,
    admission control and batch timeouts, replayed identically by both
    engines from the plan's own seeded rng streams (DESIGN.md §11).
    ``wall_budget_s > 0`` cuts the run off after that much *wall-clock*
    time: the result is marked ``truncated`` and everything unresolved
    counts as unserved — a graceful partial answer instead of a hung grid
    cell.

    ``residency`` is an optional
    :class:`~repro.serving.residency.ResidencyPlan`: per-worker weights
    caches for multi-model serving (DESIGN.md §13).  Every dispatched
    batch must then carry ``Batch.model``; a cache miss stalls execution
    by the model's load time (plus eviction costs), charged identically
    by both engines.  ``residency=None`` (every single-model run) takes
    zero new branches — the ``single-model-noop`` claim gates this
    bitwise.  Residency composes with neither fault injection nor decode
    batches (both raise ``ValueError``, the pinned unsupported seams).
    """
    workers = list(workers)
    if not workers:
        raise ValueError("need at least one worker")
    n = len(workers)
    rng = np.random.default_rng(seed)
    # Only work-aware policies read queued_work; 1-worker runs and
    # count-based policies skip the ledger bookkeeping entirely.
    track_work = n > 1 and (callable(policy) or policy in ("jsq_work", "p2c"))
    pool = _Pool(workers, rng, track_work=track_work)
    if callable(policy):
        pick = policy
    else:
        try:
            pick = DISPATCH_POLICIES[policy](workers, rng)
        except KeyError:
            raise ValueError(
                f"unknown dispatch policy {policy!r}; "
                f"known: {sorted(DISPATCH_POLICIES)}"
            ) from None
    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; known: {list(ENGINES)}"
        )
    if residency is not None and faults is not None:
        # Crash-during-load semantics (is a half-loaded model resident?
        # does the stall replay after restart?) have no honest answer yet;
        # fail loudly rather than charge something undefined.
        raise ValueError(
            "multi-model residency is not supported under fault injection"
        )
    res = residency.start(n) if residency is not None else None
    pool.residency = res
    fs = faults.start(n) if faults is not None else None
    if fs is not None and (fs.crashes or fs.plan.batch_timeout_ms > 0.0):
        # Crash termination leans on every scheduler's drop counter to
        # decide whether unresolved work remains (all in-repo schedulers
        # expose it); refuse silently-wrong accounting up front.
        for w_ in workers:
            if getattr(w_.scheduler, "n_timed_out", None) is None:
                raise ValueError(
                    "fault injection (crashes/batch timeouts) requires "
                    "schedulers exposing n_timed_out"
                )
    if engine == "array":
        return _array_loop(
            requests,
            workers,
            pool,
            pick,
            horizon=horizon,
            charge_scheduler_overhead=charge_scheduler_overhead,
            fs=fs,
            res=res,
            wall_budget_s=wall_budget_s,
        )

    requests = sorted(requests, key=lambda r: r.release)
    events: list[tuple[float, int, int, object]] = []
    seq = itertools.count()
    for r in requests:
        heapq.heappush(events, (r.release, next(seq), _ARRIVAL, r))

    plan = fs.plan if fs is not None else None
    n_rejected = 0
    n_failed = 0
    n_retried = 0
    n_finished = 0
    truncated = False
    down = [False] * n
    # Per-worker crash epoch: a DONE/ABORT event carries the epoch its
    # batch was dispatched under; a crash bumps the epoch so the stale
    # completion becomes a tombstone when it fires.
    epoch = [0] * n
    # In-flight batch payloads, maintained only under a fault plan (the
    # crash-abort path needs the batch; ``inflight`` keeps only spans).
    running: list[Batch | None] = [None] * n
    gate = plan is not None and plan.admission_floor > 0.0
    timeout_ms = plan.batch_timeout_ms if plan is not None else 0.0
    if fs is not None and fs.crashes:
        # initial crash draws, one per worker in index order (the array
        # loop mirrors this exactly, so seq numbers line up)
        for w in range(n):
            heapq.heappush(
                events, (fs.next_crash(w, 0.0), next(seq), _CRASH, w)
            )

    peak_heap = len(events)
    worker_busy_time = 0.0
    sched_time = 0.0  # wall-clock seconds inside scheduler hooks
    n_decisions = 0
    n_batches = 0
    last_time = 0.0
    inflight: list[tuple[float, float] | None] = [None] * n  # (start, end)
    # At most one *live* WAKE per worker (re-armed only for an earlier
    # wake): the dedup that keeps the heap from flooding under light load.
    pending_wake: list[float | None] = [None] * n

    def try_dispatch(w: int, now: float) -> None:
        nonlocal worker_busy_time, peak_heap, sched_time, n_decisions
        if pool.busy[w] or down[w]:
            return
        worker = workers[w]
        # simlint: ignore[R1] -- meters real scheduler overhead (reported, optionally charged as latency); the sim clock itself stays virtual
        t0 = _time.perf_counter()
        batch, wake = worker.scheduler.next_batch(now)
        # simlint: ignore[R1] -- closes the overhead meter opened above
        dt = _time.perf_counter() - t0
        sched_time += dt
        n_decisions += 1
        overhead = dt * 1e3 if charge_scheduler_overhead else 0.0
        if batch is not None and getattr(batch, "decode", False):
            # Resumable token-level execution (DESIGN.md §12): the dispatch
            # step prefills every initial member and produces their first
            # token; the run then re-arms _STEP events until the last
            # member hits EOS.
            if fs is not None:
                raise ValueError(
                    "decode (token-level) batches are not supported "
                    "under fault injection"
                )
            if res is not None:
                raise ValueError(
                    "decode (token-level) batches are not supported "
                    "under multi-model residency"
                )
            start = now + overhead
            run = _DecodeRun(batch, list(batch.requests), None)
            dur = _decode_step_dur(
                worker.executor, run.active, batch.requests, start
            )
            for r in batch.requests:
                r.started = start
                pool.discharge(w, r.rid)
            pool.busy[w] = True
            worker_busy_time += dur
            inflight[w] = (start, start + dur)
            heapq.heappush(
                events, (start + dur, next(seq), _STEP, (w, run, epoch[w]))
            )
            peak_heap = max(peak_heap, len(events))
        elif batch is not None:
            start = now + overhead
            if res is not None:
                # Weights residency (DESIGN.md §13): a cache miss stalls
                # the batch by the load time (plus eviction costs) before
                # execution can begin.  The worker is occupied for the
                # whole stall — loads are not overlapped with compute.
                if batch.model is None:
                    raise ValueError(
                        "residency-managed run dispatched a batch without "
                        "a model id (scheduler must stamp Batch.model)"
                    )
                stall = res.acquire(w, batch.model, start)
                start += stall
            else:
                stall = 0.0
            dur = worker.executor(batch, start)
            ev_kind = _DONE
            if fs is not None:
                dur = fs.straggle(dur)
                if 0.0 < timeout_ms < dur:
                    # overlong batch: aborted at the timeout deadline,
                    # its requests go through the retry gate
                    dur = timeout_ms
                    ev_kind = _ABORT
                running[w] = batch
            for r in batch.requests:
                r.started = start
                pool.discharge(w, r.rid)
            pool.busy[w] = True
            worker_busy_time += stall + dur
            inflight[w] = (start - stall, start + dur)
            heapq.heappush(
                events, (start + dur, next(seq), ev_kind, (w, batch, epoch[w]))
            )
            peak_heap = max(peak_heap, len(events))
        elif wake is not None and np.isfinite(wake) and wake > now:
            if pending_wake[w] is None or wake < pending_wake[w]:
                pending_wake[w] = wake
                heapq.heappush(events, (wake, next(seq), _WAKE, w))
                peak_heap = max(peak_heap, len(events))
        # the decision may have timed requests out (drop phase) — keep the
        # policy load signal honest
        pool.sweep_dropped(w)

    def work_remains() -> bool:
        # Any request without a terminal state yet, arrived or not.  A
        # crash/restart only reschedules itself while this holds, so the
        # renewal process cannot keep an otherwise-drained loop alive.
        resolved = n_finished + n_rejected + n_failed
        for w_ in workers:
            resolved += w_.scheduler.n_timed_out  # type: ignore[attr-defined]
        return resolved < len(requests)

    def abort_batch(w: int, batch: Batch, now: float) -> None:
        # Crash/timeout abort: each request re-enters through the
        # deadline-aware retry gate or terminates honestly as failed.
        nonlocal n_failed, n_retried, peak_heap
        assert fs is not None
        sched = workers[w].scheduler
        for r in batch.requests:
            r.started = None
            retry, t_retry = fs.retry_decision(sched, r, now)
            if retry:
                r.retries += 1
                n_retried += 1
                heapq.heappush(events, (t_retry, next(seq), _RETRY, r))
            else:
                r.failed = now
                n_failed += 1
        peak_heap = max(peak_heap, len(events))

    wall_deadline = None
    if wall_budget_s > 0.0:
        # simlint: ignore[R1] -- wall-budget truncation is real elapsed time by design; the sim clock stays virtual
        wall_deadline = _time.perf_counter() + wall_budget_s
    n_events = 0
    while events:
        now, _, kind, payload = heapq.heappop(events)
        n_events += 1
        if (
            wall_deadline is not None
            and not n_events & 1023
            # simlint: ignore[R1] -- wall-budget truncation check (real elapsed time by design)
            and _time.perf_counter() > wall_deadline
        ):
            # Out of wall-clock budget: stop observing at the last
            # processed event (the popped one is discarded unprocessed),
            # clamp in-flight busy credit exactly like the horizon path,
            # and report the partial stats as ``truncated``.
            truncated = True
            for span in inflight:
                if span is not None and span[1] > last_time:
                    worker_busy_time -= span[1] - max(span[0], last_time)
            break
        if horizon is not None and now > horizon:
            # Stop observing at the horizon: the clock reads ``horizon``
            # (not the time of the first event beyond it) and busy time is
            # only credited for work inside the window — an in-flight
            # batch's requests stay unserved, so crediting its full
            # duration would overstate utilization.
            last_time = horizon
            for span in inflight:
                if span is not None and span[1] > horizon:
                    worker_busy_time -= span[1] - max(span[0], horizon)
            break
        last_time = now
        if kind == _ARRIVAL:
            # Coalesce every arrival bearing this exact timestamp (a burst
            # drained from the network in one go).  While a worker is idle
            # its share is delivered one request at a time with a dispatch
            # attempt in between — identical to the pre-coalescing loop, so
            # an urgent head-of-burst request can still grab the idle
            # worker.  The moment the worker goes busy (the high-load hot
            # path) the rest of the burst is delivered as ONE bulk
            # ``on_arrivals`` call and scored in a single vectorized pass.
            # simlint: ignore[R5] -- one burst buffer per ARRIVAL event; the coalescing is what enables the bulk on_arrivals path
            arrivals: list[Request] = [payload]
            while events and events[0][0] == now and events[0][2] == _ARRIVAL:
                arrivals.append(heapq.heappop(events)[3])
            # Route/deliver in arrival order, exactly as the pre-coalescing
            # loop did: an arrival routed to an IDLE worker is delivered and
            # dispatched immediately (so an urgent head-of-burst request can
            # grab the worker, and later picks see the dispatch's busy/
            # discharge side effects).  Only arrivals routed to a BUSY
            # worker — where a dispatch attempt would be a no-op anyway —
            # are buffered and flushed as ONE bulk ``on_arrivals`` call,
            # the high-load case where the vectorized scoring pass pays.
            # ``pending_offset`` keeps count-based policies seeing buffered
            # requests as if they were already delivered.
            # simlint: ignore[R5] -- one routing buffer per burst, replacing per-request scheduler calls with one bulk delivery per worker
            buffered: dict[int, list[Request]] = {}
            for req in arrivals:
                w = pick(req, now, pool) if n > 1 else 0
                if gate and not fs.admit(
                    workers[w].scheduler,
                    req,
                    now,
                    # requests ahead on the picked worker: its queue, the
                    # burst share buffered for it, and the in-flight batch
                    getattr(workers[w].scheduler, "n_pending", 0)
                    + pool.pending_offset[w]
                    + (1 if pool.busy[w] else 0),
                ):
                    # shed at the front door: never queued, never charged
                    # (the pick above still ran, so the policy rng stream
                    # is identical with the gate on or off)
                    req.rejected = now
                    n_rejected += 1
                    continue
                pool.charge(w, req)
                if pool.busy[w]:
                    # simlint: ignore[R5] -- group list created once per (burst, worker), not per request
                    buffered.setdefault(w, []).append(req)
                    pool.pending_offset[w] += 1
                else:
                    t0 = _time.perf_counter()  # simlint: ignore[R1] -- overhead meter, not sim time
                    workers[w].scheduler.on_arrival(req, now)
                    sched_time += _time.perf_counter() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
                    try_dispatch(w, now)
            for w, group in buffered.items():
                pool.pending_offset[w] = 0
                sched = workers[w].scheduler
                deliver = getattr(sched, "on_arrivals", None)
                t0 = _time.perf_counter()  # simlint: ignore[R1] -- overhead meter, not sim time
                if deliver is not None:
                    deliver(group, now)
                else:
                    for req in group:
                        sched.on_arrival(req, now)
                sched_time += _time.perf_counter() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
        elif kind == _DONE:
            w, batch, ep = payload
            if ep != epoch[w]:
                continue  # tombstone: the worker crashed under this batch
            pool.busy[w] = False
            inflight[w] = None
            if fs is not None:
                running[w] = None
            n_batches += 1
            n_finished += len(batch.requests)
            for r in batch.requests:
                r.finished = now
            t0 = _time.perf_counter()  # simlint: ignore[R1] -- overhead meter, not sim time
            workers[w].scheduler.on_batch_done(
                # simlint: ignore[R5] -- one alone-times list per completed batch (feedback path), not per request
                batch, now, [r.true_time for r in batch.requests]
            )
            sched_time += _time.perf_counter() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            try_dispatch(w, now)
        elif kind == _STEP:
            # One decode iteration of a resumable execution: advance token
            # counts, retire EOS requests, let the scheduler admit joiners
            # at this token boundary, then re-arm (or drain the run).
            w, run, ep = payload
            if ep != epoch[w]:
                continue  # tombstone (decode runs never coexist with faults today, but keep the contract uniform)
            finished, _ = _advance_decode(run, now)
            n_finished += len(finished)
            for r in finished:
                r.finished = now
            t0 = _time.perf_counter()  # simlint: ignore[R1] -- overhead meter, not sim time
            joined = workers[w].scheduler.on_decode_step(
                finished, len(run.active), now
            )
            sched_time += _time.perf_counter() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            n_decisions += 1
            if joined:
                for r in joined:
                    r.started = now
                    pool.discharge(w, r.rid)
                run.active.extend(joined)
            if run.active:
                dur = _decode_step_dur(
                    workers[w].executor, run.active, joined, now
                )
                worker_busy_time += dur
                inflight[w] = (now, now + dur)
                heapq.heappush(
                    events, (now + dur, next(seq), _STEP, (w, run, ep))
                )
                peak_heap = max(peak_heap, len(events))
            else:
                n_batches += 1
                pool.busy[w] = False
                inflight[w] = None
                try_dispatch(w, now)
            # the admission hook may also have timed requests out
            pool.sweep_dropped(w)
        elif kind == _WAKE:
            w = payload
            if pending_wake[w] is not None and now >= pending_wake[w]:
                pending_wake[w] = None
            try_dispatch(w, now)
        elif kind == _ABORT:
            w, batch, ep = payload
            if ep != epoch[w]:
                continue  # the worker crashed before the timeout fired
            pool.busy[w] = False
            inflight[w] = None
            running[w] = None
            abort_batch(w, batch, now)
            try_dispatch(w, now)
        elif kind == _CRASH:
            w = payload
            if work_remains():
                # Kill the worker: bump its epoch (outstanding DONE/ABORT
                # events become tombstones), abort any in-flight batch,
                # schedule the restart.  With no work left the crash is
                # discarded and nothing is rescheduled, so the heap
                # drains and the loop terminates.
                epoch[w] += 1
                down[w] = True
                span = inflight[w]
                if span is not None:
                    # credit only the work actually done before the crash
                    worker_busy_time -= span[1] - max(span[0], now)
                    inflight[w] = None
                    pool.busy[w] = False
                    doomed = running[w]
                    running[w] = None
                    assert doomed is not None
                    abort_batch(w, doomed, now)
                heapq.heappush(
                    events,
                    (now + plan.restart_delay_ms, next(seq), _RESTART, w),
                )
                peak_heap = max(peak_heap, len(events))
        elif kind == _RESTART:
            w = payload
            down[w] = False
            if work_remains():
                heapq.heappush(
                    events, (fs.next_crash(w, now), next(seq), _CRASH, w)
                )
                peak_heap = max(peak_heap, len(events))
            try_dispatch(w, now)
        else:  # _RETRY
            req = payload
            w = pick(req, now, pool) if n > 1 else 0
            if down[w]:
                # Dead-target re-route: deterministically drain to the
                # next live sibling (fleet mode — a dead pool's requeued
                # work flows across pool boundaries).  All-dead keeps the
                # original target: it queues and the restart drains it.
                for k in range(1, n):
                    w2 = (w + k) % n
                    if not down[w2]:
                        w = w2
                        break
            pool.charge(w, req)
            t0 = _time.perf_counter()  # simlint: ignore[R1] -- overhead meter, not sim time
            workers[w].scheduler.on_arrival(req, now)
            sched_time += _time.perf_counter() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            try_dispatch(w, now)

    ok = sum(1 for r in requests if r.ok)
    late = sum(1 for r in requests if r.finished is not None and not r.ok)
    dropped = sum(1 for r in requests if r.dropped is not None)
    # Unserved = no terminal state at all; scanned (not derived) so the
    # conservation invariant stays a real, falsifiable property.
    unserved = sum(
        1
        for r in requests
        if r.finished is None and r.dropped is None
        and r.rejected is None and r.failed is None
    )
    lat = np.array(
        [r.finished - r.release for r in requests if r.finished is not None]
    )
    return SimResult(
        n_total=len(requests),
        n_finished_ok=ok,
        n_finished_late=late,
        n_dropped=dropped,
        n_unserved=unserved,
        worker_busy=worker_busy_time,
        makespan_ms=last_time,
        latencies=lat,
        n_workers=n,
        peak_heap_size=peak_heap,
        sched_time_ms=sched_time * 1e3,
        n_decisions=n_decisions,
        n_batches=n_batches,
        n_rejected=n_rejected,
        n_failed=n_failed,
        n_retried=n_retried,
        truncated=truncated,
        n_model_loads=res.n_loads if res is not None else 0,
        n_model_evicts=res.n_evicts if res is not None else 0,
        model_load_ms=res.load_ms_total if res is not None else 0.0,
    )


def _wheel_width(group_times: Sequence[float]) -> float | None:
    """Bucket width for the DONE/WAKE wheel: a few mean arrival-group gaps
    (batch completions land roughly once per served burst of arrivals), or
    ``None`` → pure-heapq mode when the trace gives no usable spread."""
    if len(group_times) < 2:
        return None
    span = group_times[-1] - group_times[0]
    if not (span > 0.0) or not math.isfinite(span):
        return None
    return 4.0 * span / (len(group_times) - 1)


def _array_loop(
    requests: Sequence[Request],
    workers: list[Worker],
    pool: _Pool,
    pick: _PickFn,
    *,
    horizon: float | None,
    charge_scheduler_overhead: bool,
    fs: "FaultStateLike | None" = None,
    res: "ResidencyStateLike | None" = None,
    wall_budget_s: float = 0.0,
) -> SimResult:
    """The array-backed engine behind ``run_event_loop(engine="array")``.

    Identical observable behaviour to the scalar loop — same scheduler-hook
    call sequence, same timestamps, same rng consumption, same result
    fields — with the event plumbing swapped out:

    - ARRIVALs never touch a priority queue: the
      :class:`~repro.core.requeststore.RequestStore` presorts the trace
      into numpy columns with same-timestamp group boundaries, so the
      arrival source is a cursor over precomputed slices (the scalar loop
      pays a heap push **and** pop per request);
    - DONE/WAKE events live in the :class:`~repro.core.eventwheel.EventWheel`
      calendar queue and are drained a bucket at a time; a three-way merge
      (arrival cursor, in-hand bucket batch, wheel head) preserves the
      scalar loop's global ``(time, seq)`` order, with arrivals numbered
      ``0..n-1`` before any dynamic event so same-timestamp arrivals still
      come first;
    - per-request state writes go to the store's ``started``/``finished``
      columns via one fancy-indexed write per *batch*, and the end-of-run
      stats fold is one vectorized pass (the object attributes are still
      written at event time — schedulers like Clipper read
      ``req.started``/``req.finished`` inside ``on_batch_done``).

    ``peak_heap_size`` reports peak *pending events*: undelivered arrivals
    plus wheel occupancy (in-flight DONEs, live and superseded WAKEs) —
    the satellite fix for the bucketed path, where "Python heap length"
    no longer exists.
    """
    n = len(workers)
    store = RequestStore(requests)
    reqs = store.requests
    gstarts = store.group_starts
    gtimes = store.group_times
    ng = len(gtimes)
    n_req = len(reqs)
    started_col = store.started
    finished_col = store.finished

    wheel = EventWheel(bucket_ms=_wheel_width(gtimes))
    # Arrivals conceptually hold seqs 0..n-1 (assigned at store build, in
    # release order); dynamic events keep counting — so at equal times
    # arrivals sort first, exactly like the scalar heap's (time, seq) keys.
    seq = itertools.count(n_req)

    plan = fs.plan if fs is not None else None
    n_rejected = 0
    n_failed = 0
    n_retried = 0
    n_finished = 0
    truncated = False
    down = [False] * n
    # per-worker crash epoch — see the scalar loop's tombstone comment
    epoch = [0] * n
    # in-flight (batch, rows) payloads, maintained only under a fault plan
    running: list[tuple[Batch, object] | None] = [None] * n
    gate = plan is not None and plan.admission_floor > 0.0
    timeout_ms = plan.batch_timeout_ms if plan is not None else 0.0
    if fs is not None and fs.crashes:
        # initial crash draws in worker index order — seqs continue from
        # n_req exactly like the scalar loop's post-arrival pushes
        for w in range(n):
            wheel.push(fs.next_crash(w, 0.0), next(seq), _CRASH, w)

    peak_pending = n_req + len(wheel)
    arr_left = n_req  # arrivals not yet delivered to a scheduler
    worker_busy_time = 0.0
    sched_time = 0.0  # wall-clock seconds inside scheduler hooks
    n_decisions = 0
    n_batches = 0
    last_time = 0.0
    inflight: list[tuple[float, float] | None] = [None] * n  # (start, end)
    pending_wake: list[float | None] = [None] * n
    pc = _time.perf_counter
    delivers = [getattr(w.scheduler, "on_arrivals", None) for w in workers]
    # Columnar delivery hooks (DESIGN.md §10): a scheduler exposing
    # ``on_arrivals_cols(store, lo, hi, now)`` takes bulk arrivals as a
    # store row range instead of an object slice; ``on_arrival_row`` is
    # the idle-path single-row variant.  Schedulers without them get the
    # exact object-delivery sequence the scalar loop produces.
    delivers_cols = [
        getattr(w.scheduler, "on_arrivals_cols", None) for w in workers
    ]
    row_delivers = [
        getattr(w.scheduler, "on_arrival_row", None) for w in workers
    ]
    busy = pool.busy
    # Schedulers that read ``req.started``/``req.finished`` inside their
    # hooks (Clipper's AIMD, adaptive Clockwork) declare it via
    # ``reads_request_state``; unknown schedulers default to True for
    # safety.  When nobody in the pool reads mid-run state, the loop skips
    # the two per-request attribute writes on the hot path and flushes the
    # columns once at the end (``store.writeback()``) instead.
    live_state = any(
        getattr(w.scheduler, "reads_request_state", True) for w in workers
    )

    def try_dispatch(w: int, now: float) -> None:
        nonlocal worker_busy_time, peak_pending, sched_time, n_decisions
        if busy[w] or down[w]:
            return
        worker = workers[w]
        # simlint: ignore[R1] -- meters real scheduler overhead (reported, optionally charged as latency); the sim clock itself stays virtual
        t0 = pc()
        batch, wake = worker.scheduler.next_batch(now)
        # simlint: ignore[R1] -- closes the overhead meter opened above
        dt = pc() - t0
        sched_time += dt
        n_decisions += 1
        overhead = dt * 1e3 if charge_scheduler_overhead else 0.0
        if batch is not None and getattr(batch, "decode", False):
            # Resumable token-level execution — the array flavour of the
            # scalar loop's decode dispatch: identical hook order and
            # timestamps, with per-batch column writes for ``started``.
            if fs is not None:
                raise ValueError(
                    "decode (token-level) batches are not supported "
                    "under fault injection"
                )
            if res is not None:
                raise ValueError(
                    "decode (token-level) batches are not supported "
                    "under multi-model residency"
                )
            start = now + overhead
            rows = batch.rows
            if rows is None:
                # simlint: ignore[R5] -- one row-index list per dispatched decode batch
                rows = store.rows_for(batch.requests)
            if type(rows) is range and rows.step == 1:
                started_col[rows.start:rows.stop] = start
            else:
                rows = np.asarray(rows, dtype=np.intp)
                started_col[rows] = start
            run = _DecodeRun(
                batch, list(batch.requests), [int(x) for x in rows]
            )
            dur = _decode_step_dur(
                worker.executor, run.active, batch.requests, start
            )
            if pool.track_work:
                if live_state:
                    for r in batch.requests:
                        r.started = start
                        pool.discharge(w, r.rid)
                else:
                    for r in batch.requests:
                        pool.discharge(w, r.rid)
            elif live_state:
                for r in batch.requests:
                    r.started = start
            busy[w] = True
            worker_busy_time += dur
            inflight[w] = (start, start + dur)
            wheel.push(start + dur, next(seq), _STEP, (w, run, epoch[w]))
            pending = arr_left + len(wheel)
            if pending > peak_pending:
                peak_pending = pending
        elif batch is not None:
            start = now + overhead
            if res is not None:
                # Weights residency — charged exactly as in the scalar
                # loop: same acquire() call order, same stall arithmetic.
                if batch.model is None:
                    raise ValueError(
                        "residency-managed run dispatched a batch without "
                        "a model id (scheduler must stamp Batch.model)"
                    )
                stall = res.acquire(w, batch.model, start)
                start += stall
            else:
                stall = 0.0
            dur = worker.executor(batch, start)
            ev_kind = _DONE
            if fs is not None:
                dur = fs.straggle(dur)
                if 0.0 < timeout_ms < dur:
                    # overlong batch: aborted at the timeout deadline
                    dur = timeout_ms
                    ev_kind = _ABORT
            rows = batch.rows
            if rows is None:
                # simlint: ignore[R5] -- one row-index list per dispatched batch: the price of one fancy-indexed column write replacing per-request attribute churn
                rows = store.rows_for(batch.requests)
            if type(rows) is range and rows.step == 1:
                # rows-annotated batch (``on_arrivals_cols`` schedulers):
                # the column write is an O(1) slice assignment
                started_col[rows.start:rows.stop] = start
            else:
                rows = np.asarray(rows, dtype=np.intp)
                started_col[rows] = start
            if pool.track_work:
                if live_state:
                    for r in batch.requests:
                        r.started = start
                        pool.discharge(w, r.rid)
                else:
                    for r in batch.requests:
                        pool.discharge(w, r.rid)
            elif live_state:
                for r in batch.requests:
                    r.started = start
            busy[w] = True
            worker_busy_time += stall + dur
            inflight[w] = (start - stall, start + dur)
            if fs is not None:
                running[w] = (batch, rows)
            wheel.push(
                start + dur, next(seq), ev_kind, (w, batch, rows, epoch[w])
            )
            pending = arr_left + len(wheel)
            if pending > peak_pending:
                peak_pending = pending
        elif wake is not None and np.isfinite(wake) and wake > now:
            if pending_wake[w] is None or wake < pending_wake[w]:
                pending_wake[w] = wake
                wheel.push(wake, next(seq), _WAKE, w)
                pending = arr_left + len(wheel)
                if pending > peak_pending:
                    peak_pending = pending
        # the decision may have timed requests out (drop phase) — keep the
        # policy load signal honest
        pool.sweep_dropped(w)

    def work_remains() -> bool:
        # see the scalar loop: crashes only reschedule while unresolved
        # work exists anywhere, so the wheel can drain
        resolved = n_finished + n_rejected + n_failed
        for w_ in workers:
            resolved += w_.scheduler.n_timed_out  # type: ignore[attr-defined]
        return resolved < n_req

    def abort_batch(w: int, batch: Batch, rows, now: float) -> None:
        # Crash/timeout abort, array flavour: clear the started column
        # for the aborted rows (writeback must not resurrect a phantom
        # start), then run each request through the retry gate.
        nonlocal n_failed, n_retried, peak_pending
        assert fs is not None
        if type(rows) is range:
            started_col[rows.start:rows.stop] = np.nan
        else:
            started_col[np.asarray(rows, dtype=np.intp)] = np.nan
        sched = workers[w].scheduler
        for r in batch.requests:
            if live_state:
                r.started = None
            retry, t_retry = fs.retry_decision(sched, r, now)
            if retry:
                r.retries += 1
                n_retried += 1
                wheel.push(t_retry, next(seq), _RETRY, r)
            else:
                r.failed = now
                n_failed += 1
        pending = arr_left + len(wheel)
        if pending > peak_pending:
            peak_pending = pending

    wall_deadline = None
    if wall_budget_s > 0.0:
        # simlint: ignore[R1] -- wall-budget truncation is real elapsed time by design; the sim clock stays virtual
        wall_deadline = pc() + wall_budget_s
    n_events = 0
    gi = 0  # next arrival group
    buf: list = []  # in-hand wheel bucket (drained, partially consumed)
    bi = 0
    nbuf = 0
    ev: tuple = ()
    while True:
        n_events += 1
        if (
            wall_deadline is not None
            and not n_events & 1023
            # simlint: ignore[R1] -- wall-budget truncation check (real elapsed time by design)
            and pc() > wall_deadline
        ):
            # Out of wall-clock budget: stop at the last processed event
            # and clamp busy credit, mirroring the scalar loop.
            truncated = True
            for span in inflight:
                if span is not None and span[1] > last_time:
                    worker_busy_time -= span[1] - max(span[0], last_time)
            break
        # --- three-way merge: arrival cursor vs in-hand bucket vs wheel ---
        t_arr = gtimes[gi] if gi < ng else math.inf
        if bi < nbuf:
            ev = buf[bi]
            ekey = (ev[0], ev[1])
            take = _TAKE_BUF
            if wheel:
                wkey = wheel.peek_key()
                if wkey < ekey:
                    # an event pushed *during* the current bucket batch
                    # landed before its remaining entries — take it singly
                    ekey = wkey
                    take = _TAKE_ONE
        elif wheel:
            ekey = wheel.peek_key()
            take = _TAKE_BUCKET
        else:
            ekey = _NO_EVENT
            take = 0
        if t_arr <= ekey[0]:
            if t_arr == math.inf:
                break  # arrivals, bucket batch and wheel all exhausted
            now = t_arr
            if horizon is not None and now > horizon:
                last_time = horizon
                for span in inflight:
                    if span is not None and span[1] > horizon:
                        worker_busy_time -= span[1] - max(span[0], horizon)
                break
            last_time = now
            a, b = gstarts[gi], gstarts[gi + 1]
            gi += 1
            arr_left -= b - a
            if n == 1:
                # Single-worker fast path (the benchmark regime): no picks,
                # no charges.  While the worker is idle its share of the
                # burst is delivered one request at a time with a dispatch
                # attempt in between (scalar semantics: an urgent
                # head-of-burst request can grab the idle worker); the
                # moment it goes busy the rest of the group is ONE slice
                # handed to bulk ``on_arrivals`` — no per-request Python at
                # all, which is where the array engine's throughput lives.
                sched0 = workers[0].scheduler
                dr0 = row_delivers[0]
                if gate:
                    # Admission-gated single-worker path: per-request
                    # probes mirror the scalar loop exactly (idle-phase
                    # delivery with dispatch attempts, then one bulk
                    # object flush for the admitted busy-phase tail).
                    # Kept entirely off the fault-free fast path below.
                    assert fs is not None
                    # simlint: ignore[R5] -- one admitted-tail buffer per gated burst
                    held: list[Request] = []
                    for i in range(a, b):
                        req = reqs[i]
                        if not fs.admit(
                            sched0,
                            req,
                            now,
                            # mirrors the scalar backlog probe: len(held)
                            # plays pending_offset's role (this path never
                            # charges the pool)
                            getattr(sched0, "n_pending", 0)
                            + len(held)
                            + (1 if busy[0] else 0),
                        ):
                            req.rejected = now
                            n_rejected += 1
                            continue
                        if busy[0]:
                            held.append(req)
                            continue
                        t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                        if dr0 is not None:
                            dr0(store, i, now)
                        else:
                            sched0.on_arrival(req, now)
                        sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
                        try_dispatch(0, now)
                    if held:
                        deliver = delivers[0]
                        t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                        if deliver is not None:
                            deliver(held, now)
                        else:
                            for req in held:
                                sched0.on_arrival(req, now)
                        sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
                    continue
                i = a
                while i < b and not busy[0]:
                    t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                    if dr0 is not None:
                        dr0(store, i, now)
                    else:
                        sched0.on_arrival(reqs[i], now)
                    sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
                    i += 1
                    try_dispatch(0, now)
                if i < b:
                    dc0 = delivers_cols[0]
                    deliver = delivers[0]
                    t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                    if dc0 is not None:
                        # columnar bulk delivery: a row range, no slice
                        dc0(store, i, b, now)
                    elif deliver is not None:
                        # simlint: ignore[R5] -- one slice per (burst, busy) window, replacing per-request heap pops and scheduler calls
                        deliver(reqs[i:b], now)
                    else:
                        for req in reqs[i:b]:
                            sched0.on_arrival(req, now)
                    sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            else:
                # Multi-worker: route/deliver in arrival order, exactly as
                # the scalar loop does (same pick → same rng draws, same
                # charge/busy side-effect ordering, same bulk flush per
                # busy worker).
                # simlint: ignore[R5] -- one routing buffer per burst, replacing per-request scheduler calls with one bulk delivery per worker
                buffered: dict[int, list[Request]] = {}
                for i in range(a, b):
                    req = reqs[i]
                    w = pick(req, now, pool)
                    if gate and not fs.admit(
                        workers[w].scheduler,
                        req,
                        now,
                        getattr(workers[w].scheduler, "n_pending", 0)
                        + pool.pending_offset[w]
                        + (1 if busy[w] else 0),
                    ):
                        # shed at the front door (pick already consumed
                        # its rng draws — same stream with the gate off)
                        req.rejected = now
                        n_rejected += 1
                        continue
                    pool.charge(w, req)
                    if busy[w]:
                        # simlint: ignore[R5] -- group list created once per (burst, worker), not per request
                        buffered.setdefault(w, []).append(req)
                        pool.pending_offset[w] += 1
                    else:
                        t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                        workers[w].scheduler.on_arrival(req, now)
                        sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
                        try_dispatch(w, now)
                for w, group in buffered.items():
                    pool.pending_offset[w] = 0
                    deliver = delivers[w]
                    t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
                    if deliver is not None:
                        deliver(group, now)
                    else:
                        sched = workers[w].scheduler
                        for req in group:
                            sched.on_arrival(req, now)
                    sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            continue
        # --- dynamic event (DONE/WAKE) ---
        if take == _TAKE_BUF:
            now, _s, kind, payload = ev
            bi += 1
        elif take == _TAKE_BUCKET:
            # refill the in-hand batch with the next wheel bucket — the
            # batched DONE/WAKE path: one calendar-bucket drain amortizes
            # the queue maintenance over every event in the bucket
            buf = wheel.pop_bucket()
            bi = 1
            nbuf = len(buf)
            now, _s, kind, payload = buf[0]
        else:  # _TAKE_ONE
            now, _s, kind, payload = wheel.pop()
        if horizon is not None and now > horizon:
            last_time = horizon
            for span in inflight:
                if span is not None and span[1] > horizon:
                    worker_busy_time -= span[1] - max(span[0], horizon)
            break
        last_time = now
        if kind == _DONE:
            w, batch, rows, ep = payload
            if ep != epoch[w]:
                continue  # tombstone: the worker crashed under this batch
            busy[w] = False
            inflight[w] = None
            if fs is not None:
                running[w] = None
            n_batches += 1
            n_finished += len(batch.requests)
            if type(rows) is range:
                finished_col[rows.start:rows.stop] = now
                alone = store.true_time[rows.start:rows.stop].tolist()
            else:
                finished_col[rows] = now
                # simlint: ignore[R5] -- one alone-times list per completed batch (feedback path), not per request
                alone = store.true_time[rows].tolist()
            if live_state:
                for r in batch.requests:
                    r.finished = now
            t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
            workers[w].scheduler.on_batch_done(batch, now, alone)
            sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            try_dispatch(w, now)
        elif kind == _STEP:
            # One decode iteration — mirrors the scalar loop's handler
            # exactly (same hook order, same timestamps), with ``finished``
            # landing in the store column per step instead of per object.
            w, run, ep = payload
            if ep != epoch[w]:
                continue  # tombstone (kept uniform with _DONE)
            finished, fin_rows = _advance_decode(run, now)
            n_finished += len(finished)
            if fin_rows:
                finished_col[np.asarray(fin_rows, dtype=np.intp)] = now
            if live_state:
                for r in finished:
                    r.finished = now
            t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
            joined = workers[w].scheduler.on_decode_step(
                finished, len(run.active), now
            )
            sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            n_decisions += 1
            if joined:
                # simlint: ignore[R5] -- one row-index list per join group
                jrows = store.rows_for(joined)
                started_col[np.asarray(jrows, dtype=np.intp)] = now
                run.rows.extend(int(x) for x in jrows)
                if pool.track_work:
                    if live_state:
                        for r in joined:
                            r.started = now
                            pool.discharge(w, r.rid)
                    else:
                        for r in joined:
                            pool.discharge(w, r.rid)
                elif live_state:
                    for r in joined:
                        r.started = now
                run.active.extend(joined)
            if run.active:
                dur = _decode_step_dur(
                    workers[w].executor, run.active, joined, now
                )
                worker_busy_time += dur
                inflight[w] = (now, now + dur)
                wheel.push(now + dur, next(seq), _STEP, (w, run, ep))
                pending = arr_left + len(wheel)
                if pending > peak_pending:
                    peak_pending = pending
            else:
                n_batches += 1
                busy[w] = False
                inflight[w] = None
                try_dispatch(w, now)
            # the admission hook may also have timed requests out
            pool.sweep_dropped(w)
        elif kind == _WAKE:
            w = payload
            if pending_wake[w] is not None and now >= pending_wake[w]:
                pending_wake[w] = None
            try_dispatch(w, now)
        elif kind == _ABORT:
            w, batch, rows, ep = payload
            if ep != epoch[w]:
                continue  # the worker crashed before the timeout fired
            busy[w] = False
            inflight[w] = None
            running[w] = None
            abort_batch(w, batch, rows, now)
            try_dispatch(w, now)
        elif kind == _CRASH:
            w = payload
            if work_remains():
                # see the scalar loop: epoch bump tombstones the pending
                # DONE/ABORT, the in-flight batch aborts, restart follows
                epoch[w] += 1
                down[w] = True
                span = inflight[w]
                if span is not None:
                    worker_busy_time -= span[1] - max(span[0], now)
                    inflight[w] = None
                    busy[w] = False
                    doomed = running[w]
                    running[w] = None
                    assert doomed is not None
                    abort_batch(w, doomed[0], doomed[1], now)
                wheel.push(
                    now + plan.restart_delay_ms, next(seq), _RESTART, w
                )
                pending = arr_left + len(wheel)
                if pending > peak_pending:
                    peak_pending = pending
        elif kind == _RESTART:
            w = payload
            down[w] = False
            if work_remains():
                wheel.push(fs.next_crash(w, now), next(seq), _CRASH, w)
                pending = arr_left + len(wheel)
                if pending > peak_pending:
                    peak_pending = pending
            try_dispatch(w, now)
        else:  # _RETRY
            req = payload
            w = pick(req, now, pool) if n > 1 else 0
            if down[w]:
                # dead-target re-route — see the scalar loop
                for k in range(1, n):
                    w2 = (w + k) % n
                    if not down[w2]:
                        w = w2
                        break
            pool.charge(w, req)
            t0 = pc()  # simlint: ignore[R1] -- overhead meter, not sim time
            workers[w].scheduler.on_arrival(req, now)
            sched_time += pc() - t0  # simlint: ignore[R1] -- overhead meter, not sim time
            try_dispatch(w, now)

    if not live_state:
        # Mid-run object writes were skipped — flush the state columns
        # onto the Request objects so callers see the scalar loop's exact
        # post-run per-object state.
        store.writeback()
    # Drop-free fast path: every ``req.dropped = ...`` write in the repo's
    # schedulers is paired with an ``n_timed_out`` increment, so a pool
    # whose schedulers all expose the counter at zero provably dropped
    # nothing and the O(n) per-object dropped scan can be skipped.
    no_drops = all(
        getattr(w_.scheduler, "n_timed_out", None) == 0 for w_ in workers
    )
    ok, late, dropped, unserved, lat = store.fold_stats(
        no_drops=no_drops, n_off_ledger=n_rejected + n_failed
    )
    return SimResult(
        n_total=n_req,
        n_finished_ok=ok,
        n_finished_late=late,
        n_dropped=dropped,
        n_unserved=unserved,
        worker_busy=worker_busy_time,
        makespan_ms=last_time,
        latencies=lat,
        n_workers=n,
        peak_heap_size=peak_pending,
        sched_time_ms=sched_time * 1e3,
        n_decisions=n_decisions,
        n_batches=n_batches,
        n_rejected=n_rejected,
        n_failed=n_failed,
        n_retried=n_retried,
        truncated=truncated,
        n_model_loads=res.n_loads if res is not None else 0,
        n_model_evicts=res.n_evicts if res is not None else 0,
        model_load_ms=res.load_ms_total if res is not None else 0.0,
    )


def simulate(
    requests: Sequence[Request],
    scheduler: SchedulerLike,
    executor: Executor,
    horizon: float | None = None,
    charge_scheduler_overhead: bool = False,
    engine: str = "scalar",
    faults: "FaultPlanLike | None" = None,
    wall_budget_s: float = 0.0,
) -> SimResult:
    """The single-worker evaluation harness (§5) — the 1-worker case of
    :func:`run_event_loop`, kept as the stable entry point."""
    return run_event_loop(
        requests,
        [Worker(scheduler, executor)],
        policy="round_robin",
        horizon=horizon,
        charge_scheduler_overhead=charge_scheduler_overhead,
        engine=engine,
        faults=faults,
        wall_budget_s=wall_budget_s,
    )
