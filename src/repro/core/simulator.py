"""Discrete-event serving simulator (paper §5 methodology).

Drives any scheduler with the simulator interface against an open-loop
arrival trace.  The worker executes one batch at a time, non-preemptively
(§3.1); the ground-truth batch execution time follows the padding model
``l_B = c0 + c1·k·max_r l_r`` (Eq. 3–4) via a pluggable *executor* so the
same loop can drive either modelled execution (for the paper's evaluation)
or real JAX execution (``repro.serving.engine``).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Protocol, Sequence

import numpy as np

from .distributions import BatchLatencyModel
from .request import Request
from .scheduler import Batch

__all__ = ["Executor", "ModelExecutor", "SimResult", "simulate"]


class Executor(Protocol):
    def __call__(self, batch: Batch, now: float) -> float:
        """Return the batch execution time in ms."""


@dataclasses.dataclass
class ModelExecutor:
    """Ground-truth execution following the paper's padding model."""

    latency_model: BatchLatencyModel
    jitter: float = 0.0  # multiplicative noise std (hardware non-determinism)
    seed: int = 0

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def __call__(self, batch: Batch, now: float) -> float:
        t = self.latency_model.batch_time([r.true_time for r in batch.requests])
        if self.jitter > 0:
            t *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        return t


@dataclasses.dataclass
class SimResult:
    n_total: int
    n_finished_ok: int
    n_finished_late: int
    n_dropped: int
    n_unserved: int
    worker_busy: float
    makespan: float
    latencies: np.ndarray

    @property
    def finish_rate(self) -> float:
        return self.n_finished_ok / max(1, self.n_total)

    @property
    def utilization(self) -> float:
        return self.worker_busy / max(self.makespan, 1e-9)

    def summary(self) -> str:
        return (
            f"finish_rate={self.finish_rate:.3f} ok={self.n_finished_ok} "
            f"late={self.n_finished_late} dropped={self.n_dropped} "
            f"unserved={self.n_unserved} util={self.utilization:.2f}"
        )


_ARRIVAL, _DONE, _WAKE = 0, 1, 2


def simulate(
    requests: Sequence[Request],
    scheduler,
    executor: Executor,
    horizon: float | None = None,
    charge_scheduler_overhead: bool = False,
) -> SimResult:
    """Run the event loop until all requests are resolved (or ``horizon``).

    ``charge_scheduler_overhead=True`` bills the *measured wall-clock* cost
    of each scheduler decision to the virtual clock (used by the Fig.-14
    overhead study: with ms-scale requests, scheduling time itself starts
    to matter)."""
    import time as _time

    requests = sorted(requests, key=lambda r: r.release)
    events: list[tuple[float, int, int, object]] = []
    seq = itertools.count()
    for r in requests:
        heapq.heappush(events, (r.release, next(seq), _ARRIVAL, r))

    busy = False
    worker_busy_time = 0.0
    last_time = 0.0
    pending_wake: float | None = None

    def try_dispatch(now: float) -> None:
        nonlocal busy, worker_busy_time, pending_wake
        if busy:
            return
        t0 = _time.perf_counter()
        batch, wake = scheduler.next_batch(now)
        overhead = (
            (_time.perf_counter() - t0) * 1e3 if charge_scheduler_overhead else 0.0
        )
        if batch is not None:
            start = now + overhead
            dur = executor(batch, start)
            for r in batch.requests:
                r.started = start
            busy = True
            worker_busy_time += dur
            heapq.heappush(events, (start + dur, next(seq), _DONE, batch))
        elif wake is not None and np.isfinite(wake) and wake > now:
            if pending_wake is None or wake < pending_wake:
                pending_wake = wake
                heapq.heappush(events, (wake, next(seq), _WAKE, None))

    while events:
        now, _, kind, payload = heapq.heappop(events)
        last_time = now
        if horizon is not None and now > horizon:
            break
        if kind == _ARRIVAL:
            scheduler.on_arrival(payload, now)
            try_dispatch(now)
        elif kind == _DONE:
            busy = False
            batch: Batch = payload
            for r in batch.requests:
                r.finished = now
            scheduler.on_batch_done(batch, now, [r.true_time for r in batch.requests])
            try_dispatch(now)
        else:  # _WAKE
            if pending_wake is not None and now >= pending_wake:
                pending_wake = None
            try_dispatch(now)

    ok = sum(1 for r in requests if r.ok)
    late = sum(1 for r in requests if r.finished is not None and not r.ok)
    dropped = sum(1 for r in requests if r.dropped is not None)
    unserved = sum(
        1 for r in requests if r.finished is None and r.dropped is None
    )
    lat = np.array(
        [r.finished - r.release for r in requests if r.finished is not None]
    )
    return SimResult(
        n_total=len(requests),
        n_finished_ok=ok,
        n_finished_late=late,
        n_dropped=dropped,
        n_unserved=unserved,
        worker_busy=worker_busy_time,
        makespan=last_time,
        latencies=lat,
    )
