"""Discrete-event serving simulator (paper §5 methodology) — compatibility
surface.

The actual loop lives in :mod:`repro.core.eventloop`, the unified
multi-worker engine; :func:`simulate` is its 1-worker case.  This module
keeps the historical import path (``repro.core.simulator``) stable for
callers and re-exports the executor/result types that used to be defined
here.
"""

from __future__ import annotations

from .eventloop import Executor, ModelExecutor, SimResult, simulate

__all__ = ["Executor", "ModelExecutor", "SimResult", "simulate"]
