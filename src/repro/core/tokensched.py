"""Token-level (continuous batching) schedulers — DESIGN.md §12.

Autoregressive decode makes the *output length* the hidden quantity: a
request's total work is ``out_tokens`` decode iterations, revealed only when
the model emits EOS.  The token-mode analogue of the paper's unpredictable
``true_time`` is therefore the per-app output-length distribution, and the
Eq.-2/3 machinery transfers: a decode step over ``k`` active requests costs
``d0 + d1·k`` (the Eq.-3 batch-latency analogue, with prefill piggybacked at
``prefill_per_token`` per prompt token), and a request's remaining work is
the conditional expectation ``E[L − d | L > d]`` of its length distribution
given ``d`` tokens already decoded
(:meth:`~repro.core.distributions.EmpiricalDistribution.expected_remaining`).

Two schedulers share one contract (``TokenSchedulerLike`` in
:mod:`repro.core.eventloop`):

- :class:`FcfsTokenScheduler` — length-blind continuous batching: admit in
  arrival order whenever a slot is free, never drop.  The Orca-style
  baseline.
- :class:`LengthAwareTokenScheduler` — learns per-app output-length
  histograms online from observed EOS events, admits
  shortest-expected-first under a per-request feasibility test against the
  TTFT/TPOT-derived deadline (the Eq.-2 admission analogue), protects the
  running batch from joins that would blow the actives' token budgets, and
  early-drops requests that can no longer finish in time even alone
  (Algorithm-1 drop-phase analogue).

Neither scheduler reads ``out_tokens``/``slo``/``deadline`` — those derive
from the hidden output length (§3.1 partial-information constraint);
visible inputs are ``release``, ``prompt_tokens``, ``app_id``,
``tokens_done`` and the configured SLO constants.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .distributions import EmpiricalDistribution
from .request import Request
from .scheduler import Batch

__all__ = [
    "TokenSchedConfig",
    "FcfsTokenScheduler",
    "LengthAwareTokenScheduler",
    "token_deadline",
]


@dataclasses.dataclass(frozen=True)
class TokenSchedConfig:
    """Shared knobs for token-level schedulers.

    ``d0``/``d1``/``prefill_per_token`` mirror the executor's decode cost
    model (profiled offline, like handing ORLOJ the Eq.-3 fit); the TTFT /
    TPOT SLOs define each request's implied deadline
    ``release + ttft + tpot·(L−1)`` — with ``L`` hidden, the length-aware
    scheduler substitutes its learned expectation.
    """

    max_batch: int = 16
    ttft_slo_ms: float = 500.0
    tpot_slo_ms: float = 50.0
    d0: float = 2.0
    d1: float = 0.25
    prefill_per_token: float = 0.02
    n_bins: int = 12
    # Fallback mean output length for apps with no history yet.
    default_len: float = 32.0
    # Refresh an app's learned histogram every N completions.
    rebuild_every: int = 32
    # Scale on the feasibility estimate in the drop phase (>1 drops later).
    drop_safety: float = 1.0


def token_deadline(cfg: TokenSchedConfig, release: float, n_tokens: float) -> float:
    """Implied deadline of a request with ``n_tokens`` output tokens:
    first token within TTFT, each subsequent token within TPOT."""
    return release + cfg.ttft_slo_ms + cfg.tpot_slo_ms * max(n_tokens - 1.0, 0.0)


class _TokenSchedulerBase:
    """Queue plumbing shared by both token schedulers."""

    reads_request_state = False

    def __init__(self, cfg: TokenSchedConfig | None = None) -> None:
        self.cfg = cfg or TokenSchedConfig()
        self._queue: list[Request] = []  # arrival order
        self.n_timed_out = 0

    # -- arrivals ------------------------------------------------------
    def on_arrival(self, req: Request, now: float) -> None:
        self._queue.append(req)

    def on_arrivals(self, reqs: Sequence[Request], now: float) -> None:
        self._queue.extend(reqs)

    def on_arrivals_cols(self, store, lo: int, hi: int, now: float) -> None:
        self._queue.extend(store.requests[lo:hi])

    # -- atomic-batch hook: never fires in token mode ------------------
    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        raise TypeError(
            "token schedulers emit decode batches only; on_batch_done is "
            "an atomic-batch hook and must never be called for them"
        )

    @property
    def n_pending(self) -> int:
        return len(self._queue)


class FcfsTokenScheduler(_TokenSchedulerBase):
    """Length-blind continuous batching: FCFS admission into free slots.

    Joins waiters whenever the running batch has a free slot, in strict
    arrival order, and never drops — the Orca-style baseline the
    length-aware scheduler is judged against.
    """

    name = "token_fcfs"

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        if not self._queue:
            return None, None
        take = self._queue[: self.cfg.max_batch]
        del self._queue[: len(take)]
        return Batch(take, len(take), decode=True), None

    def on_decode_step(
        self, finished: Sequence[Request], n_active: int, now: float
    ) -> list[Request]:
        free = self.cfg.max_batch - n_active
        if free <= 0 or not self._queue:
            return []
        take = self._queue[:free]
        del self._queue[: len(take)]
        return take


class LengthAwareTokenScheduler(_TokenSchedulerBase):
    """Distribution-aware continuous batching (the token-mode ORLOJ).

    Admission is shortest-expected-length-first under a feasibility test:
    a waiter joins only if, at the post-join batch size ``k``, its own
    estimated finish ``now + prefill + (d0 + d1·k)·E[L]`` meets its implied
    TTFT/TPOT deadline *and* every already-active request still meets its
    own (using ``E[L − d | L > d]`` for remaining work).  Waiters that
    cannot finish in time even alone are dropped immediately (Algorithm-1
    drop-phase analogue), freeing queue pressure for feasible work.
    """

    name = "token_orloj"

    def __init__(
        self,
        cfg: TokenSchedConfig | None = None,
        initial_len_dists: dict[str, EmpiricalDistribution] | None = None,
    ) -> None:
        super().__init__(cfg)
        self._len_dists: dict[str, EmpiricalDistribution] = dict(
            initial_len_dists or {}
        )
        self._default_dist = EmpiricalDistribution.delta(self.cfg.default_len)
        self._len_obs: dict[str, list[float]] = {}
        self._active: list[Request] = []

    # -- learned output-length model -----------------------------------
    def _dist(self, app_id: str) -> EmpiricalDistribution:
        return self._len_dists.get(app_id, self._default_dist)

    def _observe(self, req: Request) -> None:
        obs = self._len_obs.setdefault(req.app_id, [])
        obs.append(float(req.tokens_done))
        if len(obs) % self.cfg.rebuild_every == 0:
            self._len_dists[req.app_id] = EmpiricalDistribution.from_samples(
                obs[-512:], n_bins=self.cfg.n_bins
            )

    def _expected_len(self, req: Request) -> float:
        return max(self._dist(req.app_id).mean(), 1.0)

    def _expected_remaining(self, req: Request) -> float:
        """``E[L − d | L > d]`` for an active request — the per-step
        conditional view that replaces a static length estimate.  The
        request is still decoding, so remaining work is at least one
        token even past the distribution's observed support."""
        return max(
            self._dist(req.app_id).expected_remaining(float(req.tokens_done)), 1.0
        )

    def _deadline_est(self, req: Request, total_len: float) -> float:
        return token_deadline(self.cfg, req.release, total_len)

    # -- admission (shared by dispatch and per-step join) --------------
    def _step_time(self, k: int) -> float:
        return self.cfg.d0 + self.cfg.d1 * k

    def _hopeless(self, req: Request, now: float) -> bool:
        """Cannot finish in time even decoding alone (k = 1)."""
        exp_len = self._expected_len(req)
        fin = (
            now
            + self.cfg.prefill_per_token * req.prompt_tokens
            + self._step_time(1) * exp_len * self.cfg.drop_safety
        )
        return fin > self._deadline_est(req, exp_len)

    def _admit(self, active: Sequence[Request], now: float) -> list[Request]:
        """Drop hopeless waiters, then admit shortest-expected-first while
        the candidate and every active request stay feasible."""
        keep: list[Request] = []
        for r in self._queue:
            if self._hopeless(r, now):
                r.dropped = now
                self.n_timed_out += 1
            else:
                keep.append(r)
        self._queue = keep
        if not keep:
            return []

        # Active requests' remaining-token budgets: deadline estimate uses
        # tokens already produced plus conditional expected remainder.
        act_rem = [self._expected_remaining(a) for a in active]
        act_dl = [
            self._deadline_est(a, a.tokens_done + rem)
            for a, rem in zip(active, act_rem)
        ]

        order = sorted(
            range(len(keep)),
            key=lambda i: (self._expected_len(keep[i]), keep[i].rid),
        )
        admitted: list[Request] = []
        adm_idx: set[int] = set()
        adm_len: list[float] = []
        k = len(active)
        for i in order:
            if k >= self.cfg.max_batch:
                break
            cand = keep[i]
            k_new = k + 1
            s = self._step_time(k_new)
            exp_len = self._expected_len(cand)
            fin = now + self.cfg.prefill_per_token * cand.prompt_tokens + s * exp_len
            if fin > self._deadline_est(cand, exp_len):
                continue  # infeasible at this batch size; stays queued
            if any(now + s * rem > dl for rem, dl in zip(act_rem, act_dl)):
                break  # joining would blow an active request's budget
            if any(now + s * el > self._deadline_est(a, el)
                   for a, el in zip(admitted, adm_len)):
                continue  # would blow an earlier joiner's budget
            admitted.append(cand)
            adm_len.append(exp_len)
            adm_idx.add(i)
            k = k_new
        if adm_idx:
            self._queue = [r for j, r in enumerate(keep) if j not in adm_idx]
        return admitted

    # -- scheduler hooks -----------------------------------------------
    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        if not self._queue:
            return None, None
        admitted = self._admit((), now)
        if not admitted:
            return None, None
        self._active = list(admitted)
        return Batch(admitted, len(admitted), decode=True), None

    def on_decode_step(
        self, finished: Sequence[Request], n_active: int, now: float
    ) -> list[Request]:
        if finished:
            done = {r.rid for r in finished}
            for r in finished:
                self._observe(r)
            self._active = [a for a in self._active if a.rid not in done]
        joined = self._admit(self._active, now)
        self._active.extend(joined)
        return joined
