"""ORLOJ core: distribution-aware batch scheduling for dynamic DNN serving.

The paper's primary contribution (Yu et al., 2022) as a composable library:

- :mod:`repro.core.distributions` — empirical execution-time distributions,
  max order statistics (Eq. 6/8), the batch latency model (Eq. 3–5).
- :mod:`repro.core.priority` — the time-varying batch-aware priority score
  (Eq. 2) with milestone/overflow handling (§4.4).
- :mod:`repro.core.hull` — the O(log² n) dynamic convex-hull priority queue.
- :mod:`repro.core.scheduler` — Algorithm 1.
- :mod:`repro.core.baselines` — Clockwork/Nexus/Clipper/EDF-style baselines.
- :mod:`repro.core.profiler` — the long-term feedback loop (§3.2).
- :mod:`repro.core.eventloop` — the unified multi-worker discrete-event
  engine (§5 evaluation harness = 1 worker; §3.1 replica pools = N workers).
"""

from .baselines import (
    BASELINES,
    ClipperScheduler,
    ClockworkScheduler,
    EDFScheduler,
    NexusScheduler,
)
from .distributions import (
    BatchLatencyModel,
    EmpiricalDistribution,
    hetero_max,
    iid_max,
    mixture,
    ozbey_max_pdf,
)
from .hull import HullQueue
from .priority import DEFAULT_B, BinScoreModel, Score
from .profiler import OnlineProfiler, ProfilerConfig
from .request import PiecewiseStepCost, Request, StepCost
from .scheduler import (
    Batch,
    MultiModelOrlojScheduler,
    OrlojScheduler,
    SchedulerConfig,
)
from .eventloop import (
    DISPATCH_POLICIES,
    ModelExecutor,
    SchedulerLike,
    SimResult,
    Worker,
    run_event_loop,
    simulate,
)

__all__ = [
    "BatchLatencyModel",
    "EmpiricalDistribution",
    "hetero_max",
    "iid_max",
    "mixture",
    "ozbey_max_pdf",
    "HullQueue",
    "DEFAULT_B",
    "BinScoreModel",
    "Score",
    "OnlineProfiler",
    "ProfilerConfig",
    "PiecewiseStepCost",
    "Request",
    "StepCost",
    "Batch",
    "MultiModelOrlojScheduler",
    "OrlojScheduler",
    "SchedulerConfig",
    "BASELINES",
    "ClipperScheduler",
    "ClockworkScheduler",
    "EDFScheduler",
    "NexusScheduler",
    "DISPATCH_POLICIES",
    "ModelExecutor",
    "SchedulerLike",
    "SimResult",
    "Worker",
    "run_event_loop",
    "simulate",
]
