"""Requests, applications and SLO cost functions (paper §3.1, §4.1, App. B).

A request is defined by its *release time* and *deadline* (release + SLO) and
has a hidden minimum *execution time* (time to execute alone).  The SLO cost
function is a step: finishing after the deadline incurs penalty ``c``
(Fig. 5).  Appendix B generalises to piecewise-step functions, which
decompose into a sum of single steps — we implement that decomposition.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

__all__ = ["StepCost", "PiecewiseStepCost", "Request"]

_req_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class StepCost:
    """Single-step SLO cost: 0 before ``deadline``, ``cost`` after (Fig. 5)."""

    deadline: float
    cost: float = 1.0

    def __call__(self, t: float) -> float:
        return self.cost if t > self.deadline else 0.0

    def steps(self) -> list["StepCost"]:
        return [self]


@dataclasses.dataclass(frozen=True)
class PiecewiseStepCost:
    """Multi-step SLO cost function (Appendix B).

    ``deadlines`` d1 < d2 < ... with cumulative costs c1 < c2 < ...
    Decomposes into single steps with incremental costs
    (d1, c1), (d2, c2 - c1), ...; priority scores are computed per step and
    summed.
    """

    deadlines: tuple[float, ...]
    costs: tuple[float, ...]

    def __post_init__(self) -> None:
        if len(self.deadlines) != len(self.costs) or not self.deadlines:
            raise ValueError("deadlines and costs must be equal-length, non-empty")
        if any(b <= a for a, b in zip(self.deadlines, self.deadlines[1:])):
            raise ValueError("deadlines must be strictly increasing")
        if any(b <= a for a, b in zip(self.costs, self.costs[1:])):
            raise ValueError("costs must be strictly increasing")

    def __call__(self, t: float) -> float:
        total = 0.0
        for d, c in zip(self.deadlines, self.costs):
            if t > d:
                total = c
        return total

    def steps(self) -> list[StepCost]:
        out = []
        prev = 0.0
        for d, c in zip(self.deadlines, self.costs):
            out.append(StepCost(d, c - prev))
            prev = c
        return out


@dataclasses.dataclass(slots=True)
class Request:
    """An inference request.

    ``true_time`` is the ground-truth standalone execution time.  It is
    *hidden* from every scheduler (partial-information constraint, §3.1);
    only the simulator/executor reads it.  Schedulers see only ``app_id``,
    ``release``, ``deadline`` and the learned per-app distribution.

    Slotted: a 10⁵–10⁶-request trace materializes one object per request
    even under the array engine (they remain the scheduler-facing
    currency), so per-instance dicts would dominate trace memory — and the
    simulator's bookkeeping writes (``started``/``finished``/``dropped``)
    are measurably faster through slot descriptors.
    """

    app_id: str
    release: float
    slo: float
    true_time: float
    rid: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    cost: float = 1.0
    extra_deadlines: tuple[tuple[float, float], ...] = ()
    payload: Any = None  # e.g. token ids for the real JAX engine
    # Multi-model serving (DESIGN.md §13): which zoo model this request
    # targets.  ``None`` (every single-model trace) keeps the residency
    # tier fully inert.  Visible to schedulers and dispatch policies —
    # clients know what model they are calling.
    model_id: str | None = None

    # Token-level (continuous batching) fields.  ``prompt_tokens`` is
    # visible to schedulers (the prompt is known at admission);
    # ``out_tokens`` is the hidden ground-truth output length — the
    # data-dependent quantity nobody knows until EOS, the token-mode
    # analogue of ``true_time`` (§3.1 partial-information constraint).
    # In token mode ``slo``/``deadline`` are *derived from* ``out_tokens``
    # (slo = TTFT + TPOT·(out_tokens−1)), so they are hidden from token
    # schedulers by the same convention (DESIGN.md §12).
    prompt_tokens: int = 0
    out_tokens: int = 0

    # Bookkeeping filled in by the simulator / engine.  Exactly one of
    # ``finished``/``dropped``/``rejected``/``failed`` is set at end of
    # run (or none: unserved) — the conservation invariant the fault
    # tier property-tests.
    started: float | None = None
    finished: float | None = None
    dropped: float | None = None
    # Fault-tier terminal states: rejected at admission (never queued),
    # or failed after a crash/timeout abort exhausted the retry gate.
    rejected: float | None = None
    failed: float | None = None
    retries: int = 0
    # Token-mode bookkeeping, written by the decode-step machinery:
    # ``tokens_done`` advances once per decode iteration; ``first_token``
    # is the virtual time the first output token completed (TTFT anchor).
    tokens_done: int = 0
    first_token: float | None = None

    @property
    def deadline(self) -> float:
        return self.release + self.slo

    def cost_fn(self) -> StepCost | PiecewiseStepCost:
        if not self.extra_deadlines:
            return StepCost(self.deadline, self.cost)
        ds = (self.deadline,) + tuple(self.release + d for d, _ in self.extra_deadlines)
        cs = (self.cost,) + tuple(c for _, c in self.extra_deadlines)
        return PiecewiseStepCost(ds, cs)

    @property
    def ok(self) -> bool:
        return self.finished is not None and self.finished <= self.deadline

    def __hash__(self) -> int:
        return self.rid

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Request) and other.rid == self.rid
