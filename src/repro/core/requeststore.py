"""Struct-of-arrays request store (the static half of the array engine's
event sourcing; DESIGN.md §10).

A trace's arrivals are fully known before the simulation starts, so the
array-backed event loop never materializes them as heap entries.
:class:`RequestStore` is built **once per trace**: the request sequence is
stable-sorted by release time and its per-request scalars become numpy
columns — ``release``/``deadline``/``true_time`` read-only inputs,
``started``/``finished`` NaN-initialized state columns the loop writes
with fancy indexing per *batch*, not per request.  Same-timestamp groups
(the coalescing windows the bulk ``on_arrivals`` path feeds on) are
precomputed as plain-int boundaries, so the loop's arrival cursor is two
list indexes per group instead of a heap pop per event.

The :class:`~repro.core.request.Request` objects themselves stay around
(``self.requests``, in store order): they are the scheduler-facing
currency — ``on_arrivals`` delivery, drop-phase bookkeeping (schedulers
write ``req.dropped``), batch payloads for the executor.  What the store
eliminates is the *event engine's* per-request object churn: heap tuples,
per-event attribute writes, and the end-of-run per-object stats pass
(counts/latencies fold vectorized from the columns instead).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .request import Request

__all__ = ["RequestStore"]


class RequestStore:
    """Columnar view over one trace, sorted by release time (stable)."""

    __slots__ = ("requests", "release", "deadline", "true_time", "started",
                 "finished", "group_starts", "group_times", "_row",
                 "_rowbase")

    def __init__(self, requests: Sequence[Request]) -> None:
        n = len(requests)
        # One listcomp per column (C-speed np.array over a plain list beats
        # fromiter-over-generator ~3x; the store build is itself on the
        # per-trace critical path at 10⁵–10⁶ requests).
        release = np.array([r.release for r in requests], dtype=np.float64)
        if n == 0 or bool(np.all(release[:-1] <= release[1:])):
            # Already in release order (every generated trace is — arrivals
            # come from a cumsum): skip the argsort and the reorder pass.
            self.requests = list(requests)
            self.release = release
        else:
            # Stable sort ≡ ``sorted(requests, key=lambda r: r.release)`` —
            # the scalar loop's ordering, so stats fold identically.
            order = np.argsort(release, kind="stable")
            self.requests = [requests[i] for i in order.tolist()]
            self.release = release[order]
        self.true_time = np.array(
            [r.true_time for r in self.requests], dtype=np.float64
        )
        slo = np.array([r.slo for r in self.requests], dtype=np.float64)
        # Same float op as ``Request.deadline`` (release + slo): comparisons
        # against the column are bit-identical to the property.
        self.deadline = self.release + slo
        self.started = np.full(n, np.nan)
        self.finished = np.full(n, np.nan)
        # Same-timestamp group boundaries: group g is the half-open row
        # range [group_starts[g], group_starts[g+1]) and every row in it
        # bears release == group_times[g].  Plain Python ints/floats —
        # the loop indexes these every iteration and ``list[int]`` beats
        # numpy scalar extraction on that path.
        if n:
            change = np.flatnonzero(np.diff(self.release)) + 1
            starts = np.concatenate(([0], change, [n]))
        else:
            starts = np.array([0], dtype=np.intp)
        self.group_starts: list[int] = [int(i) for i in starts]
        self.group_times: list[float] = [
            float(t) for t in self.release[starts[:-1]]
        ]
        # (rid - base) -> row, built lazily on the first batch dispatch: an
        # overloaded trace dispatches few of its requests, and the eager
        # map build was a measurable slice of store construction.
        self._row: list[int] | dict[int, int] | None = None
        self._rowbase = 0

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def n_groups(self) -> int:
        return len(self.group_times)

    def group(self, g: int) -> list[Request]:
        """The requests of same-timestamp group ``g`` (store order)."""
        return self.requests[self.group_starts[g]:self.group_starts[g + 1]]

    def rows_for(self, requests: Sequence[Request]) -> list[int]:
        """Store rows for a batch's requests (rids are global counters,
        not store indices — hence the map)."""
        row = self._row
        if row is None:
            row = self._build_rowmap()
        base = self._rowbase
        return [row[r.rid - base] for r in requests]

    def _build_rowmap(self) -> list[int] | dict[int, int]:
        """Row lookup keyed by ``rid - base``.  Request ids come from one
        global counter, so any trace built in one go (``generate_requests``,
        ``RequestSet.fresh()``) has a *contiguous* rid range — then the map
        is a flat list filled by one vectorized scatter instead of a
        100k-entry dict comprehension.  Arbitrary rid sets fall back to a
        dict with the same ``rid - base`` keying."""
        reqs = self.requests
        n = len(reqs)
        rids = np.array([r.rid for r in reqs], dtype=np.int64)
        base = int(rids.min()) if n else 0
        row: list[int] | dict[int, int]
        if n and int(rids.max()) - base + 1 == n:
            # rids are unique (global counter), so span == n ⇒ contiguous
            scatter = np.empty(n, dtype=np.int64)
            scatter[rids - base] = np.arange(n)
            row = scatter.tolist()
        else:
            row = {int(rid) - base: i for i, rid in enumerate(rids.tolist())}
        self._rowbase = base
        self._row = row
        return row

    # ------------------------------------------------------------- stats
    def fold_stats(
        self, no_drops: bool = False, n_off_ledger: int = 0
    ) -> tuple[int, int, int, int, np.ndarray]:
        """Vectorized end-of-run accounting from the state columns:
        ``(ok, late, dropped, unserved, latencies)``, bit-identical to the
        scalar loop's per-object pass (same floats, same store order).

        ``dropped`` is the one per-object read left: schedulers mark
        timeouts by writing ``req.dropped`` (their own bookkeeping), so the
        store has no column for it — one O(n) predicate scan at fold time,
        off the hot path.  The caller may pass ``no_drops=True`` when it
        has *proven* nothing was dropped (every scheduler in the pool
        exposes an ``n_timed_out`` counter, incremented alongside every
        ``req.dropped`` write, and all read zero) — that skips the scan.

        ``n_off_ledger`` is the count of requests the fault tier resolved
        *outside* the columns (admission-rejected or retry-exhausted
        ``failed`` — both look unfinished-and-undropped here): they are
        subtracted from ``unserved`` so the caller's terminal-state
        accounting conserves every request exactly once."""
        n = len(self.requests)
        fin = self.finished
        finished_mask = ~np.isnan(fin)
        ok_mask = finished_mask & (fin <= self.deadline)
        ok = int(np.count_nonzero(ok_mask))
        n_finished = int(np.count_nonzero(finished_mask))
        late = n_finished - ok
        if no_drops:
            dropped = 0
            unserved = n - n_finished - n_off_ledger
        else:
            dropped_mask = np.fromiter(
                (r.dropped is not None for r in self.requests),
                dtype=bool,
                count=n,
            )
            dropped = int(np.count_nonzero(dropped_mask))
            unserved = (
                int(np.count_nonzero(~finished_mask & ~dropped_mask))
                - n_off_ledger
            )
        latencies = (fin - self.release)[finished_mask]
        return ok, late, dropped, unserved, latencies

    def writeback(self) -> None:
        """Flush the ``started``/``finished`` columns onto the Request
        objects — one O(n) pass after the run, so downstream consumers
        (tests, the engine sim-twin) see the same per-object state the
        scalar loop leaves behind."""
        for r, s, f in zip(
            self.requests, self.started.tolist(), self.finished.tolist()
        ):
            if s == s:  # not NaN
                r.started = s
            if f == f:
                r.finished = f
