"""Baseline schedulers the paper compares against (§2.3, §5).

All baselines share the simulator interface of :class:`OrlojScheduler`:
``on_arrival``, ``next_batch``, ``on_batch_done``.  They model the
*scheduling policies* of the systems as characterised by the paper:

- :class:`ClockworkScheduler` — plan-ahead with a single point estimate per
  batch size and strict action windows: when a batch overruns its predicted
  latency, the pre-committed next batch misses its window and fails
  ("frequent time-out error in its scheduler, causing the subsequent batch
  to fail", §2.3).
- :class:`NexusScheduler` — ahead-of-time squishy-bin plan from the *mean*
  execution time: a fixed batch size chosen so that queueing + execution
  fits the SLO, FIFO service.
- :class:`ClipperScheduler` — reactive AIMD adaptive batching on observed
  latencies, FIFO service.
- :class:`EDFScheduler` — earliest-deadline-first with greedy batching on a
  mean estimate (ablation: plan-ahead without distributions).
"""

from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Sequence

import numpy as np

from .distributions import BatchLatencyModel
from .request import Request
from .scheduler import Batch

__all__ = [
    "BASELINES",
    "ClockworkScheduler",
    "NexusScheduler",
    "ClipperScheduler",
    "EDFScheduler",
]


class _PointEstimator:
    """Sliding-window point estimator of the standalone execution time."""

    def __init__(
        self,
        kind: str = "mean",
        window: int = 512,
        init_samples: Sequence[float] | None = None,
    ) -> None:
        self.kind = kind
        self.buf: deque[float] = deque(maxlen=window)
        if init_samples is not None:
            for x in init_samples:
                self.buf.append(float(x))

    def observe(self, x: float) -> None:
        self.buf.append(float(x))

    def value(self) -> float:
        if not self.buf:
            return 10.0
        arr = np.asarray(self.buf)
        if self.kind == "mean":
            return float(arr.mean())
        if self.kind == "p99":
            return float(np.quantile(arr, 0.99))
        if self.kind == "max":
            return float(arr.max())
        raise ValueError(self.kind)


class _BaselineBase:
    # Most baselines never read ``req.started``/``req.finished`` inside
    # their hooks, so the array event loop may defer those object writes to
    # one end-of-run flush.  Schedulers that DO read them (Clipper's AIMD,
    # adaptive Clockwork) override this.
    reads_request_state = False

    def __init__(
        self,
        latency_model: BatchLatencyModel,
        batch_sizes: tuple[int, ...] = (1, 2, 4, 8, 16),
        estimator: str = "mean",
        init_samples: Sequence[float] | None = None,
    ) -> None:
        self.latency_model = latency_model
        self.batch_sizes = tuple(sorted(batch_sizes))
        self.est = _PointEstimator(estimator, init_samples=init_samples)
        self.n_timed_out = 0

    def est_batch(self, bs: int) -> float:
        return self.latency_model.c0 + self.latency_model.c1 * bs * self.est.value()

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        for x in alone_times_ms:
            self.est.observe(x)

    def on_arrivals(self, reqs: Sequence[Request], now: float) -> None:
        """Bulk-arrival entry point (the event loop coalesces same-timestamp
        arrivals); the baselines have no vectorized scoring, so it is just
        the per-request hook in order."""
        for req in reqs:
            self.on_arrival(req, now)

    @property
    def n_pending(self) -> int:  # pragma: no cover - overridden where needed
        raise NotImplementedError


class ClockworkScheduler(_BaselineBase):
    """Clockwork-style plan-ahead scheduling with strict action windows."""

    name = "clockwork"

    def __init__(
        self,
        *args,
        window_slack: float = 10.0,
        obs_window: int = 32,
        adaptive: bool = False,
        **kwargs,
    ) -> None:
        # Paper-faithful mode (default, ``adaptive=False``): Clockwork
        # profiles each batch size *offline once* — a single point estimate
        # (≈ the mean over its profiling inputs).  Exact for static DNNs;
        # for data-dependent models it under-predicts the batch max almost
        # every time, tripping the strict action window of the pre-planned
        # next batch — the "fail-every-other-batch" pattern of §2.3.
        #
        # ``adaptive=True`` is a *hardened* beyond-paper variant: per-batch-
        # size max-of-sliding-window over observed batch latencies.
        kwargs.setdefault("estimator", "mean")
        super().__init__(*args, **kwargs)
        self.adaptive = adaptive
        # adaptive mode observes finished-started durations in on_batch_done
        self.reads_request_state = adaptive
        self.window_slack = window_slack  # ms tolerance on the action window
        self._bs_obs: dict[int, deque[float]] = {}
        self._obs_window = obs_window
        self._edf: list[tuple[float, int, Request]] = []
        self._pending: dict[int, Request] = {}
        # Predicted completion of the in-flight batch: the next action is
        # scheduled to start there, with a strict lateness window.
        self._planned_start: float | None = None

    def est_batch(self, bs: int) -> float:
        if self.adaptive:
            obs = self._bs_obs.get(bs)
            if obs:
                return max(obs)
        # Offline profile: Eq. 3 with the point estimate of the alone time.
        return self.latency_model.c0 + self.latency_model.c1 * bs * self.est.value()

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        if self.adaptive:
            # Online adaptation is the hardened variant only; stock
            # Clockwork keeps its offline profile fixed.
            super().on_batch_done(batch, now, alone_times_ms)
            r0 = batch.requests[0]
            if r0.started is not None and r0.finished is not None:
                self._bs_obs.setdefault(
                    len(batch.requests), deque(maxlen=self._obs_window)
                ).append(r0.finished - r0.started)

    def on_arrival(self, req: Request, now: float) -> None:
        self._pending[req.rid] = req
        heapq.heappush(self._edf, (req.deadline, req.rid, req))

    def _pop_feasible(self, now: float) -> list[Request]:
        """Drop hopeless heads; return live EDF-ordered queue view."""
        live: list[Request] = []
        while self._edf:
            deadline, rid, req = self._edf[0]
            if rid not in self._pending:
                heapq.heappop(self._edf)
                continue
            if now + self.est_batch(1) > deadline:
                heapq.heappop(self._edf)
                del self._pending[rid]
                req.dropped = now
                self.n_timed_out += 1
                continue
            break
        live = sorted(
            (r for r in self._pending.values()), key=lambda r: r.deadline
        )
        return live

    def _plan(self, at: float, among: list[Request] | None = None) -> list[Request]:
        live = among if among is not None else self._pop_feasible(at)
        if not live:
            return []
        # Largest batch size that still meets the earliest deadline under
        # the point estimate.
        chosen = 1
        for bs in self.batch_sizes:
            if bs <= len(live) and at + self.est_batch(bs) <= live[0].deadline:
                chosen = bs
        return live[:chosen]

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        # The controller scheduled the next action at the *predicted*
        # completion of the in-flight batch.  If the batch overran the
        # prediction by more than the action window, the planned action is
        # rejected by the worker: the batch that would have run fails.
        if self._planned_start is not None:
            planned = self._planned_start
            self._planned_start = None
            if now > planned + self.window_slack:
                victims = [
                    r
                    for r in sorted(
                        self._pending.values(), key=lambda r: r.deadline
                    )
                    if r.release <= planned
                ]
                victims = self._plan(planned, among=victims)
                for r in victims:
                    self._pending.pop(r.rid, None)
                    r.dropped = now
                    self.n_timed_out += 1
        picked = self._plan(now)
        for r in picked:
            self._pending.pop(r.rid, None)
        if not picked:
            return None, None
        self._planned_start = now + self.est_batch(len(picked))
        return Batch(picked, len(picked)), None

    @property
    def n_pending(self) -> int:
        return len(self._pending)


class NexusScheduler(_BaselineBase):
    """Nexus-style ahead-of-time plan: fixed batch size from the mean."""

    name = "nexus"

    def __init__(self, *args, replan_interval: float = 5_000.0, **kwargs) -> None:
        kwargs.setdefault("estimator", "mean")
        super().__init__(*args, **kwargs)
        self.replan_interval = replan_interval
        self._fifo: deque[Request] = deque()
        self._plan_bs = self.batch_sizes[0]
        self._last_plan = -math.inf

    def _replan(self, now: float, slo: float) -> None:
        if now - self._last_plan < self.replan_interval:
            return
        self._last_plan = now
        # Squishy-bin rule: exec + (worst-case) queueing = 2·est(B) ≤ SLO.
        chosen = self.batch_sizes[0]
        for bs in self.batch_sizes:
            if 2.0 * self.est_batch(bs) <= slo:
                chosen = bs
        self._plan_bs = chosen

    def on_arrival(self, req: Request, now: float) -> None:
        self._fifo.append(req)
        self._replan(now, req.slo)

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        # Drop expired heads (mean estimate says they cannot make it).
        while self._fifo and now + self.est_batch(1) > self._fifo[0].deadline:
            req = self._fifo.popleft()
            req.dropped = now
            self.n_timed_out += 1
        if not self._fifo:
            return None, None
        b = self._plan_bs
        head = self._fifo[0]
        if len(self._fifo) < b:
            # Wait for the batch to fill unless the head forces a flush.
            flush_at = head.deadline - self.est_batch(b)
            if now < flush_at:
                return None, flush_at
            b = len(self._fifo)
        picked = [self._fifo.popleft() for _ in range(min(b, len(self._fifo)))]
        return Batch(picked, len(picked)), None

    @property
    def n_pending(self) -> int:
        return len(self._fifo)


class ClipperScheduler(_BaselineBase):
    """Clipper-style reactive AIMD adaptive batching, FIFO service."""

    name = "clipper"
    # AIMD reads finished-started exec durations inside on_batch_done
    reads_request_state = True

    def __init__(self, *args, **kwargs) -> None:
        kwargs.setdefault("estimator", "mean")
        super().__init__(*args, **kwargs)
        self._fifo: deque[Request] = deque()
        self._cap = float(self.batch_sizes[-1])
        self._slo_hint: float | None = None

    def on_arrival(self, req: Request, now: float) -> None:
        self._fifo.append(req)
        self._slo_hint = req.slo

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        while self._fifo and now + self.est_batch(1) > self._fifo[0].deadline:
            req = self._fifo.popleft()
            req.dropped = now
            self.n_timed_out += 1
        if not self._fifo:
            return None, None
        k = min(int(self._cap), len(self._fifo))
        k = max(k, 1)
        picked = [self._fifo.popleft() for _ in range(k)]
        return Batch(picked, len(picked)), None

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        super().on_batch_done(batch, now, alone_times_ms)
        if self._slo_hint is None:
            return
        # AIMD on observed batch *execution latency* vs the SLO budget
        # (Clipper's adaptive batching targets exec-under-SLO).
        r0 = batch.requests[0]
        if r0.started is not None and r0.finished is not None:
            duration = r0.finished - r0.started
            if duration > self._slo_hint:
                self._cap = max(1.0, self._cap * 0.5)
            else:
                self._cap = min(float(self.batch_sizes[-1]), self._cap + 1.0)

    @property
    def n_pending(self) -> int:
        return len(self._fifo)


class EDFScheduler(_BaselineBase):
    """EDF + greedy batch sizing on a mean point estimate (ablation)."""

    name = "edf"

    def __init__(self, *args, **kwargs) -> None:
        kwargs.setdefault("estimator", "mean")
        super().__init__(*args, **kwargs)
        self._pending: dict[int, Request] = {}

    def on_arrival(self, req: Request, now: float) -> None:
        self._pending[req.rid] = req

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        live = sorted(self._pending.values(), key=lambda r: r.deadline)
        while live and now + self.est_batch(1) > live[0].deadline:
            r = live.pop(0)
            del self._pending[r.rid]
            r.dropped = now
            self.n_timed_out += 1
        if not live:
            return None, None
        chosen = 1
        for bs in self.batch_sizes:
            if bs <= len(live) and now + self.est_batch(bs) <= live[0].deadline:
                chosen = bs
        picked = live[:chosen]
        for r in picked:
            del self._pending[r.rid]
        return Batch(picked, len(picked)), None

    @property
    def n_pending(self) -> int:
        return len(self._pending)


# name -> class, for harnesses that select compared systems by name (the
# ``repro.eval`` grid runner, ``benchmarks/common.py``).  Every entry shares
# the ``on_arrival(s)`` / ``next_batch`` / ``on_batch_done`` protocol and the
# ``(latency_model, init_samples=...)`` constructor shape.
BASELINES: dict[str, type[_BaselineBase]] = {
    cls.name: cls
    for cls in (ClockworkScheduler, NexusScheduler, ClipperScheduler, EDFScheduler)
}
