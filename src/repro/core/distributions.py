"""Empirical execution-time distributions and order statistics (paper §4.2).

Orloj represents the standalone execution time of a request as a random
variable described by an empirical histogram learned online.  This module
implements:

- :class:`EmpiricalDistribution` — a histogram with a *piecewise-linear* CDF
  (uniform-within-bin).  The paper notes (§4.2.1) that using the raw discrete
  histogram CDF for ``E[max]`` is "far too inaccurate"; the piecewise-linear
  CDF lets us integrate ``E[max] = lo + ∫ (1 - F(l)^k) dl`` *exactly* per
  segment (the integrand is polynomial on each segment).
- i.i.d. max order statistics (Eq. 6): ``F_(k) = F^k``.
- non-identical max order statistics (Eq. 8, Özbey et al.).  For the
  *maximum*, Eq. 8 reduces to the product form ``F_max = Π_i F_i``; we
  implement the product form (numerically stable, O(k·bins)) and keep a
  literal small-k expansion of Eq. 8 for validation in tests.
- the batch execution-time model (Eq. 3–5):
  ``L_B = c0 + c1 · k · max_r L_r``.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

import numpy as np

__all__ = [
    "EmpiricalDistribution",
    "iid_max",
    "hetero_max",
    "ozbey_max_pdf",
    "mixture",
    "BatchLatencyModel",
]


@dataclasses.dataclass(frozen=True)
class EmpiricalDistribution:
    """Histogram distribution with a piecewise-linear CDF.

    ``edges``  — monotonically increasing bin edges, length ``n + 1``.
    ``probs``  — bin probabilities, length ``n``; sums to 1.
    """

    edges: np.ndarray
    probs: np.ndarray

    def __post_init__(self) -> None:
        edges = np.asarray(self.edges, dtype=np.float64)
        probs = np.asarray(self.probs, dtype=np.float64)
        if edges.ndim != 1 or probs.ndim != 1 or edges.size != probs.size + 1:
            raise ValueError("edges must have len(probs) + 1 entries")
        if np.any(np.diff(edges) <= 0):
            raise ValueError("edges must be strictly increasing")
        if np.any(probs < -1e-12):
            raise ValueError("probs must be non-negative")
        total = probs.sum()
        if not math.isfinite(total) or total <= 0:
            raise ValueError("probs must sum to a positive finite value")
        object.__setattr__(self, "edges", edges)
        object.__setattr__(self, "probs", np.maximum(probs, 0.0) / total)
        # CDF at the knots, computed once: cdf()/quantile()/iid_max/
        # expected_max/rebin all consume it, and re-running np.cumsum per
        # call dominated the distribution algebra on the hot path.  Frozen
        # so a caller cannot corrupt the cache in place.
        knots = np.concatenate([[0.0], np.cumsum(self.probs)])
        knots.flags.writeable = False
        object.__setattr__(self, "_cdf_knots", knots)

    # -- constructors -----------------------------------------------------
    @classmethod
    def from_samples(
        cls, samples: Sequence[float], n_bins: int = 16
    ) -> "EmpiricalDistribution":
        samples = np.asarray(list(samples), dtype=np.float64)
        if samples.size == 0:
            raise ValueError("need at least one sample")
        lo, hi = float(samples.min()), float(samples.max())
        if hi <= lo:  # degenerate: all samples equal
            span = max(abs(lo) * 1e-3, 1e-6)
            lo, hi = lo - span, hi + span
        counts, edges = np.histogram(samples, bins=n_bins, range=(lo, hi))
        return cls(edges, counts.astype(np.float64))

    @classmethod
    def delta(cls, value: float, width: float | None = None) -> "EmpiricalDistribution":
        """A (near-)deterministic execution time — the static-DNN case."""
        width = width if width is not None else max(abs(value) * 1e-3, 1e-6)
        return cls(np.array([value - width / 2, value + width / 2]), np.array([1.0]))

    # -- basic queries -----------------------------------------------------
    @property
    def lo(self) -> float:
        return float(self.edges[0])

    @property
    def hi(self) -> float:
        return float(self.edges[-1])

    def cdf(self, x: np.ndarray | float) -> np.ndarray:
        """Piecewise-linear CDF evaluated at ``x``."""
        x = np.asarray(x, dtype=np.float64)
        return np.interp(x, self.edges, self._cdf_knots, left=0.0, right=1.0)

    def cdf_at_knots(self) -> np.ndarray:
        """Cached CDF at the bin edges (read-only view — do not mutate)."""
        return self._cdf_knots

    def mean(self) -> float:
        mids = 0.5 * (self.edges[:-1] + self.edges[1:])
        return float(np.dot(mids, self.probs))

    def var(self) -> float:
        mids = 0.5 * (self.edges[:-1] + self.edges[1:])
        m = self.mean()
        # within-bin uniform variance + between-bin variance
        w = np.diff(self.edges)
        return float(np.dot(self.probs, (mids - m) ** 2 + w * w / 12.0))

    def quantile(self, q: float) -> float:
        cum = self.cdf_at_knots()
        return float(np.interp(q, cum, self.edges))

    def sample(self, rng: np.random.Generator, size: int = 1) -> np.ndarray:
        idx = rng.choice(self.probs.size, size=size, p=self.probs)
        u = rng.random(size)
        return self.edges[idx] + u * (self.edges[idx + 1] - self.edges[idx])

    # -- transforms ---------------------------------------------------------
    def affine(self, scale: float, shift: float) -> "EmpiricalDistribution":
        """Distribution of ``scale · X + shift`` (scale > 0)."""
        if scale <= 0:
            raise ValueError("scale must be positive")
        return EmpiricalDistribution(self.edges * scale + shift, self.probs)

    def rebin(self, edges: np.ndarray) -> "EmpiricalDistribution":
        """Project this distribution onto a new knot grid."""
        cdf = self.cdf(edges)
        probs = np.diff(cdf)
        # Degenerate overlap can yield all-zero probs if grids are disjoint.
        if probs.sum() <= 0:
            raise ValueError("rebin grid does not overlap distribution support")
        return EmpiricalDistribution(edges, probs)

    def iid_max(self, k: int) -> "EmpiricalDistribution":
        return iid_max(self, k)

    # -- conditional tail (token-mode remaining-length view) ------------------
    def conditional_tail(self, t: float) -> "EmpiricalDistribution":
        """Distribution of ``X | X > t`` — the renormalized upper tail.

        The per-step view token-level scheduling needs (DESIGN.md §12): a
        request that has already produced ``t`` tokens without hitting EOS
        has remaining-length distribution ``(X − t) | X > t``; this returns
        the un-shifted conditional ``X | X > t`` (shift by ``−t`` via the
        caller, or use :meth:`expected_remaining` for the mean directly).
        Exact under the piecewise-linear CDF."""
        edges = self.edges
        if t <= edges[0]:
            return self
        tail = 1.0 - float(self.cdf(t))
        if t >= edges[-1] or tail <= 0.0:
            raise ValueError(f"no mass above t={t} (support ends at {self.hi})")
        i = int(np.searchsorted(edges, t, side="right"))
        new_edges = np.concatenate([[t], edges[i:]])
        cdf = np.interp(new_edges, edges, self._cdf_knots)
        return EmpiricalDistribution(new_edges, np.diff(cdf))

    def expected_remaining(self, t: float) -> float:
        """``E[X − t | X > t]`` — exact under the piecewise-linear CDF.

        ``∫_t^hi (1 − F(x)) dx / (1 − F(t))``; integrand is linear on each
        segment, so the trapezoid over the knots above ``t`` is exact.
        Returns 0 when no mass lies above ``t`` (the tail is exhausted —
        callers treat this as "expected to finish immediately")."""
        edges = self.edges
        if t >= edges[-1]:
            return 0.0
        knots = self._cdf_knots
        st = 1.0 - float(np.interp(t, edges, knots, left=0.0, right=1.0))
        if st <= 1e-12:
            return 0.0
        i = int(np.searchsorted(edges, t, side="right"))
        xs = np.concatenate([[t], edges[i:]])
        ys = 1.0 - np.interp(xs, edges, knots, left=0.0, right=1.0)
        area = float(np.sum((ys[:-1] + ys[1:]) * np.diff(xs)) * 0.5)
        return area / st

    # -- exact piecewise integrals -------------------------------------------
    def expected_max(self, k: int) -> float:
        """``E[max of k i.i.d. draws]`` — exact under piecewise-linear CDF.

        E[max] = lo + ∫_lo^hi (1 - F(l)^k) dl.  On a segment where the CDF
        rises linearly from a to b over width w,
        ∫ F^k dl = w · (b^{k+1} - a^{k+1}) / ((k+1)(b - a)).
        """
        if k < 1:
            raise ValueError("k must be >= 1")
        cum = self.cdf_at_knots()
        a, b = cum[:-1], cum[1:]
        w = np.diff(self.edges)
        flat = np.isclose(a, b)
        seg = np.where(
            flat,
            w * a ** k,
            w * (b ** (k + 1) - a ** (k + 1)) / ((k + 1) * np.where(flat, 1.0, b - a)),
        )
        return float(self.edges[0] + np.sum(w) - np.sum(seg))


def iid_max(dist: EmpiricalDistribution, k: int) -> EmpiricalDistribution:
    """Distribution of the max of ``k`` i.i.d. draws (Eq. 6: ``F_(k)=F^k``)."""
    if k < 1:
        raise ValueError("k must be >= 1")
    if k == 1:
        return dist
    cum = dist.cdf_at_knots() ** k
    return EmpiricalDistribution(dist.edges, np.diff(cum))


def _merged_grid(
    dists: Sequence[EmpiricalDistribution], max_knots: int = 256
) -> tuple[np.ndarray, bool]:
    """Merged knot grid and whether it is *exact* (kept every input knot
    rather than subsampling past ``max_knots``)."""
    knots = np.unique(np.concatenate([d.edges for d in dists]))
    if knots.size <= max_knots:
        return knots, True
    knots = np.interp(
        np.linspace(0, 1, max_knots), np.linspace(0, 1, knots.size), knots
    )
    return np.unique(knots), False


def hetero_max(
    dists: Sequence[EmpiricalDistribution], grid: np.ndarray | None = None
) -> EmpiricalDistribution:
    """Max of independent, non-identically distributed variables (§4.2.2).

    The k-th (maximum) order statistic of independent variables has CDF
    ``Π_i F_i`` — the closed form to which Eq. 8 (Özbey et al.) reduces for
    the top order statistic.  Evaluated on the merged knot grid (pass a
    precomputed ``grid`` to skip the re-merge on repeated calls).
    """
    dists = list(dists)
    if not dists:
        raise ValueError("need at least one distribution")
    if len(dists) == 1 and grid is None:
        return dists[0]
    if grid is None:
        grid, _ = _merged_grid(dists)
    cdf = np.ones_like(grid)
    for d in dists:
        cdf = cdf * d.cdf(grid)
    probs = np.diff(cdf)
    return EmpiricalDistribution(grid, probs)


def ozbey_max_pdf(
    dists: Sequence[EmpiricalDistribution], xs: np.ndarray
) -> np.ndarray:
    """Literal Eq. 8 (Özbey et al. 2019) for the k-th order statistic PDF.

    f_(k) = Σ_{κ=1..k} (-1)^{k-κ} κ^k / k! · Σ_{|s|=κ} k [F^s]^{k-1} f^s

    with ``F^s = (1/|s|) Σ_{i∈s} F_i`` and likewise for ``f^s``.  Exponential
    in ``k`` — used only in tests to validate the product-CDF implementation.
    """
    k = len(dists)
    xs = np.asarray(xs, dtype=np.float64)
    total = np.zeros_like(xs)
    idx = range(k)
    for kappa in range(1, k + 1):
        coeff = (-1.0) ** (k - kappa) * kappa ** k / math.factorial(k)
        inner = np.zeros_like(xs)
        for s in itertools.combinations(idx, kappa):
            Fs = np.mean([dists[i].cdf(xs) for i in s], axis=0)
            fs = np.mean([_pdf(dists[i], xs) for i in s], axis=0)
            inner = inner + k * Fs ** (k - 1) * fs
        total = total + coeff * inner
    return total


def _pdf(dist: EmpiricalDistribution, xs: np.ndarray) -> np.ndarray:
    """Piecewise-constant PDF consistent with the piecewise-linear CDF."""
    xs = np.asarray(xs, dtype=np.float64)
    dens = dist.probs / np.diff(dist.edges)
    idx = np.clip(np.searchsorted(dist.edges, xs, side="right") - 1, 0, dens.size - 1)
    out = dens[idx]
    out = np.where((xs < dist.edges[0]) | (xs >= dist.edges[-1]), 0.0, out)
    return out


def mixture(
    dists: Sequence[EmpiricalDistribution],
    weights: Sequence[float] | None = None,
    grid: np.ndarray | None = None,
) -> EmpiricalDistribution:
    """Weighted mixture of app distributions (multimodal joint, §2.2/§4.3).

    Pass a precomputed ``grid`` (e.g. the scheduler's cached merged knot
    grid) to skip the per-call grid merge."""
    dists = list(dists)
    if not dists:
        raise ValueError("need at least one distribution")
    if weights is None:
        weights = [1.0] * len(dists)
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    if grid is None:
        grid, _ = _merged_grid(dists)
    cdf = np.zeros_like(grid)
    for wi, d in zip(w, dists):
        cdf = cdf + wi * d.cdf(grid)
    return EmpiricalDistribution(grid, np.diff(cdf))


@dataclasses.dataclass(frozen=True)
class BatchLatencyModel:
    """Eq. 3: ``l_B = c0 + c1 · k · l`` with ``l = max_r l_r`` (Eq. 4).

    ``bucket`` — optional padded-length bucketing (TPU static-shape regime):
    the max is rounded up to a multiple of ``bucket`` before applying the
    affine model.  ``bucket=0`` reproduces the paper's GPU model exactly.
    """

    c0: float
    c1: float
    bucket: float = 0.0

    def _bucketed(self, l: float) -> float:
        if self.bucket > 0:
            return math.ceil(l / self.bucket) * self.bucket
        return l

    def batch_time(self, alone_times_ms: Sequence[float]) -> float:
        """Ground-truth batch execution time given standalone times."""
        k = len(alone_times_ms)
        if k == 0:
            return 0.0
        return self.c0 + self.c1 * k * self._bucketed(max(alone_times_ms))

    def batch_dist(
        self, max_dist: EmpiricalDistribution, k: int
    ) -> EmpiricalDistribution:
        """Distribution of ``L_B`` given the distribution of the batch max
        (Eq. 9 is the corresponding change of variables)."""
        if k < 1:
            raise ValueError("k must be >= 1")
        d = max_dist
        if self.bucket > 0:
            # Project the max onto bucket boundaries (step function): the
            # padded length is ceil(max / bucket) · bucket, so all mass in
            # (prev_boundary, boundary] collapses to a thin bin at `boundary`.
            lo = math.floor(d.lo / self.bucket)
            hi = max(math.ceil(d.hi / self.bucket), lo + 1)
            grid = np.arange(lo, hi + 1, dtype=np.float64) * self.bucket
            pmass = np.diff(d.cdf(grid))
            vals = grid[1:]
            keep = pmass > 0
            vals, pmass = vals[keep], pmass[keep]
            if vals.size == 0:
                vals, pmass = np.array([grid[-1]]), np.array([1.0])
            width = self.bucket * 1e-3
            edges_list: list[float] = []
            probs_list: list[float] = []
            for i, v in enumerate(vals):
                edges_list.append(float(v) - width)
                edges_list.append(float(v))
                probs_list.append(float(pmass[i]))
                if i < vals.size - 1:
                    probs_list.append(0.0)  # zero-mass gap up to next bucket
            d = EmpiricalDistribution(np.array(edges_list), np.array(probs_list))
        return d.affine(self.c1 * k, self.c0)

    def expected_batch_time(
        self, dist: EmpiricalDistribution, k: int
    ) -> float:
        """Eq. 5: ``E[L_B] = c0 + c1 · k · E[max_k]`` for i.i.d. draws from
        ``dist`` (used with the mixture distribution per §4.3)."""
        if self.bucket > 0:
            return self.batch_dist(dist.iid_max(k), k).mean()
        return self.c0 + self.c1 * k * dist.expected_max(k)
