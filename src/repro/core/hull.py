"""Dynamic convex-hull priority queue (paper §4.4).

Each pending request is a line ``p(x) = α·x + β`` with ``x = e^{b(t−base)}``
(Eq. 2 rewritten, §4.4).  The top-priority request at time ``t`` is the line
maximising ``α·x + β`` — the first point of the upper convex hull hit by a
sweep line of slope ``−x``.

The paper implements Overmars–van Leeuwen (O(log² n) fully-dynamic hulls)
with a hand-rolled 2-3-tree concatenable queue.  We use the *logarithmic
method* (Bentley–Saxe) instead: O(log n) static convex-hull-trick blocks of
geometrically increasing size, lazy deletion with purge-on-hit, and global
compaction once half the structure is tombstones.  Insert is O(log n)
amortised, query O(log² n) — the same asymptotics the paper reports for its
queue (Fig. 12), with a far simpler implementation (see DESIGN.md
§Substitutions).
"""

from __future__ import annotations

import bisect
import math
from typing import Hashable, Iterable, Sequence

import numpy as np

__all__ = ["HullQueue"]


class _Block:
    """Static convex-hull-trick structure for max(α·x + β) over x > 0."""

    __slots__ = ("lines", "hull_keys", "hull_alpha", "hull_beta", "breaks")

    def __init__(self, lines: Sequence[tuple[Hashable, float, float]]):
        # lines: (key, alpha, beta)
        self.lines = list(lines)
        pts = sorted(self.lines, key=lambda e: (e[1], e[2]))
        # Deduplicate equal slopes, keeping the max intercept.
        dedup: list[tuple[Hashable, float, float]] = []
        for e in pts:
            if dedup and dedup[-1][1] == e[1]:
                dedup[-1] = e  # e has >= beta due to sort
            else:
                dedup.append(e)
        hull: list[tuple[Hashable, float, float]] = []
        for e in dedup:
            while len(hull) >= 2 and self._bad(hull[-2], hull[-1], e):
                hull.pop()
            hull.append(e)
        self.hull_keys = [e[0] for e in hull]
        self.hull_alpha = [e[1] for e in hull]
        self.hull_beta = [e[2] for e in hull]
        # breaks[i] = x at which hull[i+1] overtakes hull[i]
        self.breaks = [
            (self.hull_beta[i] - self.hull_beta[i + 1])
            / (self.hull_alpha[i + 1] - self.hull_alpha[i])
            for i in range(len(hull) - 1)
        ]

    @staticmethod
    def _bad(
        a: tuple[int, float, float],
        b: tuple[int, float, float],
        c: tuple[int, float, float],
    ) -> bool:
        # b is never the max if c overtakes a no later than b does.
        #   (c_beta - a_beta)/(a_alpha - c_alpha) <= (b_beta - a_beta)/(a_alpha - b_alpha)
        return (c[2] - a[2]) * (b[1] - a[1]) >= (b[2] - a[2]) * (c[1] - a[1])

    def __len__(self) -> int:
        return len(self.lines)

    def argmax(self, x: float) -> tuple[Hashable, float]:
        i = bisect.bisect_right(self.breaks, x)
        return self.hull_keys[i], self.hull_alpha[i] * x + self.hull_beta[i]


class HullQueue:
    """Fully-dynamic max-envelope queue over lines ``α·x + β``.

    Operations: ``insert(key, α, β)``, ``delete(key)``, ``update``,
    ``argmax(x)`` / ``value(key, x)``.  Lazy deletion: a tombstoned line that
    surfaces as a block argmax triggers a purge-rebuild of that block; a
    global compaction runs once tombstones outnumber live lines.
    """

    def __init__(self) -> None:
        self._alive: dict[Hashable, tuple[float, float]] = {}
        self._blocks: list[_Block] = []
        self._dead = 0

    def __len__(self) -> int:
        return len(self._alive)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._alive

    def keys(self) -> Iterable[Hashable]:
        return self._alive.keys()

    # -- mutation ----------------------------------------------------------
    def insert(self, key: Hashable, alpha: float, beta: float) -> None:
        if key in self._alive:
            raise KeyError(f"duplicate key {key!r}")
        if not (math.isfinite(alpha) and math.isfinite(beta)):
            raise ValueError("non-finite line coefficients (overflow guard)")
        self._alive[key] = (alpha, beta)
        self._push_block([(key, alpha, beta)])

    def insert_many(
        self, items: Iterable[tuple[Hashable, float, float]]
    ) -> None:
        """Insert many lines as ONE static block: a single O(n log n) hull
        build instead of n cascading binary-counter merges (the arrival-path
        bulk load, DESIGN.md §Hot-path).  All-or-nothing: validates every
        item before touching the structure."""
        items = list(items)
        seen: set[Hashable] = set()
        for key, alpha, beta in items:
            if key in self._alive or key in seen:
                raise KeyError(f"duplicate key {key!r}")
            seen.add(key)
            if not (math.isfinite(alpha) and math.isfinite(beta)):
                raise ValueError("non-finite line coefficients (overflow guard)")
        if not items:
            return
        for key, alpha, beta in items:
            self._alive[key] = (alpha, beta)
        self._push_block(items)

    def bulk_load(
        self, items: Iterable[tuple[Hashable, float, float]]
    ) -> None:
        """Discard all current lines and load ``items`` as one block — the
        O(n log n) full-rebuild path (base reset / profiler snapshot swap)."""
        self._alive.clear()
        self._blocks = []
        self._dead = 0
        self.insert_many(items)

    def delete(self, key: Hashable) -> None:
        del self._alive[key]
        self._dead += 1
        if self._dead > max(8, len(self._alive)):
            self._compact()

    def update(self, key: Hashable, alpha: float, beta: float) -> None:
        """Replace ``key``'s line in place: overwrite the live coefficients
        (the stale block entry tombstones lazily via the ``_is_alive``
        check) and push the new line, without the delete+insert round trip
        and its early compaction churn."""
        cur = self._alive.get(key)
        if cur is None:
            raise KeyError(key)
        if cur == (alpha, beta):
            return  # no-op: the live block entry is already this line
        if not (math.isfinite(alpha) and math.isfinite(beta)):
            raise ValueError("non-finite line coefficients (overflow guard)")
        self._alive[key] = (alpha, beta)
        self._dead += 1  # the superseded copy lingering in its block
        self._push_block([(key, alpha, beta)])
        if self._dead > max(8, len(self._alive)):
            self._compact()

    def _push_block(self, lines: list[tuple[int, float, float]]) -> None:
        self._blocks.append(_Block(lines))
        # Binary-counter merging keeps O(log n) blocks, geometric sizes.
        while (
            len(self._blocks) >= 2
            and len(self._blocks[-2]) <= 2 * len(self._blocks[-1])
        ):
            b = self._blocks.pop()
            a = self._blocks.pop()
            merged = [e for e in (a.lines + b.lines) if self._is_alive(e)]
            if merged:
                self._blocks.append(_Block(merged))

    def _is_alive(self, e: tuple[Hashable, float, float]) -> bool:
        v = self._alive.get(e[0])
        return v is not None and v == (e[1], e[2])

    def _compact(self) -> None:
        lines = [(k, a, b) for k, (a, b) in self._alive.items()]
        self._blocks = []
        self._dead = 0
        if lines:
            self._blocks.append(_Block(lines))

    # -- queries -----------------------------------------------------------
    def value(self, key: Hashable, x: float) -> float:
        a, b = self._alive[key]
        return a * x + b

    def argmax(self, x: float) -> tuple[Hashable, float] | None:
        """Return (key, value) of the live line maximising α·x + β."""
        best_key: Hashable | None = None
        best_val = -math.inf
        i = 0
        while i < len(self._blocks):
            blk = self._blocks[i]
            j = bisect.bisect_right(blk.breaks, x)
            key = blk.hull_keys[j]
            coeffs = (blk.hull_alpha[j], blk.hull_beta[j])
            if self._alive.get(key) != coeffs:
                # Tombstone (deleted, or stale coefficients after an update)
                # surfaced as this block's argmax: purge the block and retry.
                live = [e for e in blk.lines if self._is_alive(e)]
                if live:
                    self._blocks[i] = _Block(live)
                else:
                    self._blocks.pop(i)
                continue
            val = coeffs[0] * x + coeffs[1]
            if val > best_val:
                best_key, best_val = key, val
            i += 1
        if best_key is None:
            return None
        return best_key, best_val

    def pop_max(self, x: float) -> tuple[Hashable, float] | None:
        got = self.argmax(x)
        if got is None:
            return None
        self.delete(got[0])
        return got

    def pop_topk(self, x: float, k: int) -> list[tuple[Hashable, float]]:
        """Pop the (up to) k live lines maximising ``α·x + β`` at one fixed
        ``x``, best first.

        PopBatch pops at a *single* sweep position, so the top-k reduces to
        one vectorized O(n) value scan + argpartition.  Popping through the
        hull instead would surface a fresh tombstone at the top of the
        largest block on every pop and pay k near-full purge rebuilds
        (DESIGN.md §Hot-path); the envelope machinery is only worth it for
        queries at varying ``x``.
        """
        n = len(self._alive)
        if k <= 0 or n == 0:
            return []
        if k == 1 or n <= 4:
            out = []
            for _ in range(min(k, n)):
                got = self.pop_max(x)
                if got is None:
                    break
                out.append(got)
            return out
        keys = list(self._alive)
        coef = np.array(list(self._alive.values()))
        vals = coef[:, 0] * x + coef[:, 1]
        k = min(k, n)
        idx = np.argpartition(-vals, k - 1)[:k]
        idx = idx[np.argsort(-vals[idx], kind="stable")]
        out = [(keys[i], float(vals[i])) for i in idx]
        for key, _ in out:
            self.delete(key)
        return out
