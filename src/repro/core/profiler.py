"""Online per-application execution-time profiler (paper §3.2).

The long-term feedback loop: finished requests are *sampled* and evaluated
standalone off the critical path; their alone-times are accumulated per
application and periodically picked up by the scheduler.  To adapt to input
drift the profiling memory is reset on a configurable window.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque

import numpy as np

from .distributions import EmpiricalDistribution

__all__ = ["ProfilerConfig", "OnlineProfiler"]


@dataclasses.dataclass(frozen=True)
class ProfilerConfig:
    sample_rate: float = 0.25  # fraction of finished requests re-profiled
    pickup_interval: float = 2_000.0  # ms between scheduler pickups (§3.2)
    memory_window: float = 120_000.0  # ms; drift-reset window (§3.2)
    max_samples_per_app: int = 4_096
    n_bins: int = 12
    seed: int = 0


class OnlineProfiler:
    """Collects sampled alone-times per app; serves snapshot distributions."""

    def __init__(self, cfg: ProfilerConfig | None = None):
        self.cfg = cfg or ProfilerConfig()
        self._rng = np.random.default_rng(self.cfg.seed)
        self._samples: dict[str, deque[tuple[float, float]]] = defaultdict(
            lambda: deque(maxlen=self.cfg.max_samples_per_app)
        )
        self._last_pickup = -np.inf
        self._snapshot: dict[str, EmpiricalDistribution] = {}
        self._dirty = False

    # -- ingestion ----------------------------------------------------------
    def seed_history(
        self, app_id: str, alone_times_ms: Sequence[float], now: float = 0.0
    ) -> None:
        """Warm-start from historical data (the paper assumes SLOs and
        distributions are derived from historical observations)."""
        for x in alone_times_ms:
            self._samples[app_id].append((now, float(x)))
        self._dirty = True

    def observe(self, app_id: str, alone_time_ms: float, now: float) -> None:
        """Called when a finished request is (probabilistically) sampled."""
        if self._rng.random() <= self.cfg.sample_rate:
            self._samples[app_id].append((now, float(alone_time_ms)))
            self._dirty = True

    # -- pickup -------------------------------------------------------------
    def maybe_pickup(self, now: float) -> dict[str, EmpiricalDistribution] | None:
        """Return fresh per-app distributions if the pickup interval elapsed
        and new data arrived; otherwise ``None`` (scheduler keeps its copy)."""
        if now - self._last_pickup < self.cfg.pickup_interval:
            return None
        self._last_pickup = now
        if not self._dirty:
            return None
        self._dirty = False
        self._expire(now)
        snap: dict[str, EmpiricalDistribution] = {}
        for app, buf in self._samples.items():
            if len(buf) >= 2:
                snap[app] = EmpiricalDistribution.from_samples(
                    [x for _, x in buf], n_bins=self.cfg.n_bins
                )
        if snap:
            self._snapshot = snap
            return dict(snap)
        return None

    def current(self) -> dict[str, EmpiricalDistribution]:
        return dict(self._snapshot)

    def _expire(self, now: float) -> None:
        cutoff = now - self.cfg.memory_window
        for buf in self._samples.values():
            while buf and buf[0][0] < cutoff and len(buf) > 8:
                buf.popleft()
