"""ORLOJ's batch-aware distribution-based scheduler (paper §3.2, §4, Alg. 1).

Structure per Algorithm 1:

- one priority queue (dynamic convex hull, :mod:`.hull`) per supported batch
  size ``bs``, holding every pending request still *feasible* at that batch
  size, scored by the Eq.-2 batch-aware priority with the ``L_B(bs)``
  histogram (mixture of all app distributions, §4.3);
- a deadline heap per batch size (the paper uses a Fibonacci heap) driving
  the drop phase (lines 10–14);
- a milestone heap triggering lazy (α, β) re-computation (lines 5–9);
- base-time reset for exponential-overflow handling (lines 2–4, §4.4).

Hot path (DESIGN.md §Hot-path): arrivals are delivered in bulk through
:meth:`OrlojScheduler.on_arrivals` — one :meth:`BinScoreModel.score_many`
pass plus one :meth:`HullQueue.insert_many` block per batch size — and the
full-recompute paths (base reset, profiler snapshot swap) rebuild each hull
with :meth:`HullQueue.bulk_load` from a single vectorized scoring pass.
The distribution algebra behind a snapshot swap is cached: the merged knot
grid is computed once, ``iid_max(mix, bs)`` is one CDF-power per batch size
off a shared knot-CDF, and per-(app, bs) drop-phase estimates are memoized.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Sequence

import numpy as np

from .distributions import (
    BatchLatencyModel,
    EmpiricalDistribution,
    _merged_grid,
    hetero_max,
    iid_max,
    mixture,
)
from .hull import HullQueue
from .priority import DEFAULT_B, RESET_EXPONENT, BinScoreModel, aggregate_steps
from .profiler import OnlineProfiler, ProfilerConfig
from .request import PiecewiseStepCost, Request

__all__ = ["SchedulerConfig", "OrlojScheduler", "MultiModelOrlojScheduler", "Batch"]


def _flatten_steps(
    reqs: Sequence[Request],
) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    """Flatten the requests' SLO cost steps into ``(deadlines, costs,
    seg_starts)`` arrays for :meth:`BinScoreModel.score_many`.

    ``seg_starts`` is ``None`` on the common all-single-step path (rows map
    1:1 to requests); otherwise it holds each request's first row for
    :func:`~repro.core.priority.aggregate_steps`."""
    if all(not r.extra_deadlines for r in reqs):
        d = np.array([r.release + r.slo for r in reqs])
        c = np.array([r.cost for r in reqs])
        return d, c, None
    ds: list[float] = []
    cs: list[float] = []
    starts: list[int] = []
    for r in reqs:
        starts.append(len(ds))
        fn = r.cost_fn()
        steps = fn.steps() if isinstance(fn, PiecewiseStepCost) else [fn]
        for s in steps:
            ds.append(s.deadline)
            cs.append(s.cost)
    return np.array(ds), np.array(cs), np.array(starts)


def _score_flat(
    model: BinScoreModel,
    deadlines: np.ndarray,
    costs: np.ndarray,
    seg_starts: np.ndarray | None,
    t: float,
    base: float,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-request (α, β, milestone) arrays from flattened step arrays."""
    alpha, beta, milestone = model.score_many(deadlines, costs, t, base)
    if seg_starts is None:
        return alpha, beta, milestone
    return aggregate_steps(alpha, beta, milestone, seg_starts)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    batch_sizes: tuple[int, ...] = (1, 2, 4, 8, 16)
    b: float = DEFAULT_B  # anticipated-delay parameter (§4.1, §5.6)
    n_bins: int = 12
    # 'earliest' = prose of §3.2 (earliest D_Qbs first, larger bs on ties);
    # 'paper_desc' = the literal Algorithm-1 line-16 ordering.
    bs_order: str = "earliest"
    # Refine the drop-phase feasibility estimate with the request's own app
    # distribution: E[max(L_app, L_mix^{bs-1})] instead of E[L_mix^{bs}].
    refine_feasibility: bool = True
    drop_safety: float = 1.0  # scale on EstimateBatchLatency in the drop phase


@dataclasses.dataclass
class Batch:
    """A scheduling decision: the requests to execute at ``batch_size``.

    ``rows`` is an optional columnar annotation for the array engine
    (DESIGN.md §10): the requests' row indices in the run's
    :class:`~repro.core.requeststore.RequestStore`, in batch order.  A
    scheduler fed through ``on_arrivals_cols`` already knows its rows and
    a contiguous ``range`` here turns the engine's per-batch column
    writes into O(1) numpy slice assignments; ``None`` (every existing
    scheduler) means the engine resolves rows itself via
    ``RequestStore.rows_for``.  The scalar loop ignores the field.

    ``decode=True`` marks a *resumable* token-level execution (DESIGN.md
    §12): instead of completing atomically, the batch advances in decode
    steps — requests join at step boundaries via the scheduler's
    ``on_decode_step`` hook and leave at their (data-dependent) EOS step.
    Requires a worker executor exposing ``step_time`` and a scheduler
    implementing the token-mode contract (:mod:`repro.core.tokensched`).

    ``model`` names the zoo model the batch executes (DESIGN.md §13) —
    stamped by model-aware schedulers so a residency-managed event loop
    can charge the load stall before execution.  ``None`` everywhere else.
    """

    requests: list[Request]
    batch_size: int
    rows: "range | list[int] | None" = None
    decode: bool = False
    model: str | None = None

    def __len__(self) -> int:
        return len(self.requests)


class _BsState:
    """Per-batch-size state: hull queue + deadline heap + score model."""

    __slots__ = ("hull", "deadline_heap", "score_model", "est_latency")

    def __init__(self) -> None:
        self.hull = HullQueue()
        self.deadline_heap: list[tuple[float, int]] = []
        self.score_model: BinScoreModel | None = None
        self.est_latency: float = 0.0


class OrlojScheduler:
    """Distribution-aware, batch-aware priority scheduler (Algorithm 1)."""

    name = "orloj"
    # Never reads ``req.started``/``req.finished`` inside its hooks
    # (feedback comes through ``on_batch_done``'s alone-times argument), so
    # the array event loop may defer per-request state writes to the end.
    reads_request_state = False

    def __init__(
        self,
        latency_model: BatchLatencyModel,
        cfg: SchedulerConfig | None = None,
        profiler: OnlineProfiler | None = None,
        initial_dists: dict[str, EmpiricalDistribution] | None = None,
    ) -> None:
        self.cfg = cfg or SchedulerConfig()
        self.latency_model = latency_model
        self.profiler = profiler or OnlineProfiler(ProfilerConfig())
        self._pending: dict[int, Request] = {}
        self._feasible: dict[int, set[int]] = {}  # rid -> feasible batch sizes
        self._bs_state: dict[int, _BsState] = {
            bs: _BsState() for bs in self.cfg.batch_sizes
        }
        self._milestones: list[tuple[float, int, int]] = []  # (time, rid, bs)
        self._base = 0.0
        self._app_dists: dict[str, EmpiricalDistribution] = dict(initial_dists or {})
        self._app_bs_est: dict[tuple[str, int], float] = {}
        self._default_dist = EmpiricalDistribution.delta(10.0)
        self.n_timed_out = 0
        self._rebuild_models()

    # ------------------------------------------------------------------
    # Model (distribution) maintenance
    # ------------------------------------------------------------------
    def _mixture(self) -> EmpiricalDistribution:
        dists = list(self._app_dists.values())
        if not dists:
            self._grid = self._default_dist.edges
            self._grid_exact = True
            return self._default_dist
        # Cache the merged knot grid: every downstream evaluation of the
        # snapshot (mixture CDF, iid-max powers, drop-phase hetero_max)
        # shares it.  ``_grid_exact`` records whether the merge kept every
        # app knot (i.e. no 256-knot subsampling) — only then may the
        # per-app drop estimates reuse it without losing their own knots.
        self._grid, self._grid_exact = _merged_grid(dists)
        return mixture(dists, grid=self._grid)

    def _iid_max_mix(self, k: int) -> EmpiricalDistribution:
        """Memoized ``iid_max(mix, k)`` — the CDF power is one vectorized
        pass over the cached knot CDF, computed at most once per snapshot."""
        got = self._iid_max_cache.get(k)
        if got is None:
            got = iid_max(self._mix, k)
            self._iid_max_cache[k] = got
        return got

    def _rebuild_models(self) -> None:
        """Precompute per-batch-size L_B histograms, score models and
        expected latencies from the current app distributions (§4.3 — this
        is the heavy computation moved off the critical path).  One snapshot
        swap costs one mixture evaluation on the cached grid plus one CDF
        power + hull-ready score model per batch size."""
        mix = self._mixture()
        self._mix = mix
        self._app_bs_est.clear()
        self._iid_max_cache: dict[int, EmpiricalDistribution] = {1: mix}
        for bs, st in self._bs_state.items():
            max_dist = self._iid_max_mix(bs)
            batch_dist = self.latency_model.batch_dist(max_dist, bs)
            st.score_model = BinScoreModel(batch_dist, b=self.cfg.b)
            st.est_latency = self.latency_model.expected_batch_time(mix, bs)

    def estimate_batch_latency(self, req: Request, bs: int) -> float:
        """EstimateBatchLatency(r, bs) — Algorithm 1 line 11."""
        if not self.cfg.refine_feasibility or req.app_id not in self._app_dists:
            return self._bs_state[bs].est_latency
        key = (req.app_id, bs)
        got = self._app_bs_est.get(key)
        if got is None:
            own = self._app_dists[req.app_id]
            if bs == 1:
                max_dist = own
            else:
                # reuse the snapshot's cached knot grid when it is exact
                # (it then contains every knot of `own` and of the mix);
                # a subsampled grid would drop own's knots, so fall back
                # to the per-call merge there
                max_dist = hetero_max(
                    [own, self._iid_max_mix(bs - 1)],
                    grid=self._grid if self._grid_exact else None,
                )
            got = self.latency_model.c0 + self.latency_model.c1 * bs * max_dist.mean()
            self._app_bs_est[key] = got
        return got

    # ------------------------------------------------------------------
    # Arrival / bookkeeping
    # ------------------------------------------------------------------
    def on_arrival(self, req: Request, now: float) -> None:
        self.on_arrivals((req,), now)

    def on_arrivals(self, reqs: Sequence[Request], now: float) -> None:
        """Bulk arrival: score every request at every batch size in one
        vectorized Eq.-2 pass per batch size and insert the new lines as a
        single hull block (the event loop coalesces same-timestamp
        arrivals into one call)."""
        reqs = list(reqs)
        if not reqs:
            return
        deadlines, costs, seg_starts = _flatten_steps(reqs)
        rids = [r.rid for r in reqs]
        all_bs = set(self._bs_state)
        for req, rid in zip(reqs, rids):
            self._pending[rid] = req
            # simlint: ignore[R5] -- per-request feasibility state is the data structure itself, not transient churn; the drop phase mutates it per batch size
            self._feasible[rid] = set(all_bs)
        heap_entries = [(r.release + r.slo, r.rid) for r in reqs]
        for bs, st in self._bs_state.items():
            alpha, beta, miles = _score_flat(
                st.score_model, deadlines, costs, seg_starts, now, self._base
            )
            # simlint: ignore[R5] -- one bulk hull-block load per batch size (not per request); this *is* the PR-2 vectorized path replacing n scalar inserts
            st.hull.insert_many(list(zip(rids, alpha.tolist(), beta.tolist())))
            for entry in heap_entries:
                heapq.heappush(st.deadline_heap, entry)
            for rid, m in zip(rids, miles.tolist()):
                if math.isfinite(m):
                    heapq.heappush(self._milestones, (m, rid, bs))

    def on_arrivals_cols(self, store, lo: int, hi: int, now: float) -> None:
        """Columnar bulk arrival: rows ``[lo, hi)`` of the array engine's
        :class:`~repro.core.requeststore.RequestStore` (store order ==
        release order).  Delegates to :meth:`on_arrivals` over the store's
        request slice — same objects, same scoring pass, bit-identical
        behaviour — so the array loop can hand the scheduler a row range
        without materializing an intermediate list per burst."""
        self.on_arrivals(store.requests[lo:hi], now)

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        """Feedback: sampled finished requests go to the async profiler."""
        for req, alone_ms in zip(batch.requests, alone_times_ms):
            self.profiler.observe(req.app_id, alone_ms, now)
        snap = self.profiler.maybe_pickup(now)
        if snap:
            self._app_dists = snap
            self._rebuild_models()
            self._recompute_all(now)

    # ------------------------------------------------------------------
    # Score maintenance (Algorithm 1 lines 1–9)
    # ------------------------------------------------------------------
    def _x(self, now: float) -> float:
        return math.exp(self.cfg.b * (now - self._base))

    def _maybe_reset_base(self, now: float) -> None:
        if self.cfg.b * (now - self._base) > RESET_EXPONENT:
            self._base = now
            self._recompute_all(now)

    def _recompute_all(self, now: float) -> None:
        """Full (α, β) refresh (base reset, snapshot swap): one vectorized
        scoring pass per batch size + an O(n log n) hull bulk load, instead
        of O(pending · |bs|) scalar scores with cascading block merges."""
        self._milestones.clear()
        reqs = list(self._pending.values())
        if not reqs:
            for st in self._bs_state.values():
                st.hull = HullQueue()
            return
        deadlines, costs, seg_starts = _flatten_steps(reqs)
        rids = [r.rid for r in reqs]
        for bs, st in self._bs_state.items():
            alpha, beta, miles = _score_flat(
                st.score_model, deadlines, costs, seg_starts, now, self._base
            )
            lines = []
            for rid, a, b_, m in zip(
                rids, alpha.tolist(), beta.tolist(), miles.tolist()
            ):
                if bs not in self._feasible[rid]:
                    continue
                lines.append((rid, a, b_))
                if math.isfinite(m):
                    heapq.heappush(self._milestones, (m, rid, bs))
            st.hull.bulk_load(lines)

    def _update_due_scores(self, now: float) -> None:
        # Drain every due milestone first, then re-score the affected
        # (rid, bs) pairs batched per batch size.  A freshly computed
        # milestone is strictly in the future up to float rounding; the
        # `> now` guard below keeps an ulp-coincident one from re-entering
        # the heap at the same timestamp.
        due: dict[int, set[int]] = {}
        while self._milestones and self._milestones[0][0] <= now:
            _, rid, bs = heapq.heappop(self._milestones)
            if rid in self._pending and bs in self._feasible.get(rid, ()):
                due.setdefault(bs, set()).add(rid)
        for bs, rid_set in due.items():
            st = self._bs_state[bs]
            rids = sorted(rid_set)  # deterministic re-score order (R4)
            reqs = [self._pending[rid] for rid in rids]
            deadlines, costs, seg_starts = _flatten_steps(reqs)
            alpha, beta, miles = _score_flat(
                st.score_model, deadlines, costs, seg_starts, now, self._base
            )
            for rid, a, b_, m in zip(
                rids, alpha.tolist(), beta.tolist(), miles.tolist()
            ):
                st.hull.update(rid, a, b_)
                if math.isfinite(m) and m > now:
                    heapq.heappush(self._milestones, (m, rid, bs))

    # ------------------------------------------------------------------
    # Drop phase (Algorithm 1 lines 10–14)
    # ------------------------------------------------------------------
    def _drop_phase(self, now: float) -> None:
        for bs, st in self._bs_state.items():
            while st.deadline_heap:
                deadline, rid = st.deadline_heap[0]
                req = self._pending.get(rid)
                if req is None or bs not in self._feasible.get(rid, ()):
                    heapq.heappop(st.deadline_heap)  # lazy removal
                    continue
                est = self.estimate_batch_latency(req, bs) * self.cfg.drop_safety
                if now + est > deadline:
                    heapq.heappop(st.deadline_heap)
                    st.hull.delete(rid)
                    self._feasible[rid].discard(bs)
                    if not self._feasible[rid]:  # line 13–14: timed out
                        self._remove(rid)
                        req.dropped = now
                        self.n_timed_out += 1
                else:
                    break  # heap is deadline-ordered; the rest are feasible

    def _remove(self, rid: int) -> None:
        for bs in sorted(self._feasible.pop(rid, set())):
            st = self._bs_state[bs]
            if rid in st.hull:
                st.hull.delete(rid)
        self._pending.pop(rid, None)

    # ------------------------------------------------------------------
    # Batch selection (Algorithm 1 lines 15–22)
    # ------------------------------------------------------------------
    def _earliest_deadline(self, bs: int) -> float | None:
        st = self._bs_state[bs]
        while st.deadline_heap:
            deadline, rid = st.deadline_heap[0]
            if rid in self._pending and bs in self._feasible.get(rid, ()):
                return deadline
            heapq.heappop(st.deadline_heap)
        return None

    def _prepare(self, now: float) -> tuple[float, int] | None:
        """Alg.-1 maintenance phases + candidate selection, *without*
        popping: returns the winning ``(earliest deadline, batch size)``
        or ``None``.  Split from :meth:`next_batch` so a multi-model
        facade can let per-model queues compete on deadlines before
        committing one of them to a destructive :meth:`_pop`."""
        self._maybe_reset_base(now)
        self._update_due_scores(now)
        self._drop_phase(now)

        candidates: list[tuple[float, int]] = []
        for bs, st in self._bs_state.items():
            d = self._earliest_deadline(bs)
            if d is not None and len(st.hull) >= bs:
                candidates.append((d, bs))
        if not candidates:
            return None
        if self.cfg.bs_order == "paper_desc":
            candidates.sort(key=lambda e: (e[0], e[1]), reverse=True)
        else:  # earliest deadline first, larger batch on ties
            candidates.sort(key=lambda e: (e[0], -e[1]))
        return candidates[0]

    def _pop(self, candidate: int, now: float) -> Batch | None:
        """PopBatch: top ``candidate`` requests by ORLOJ score, in one
        fixed-x top-k pop (avoids k cascading tombstone purges)."""
        x = self._x(now)
        st = self._bs_state[candidate]
        picked: list[Request] = []
        for rid, _val in st.hull.pop_topk(x, candidate):
            req = self._pending[rid]
            picked.append(req)
            self._feasible[rid].discard(candidate)
            self._remove(rid)
        if not picked:
            return None
        return Batch(picked, candidate)

    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        """One scheduler iteration.  Returns (batch, next_wake_time)."""
        best = self._prepare(now)
        if best is None:
            wake = self._milestones[0][0] if self._milestones else None
            return None, wake
        batch = self._pop(best[1], now)
        if batch is None:
            return None, None
        return batch, None

    # -- introspection -------------------------------------------------
    @property
    def n_pending(self) -> int:
        return len(self._pending)


class MultiModelOrlojScheduler:
    """One shared Orloj queue over per-model keyed score models (§13).

    Multi-model serving keeps Algorithm 1 intact *per model*: each zoo
    model gets its own :class:`OrlojScheduler` (own ``L_B`` histograms,
    own :class:`~repro.core.priority.BinScoreModel` per batch size, own
    profiler feedback loop), built from that model's scaled per-app
    distributions.  The facade presents the event loop with one queue:
    arrivals route by ``Request.model_id``, and ``next_batch`` lets every
    model's candidate compete on ``(earliest deadline, -batch size)`` —
    the same ordering Alg. 1 uses across batch sizes — before committing
    exactly one inner to a destructive pop.  The winning batch is stamped
    with ``Batch.model`` so a residency-managed event loop can charge the
    weights-load stall before execution.

    Batches never mix models (one set of weights executes at a time), so
    the executor's Eq.-3 batch time stays well-defined per batch.
    """

    name = "orloj-multi"
    # Same contract as OrlojScheduler: feedback arrives via on_batch_done,
    # never by reading request bookkeeping fields.
    reads_request_state = False

    def __init__(
        self,
        latency_model: BatchLatencyModel,
        initial_dists_by_model: dict[str, dict[str, EmpiricalDistribution]],
        cfg: SchedulerConfig | None = None,
    ) -> None:
        if not initial_dists_by_model:
            raise ValueError("multi-model scheduler needs at least one model")
        self.cfg = cfg or SchedulerConfig()
        self.latency_model = latency_model
        self._inner: dict[str, OrlojScheduler] = {
            m: OrlojScheduler(latency_model, cfg=self.cfg, initial_dists=dists)
            for m, dists in initial_dists_by_model.items()
        }

    def _route(self, req: Request) -> OrlojScheduler:
        sched = self._inner.get(req.model_id)
        if sched is None:
            raise ValueError(
                f"request {req.rid} targets unknown model {req.model_id!r} "
                f"(scheduler serves {sorted(self._inner)})"
            )
        return sched

    # -- arrival / feedback hooks --------------------------------------
    def on_arrival(self, req: Request, now: float) -> None:
        self._route(req).on_arrivals((req,), now)

    def on_arrivals(self, reqs: Sequence[Request], now: float) -> None:
        by_model: dict[str, list[Request]] = {}
        for r in reqs:
            self._route(r)  # loud on unknown/unset model ids
            by_model.setdefault(r.model_id, []).append(r)
        for m, group in by_model.items():
            self._inner[m].on_arrivals(group, now)

    def on_arrivals_cols(self, store, lo: int, hi: int, now: float) -> None:
        self.on_arrivals(store.requests[lo:hi], now)

    def on_batch_done(
        self, batch: Batch, now: float, alone_times_ms: Sequence[float]
    ) -> None:
        if batch.model is None:
            raise ValueError("multi-model batch completed without a model id")
        self._inner[batch.model].on_batch_done(batch, now, alone_times_ms)

    # -- batch selection ------------------------------------------------
    def next_batch(self, now: float) -> tuple[Batch | None, float | None]:
        best: tuple[float, int, int] | None = None
        best_model: str | None = None
        for i, (m, sched) in enumerate(self._inner.items()):
            cand = sched._prepare(now)
            if cand is None:
                continue
            # deadline, larger batch on ties, then model roster order —
            # a total order, so the winner is deterministic
            key = (cand[0], -cand[1], i)
            if best is None or key < best:
                best, best_model = key, m
        if best_model is None:
            wakes = [
                s._milestones[0][0] for s in self._inner.values() if s._milestones
            ]
            return None, (min(wakes) if wakes else None)
        batch = self._inner[best_model]._pop(-best[1], now)
        if batch is None:
            return None, None
        batch.model = best_model
        return batch, None

    # -- introspection --------------------------------------------------
    @property
    def n_pending(self) -> int:
        return sum(s.n_pending for s in self._inner.values())

    @property
    def n_timed_out(self) -> int:
        return sum(s.n_timed_out for s in self._inner.values())
