from .store import restore_checkpoint, save_checkpoint, latest_step

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]
