"""Sharding-aware checkpointing: flat npz of leaves + JSON treedef.

``save`` pulls (addressable) shards to host and writes one .npz; ``restore``
rebuilds the pytree and ``device_put``s each leaf with the provided sharding
(so a checkpoint written under one mesh restores under another — the
resharding happens at load)."""

from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SEP = "§"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    path = ckpt_dir / f"step_{step:08d}.npz"
    np.savez(path, **flat)
    treedef = jax.tree_util.tree_structure(tree)
    (ckpt_dir / "treedef.json").write_text(json.dumps({"repr": str(treedef)}))
    return path


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = [
        int(m.group(1))
        for p in ckpt_dir.glob("step_*.npz")
        if (m := re.match(r"step_(\d+)\.npz", p.name))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str | Path, step: int, like: Any, shardings: Any | None = None
) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); optionally place each leaf with ``shardings``."""
    path = Path(ckpt_dir) / f"step_{step:08d}.npz"
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(flat_like[0])
    )
    for (path_k, leaf), sh in zip(flat_like[0], shard_leaves):
        key = _SEP.join(
            str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in path_k
        )
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
