"""Findings baseline: grandfathered findings that do not fail ``--check``.

``ANALYSIS_baseline.json`` (committed at the repo root) records accepted
pre-existing findings so the CI gate is *ratcheting*: anything already in
the baseline passes, any **new** finding fails the build, and fixing an
old finding makes its baseline entry stale (reported, and pruned by the
next ``--write-baseline``).

Fingerprints deliberately exclude line/column so that unrelated edits
shifting code around do not churn the baseline: a finding is identified
by ``(rule, path, scope, message)`` plus a per-key occurrence count (two
identical findings in one scope need two baseline slots).
"""

from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path
from typing import Iterable, Mapping

from .core import Finding

__all__ = ["Baseline", "diff_against_baseline", "fingerprint"]

_SCHEMA_VERSION = 1


def fingerprint(f: Finding) -> str:
    return f"{f.rule}|{f.path}|{f.scope}|{f.message}"


@dataclasses.dataclass
class Baseline:
    """count per fingerprint, plus display metadata for the human report."""

    counts: Counter
    meta: dict[str, dict]

    @classmethod
    def empty(cls) -> "Baseline":
        return cls(Counter(), {})

    @classmethod
    def from_findings(cls, findings: Iterable[Finding]) -> "Baseline":
        counts: Counter = Counter()
        meta: dict[str, dict] = {}
        for f in findings:
            fp = fingerprint(f)
            counts[fp] += 1
            meta.setdefault(
                fp,
                {
                    "rule": f.rule,
                    "name": f.name,
                    "path": f.path,
                    "scope": f.scope,
                    "message": f.message,
                },
            )
        return cls(counts, meta)

    @classmethod
    def load(cls, path: str | Path) -> "Baseline":
        p = Path(path)
        if not p.exists():
            return cls.empty()
        data = json.loads(p.read_text(encoding="utf-8"))
        counts: Counter = Counter()
        meta: dict[str, dict] = {}
        for entry in data.get("findings", []):
            fp = "{rule}|{path}|{scope}|{message}".format(**entry)
            counts[fp] = int(entry.get("count", 1))
            meta[fp] = {k: entry[k] for k in ("rule", "name", "path", "scope", "message")}
        return cls(counts, meta)

    def save(self, path: str | Path) -> None:
        entries = []
        for fp, count in sorted(self.counts.items()):
            e = dict(self.meta[fp])
            e["count"] = count
            entries.append(e)
        doc: Mapping = {
            "schema_version": _SCHEMA_VERSION,
            "tool": "repro.analysis",
            "note": (
                "Accepted pre-existing findings (DESIGN.md §9). New findings "
                "fail --check; regenerate with --write-baseline after "
                "deliberate triage only."
            ),
            "findings": entries,
        }
        Path(path).write_text(json.dumps(doc, indent=2) + "\n", encoding="utf-8")


def diff_against_baseline(
    findings: Iterable[Finding], baseline: Baseline
) -> tuple[list[Finding], list[str]]:
    """(new findings not covered by the baseline, stale baseline
    fingerprints no longer observed)."""
    budget = Counter(baseline.counts)
    new: list[Finding] = []
    for f in findings:
        fp = fingerprint(f)
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
        else:
            new.append(f)
    stale = sorted(fp for fp, n in budget.items() if n > 0)
    return new, stale
