"""R7 ``fault-swallow`` — bare ``except:`` and silently swallowed
broad exception handlers.

The fault-injection tier (``repro.serving.faults``) makes honest failure
accounting a first-class contract: a request that cannot finish is
*counted* as failed, never silently papered over.  A ``try`` body that
swallows ``Exception`` with nothing but a fallback ``return`` defeats
that — the simulation keeps running on a value nobody knows is fake, and
conservation/equivalence violations surface far from their cause.

Two shapes are flagged:

- bare ``except:`` — always.  It catches ``KeyboardInterrupt`` and
  ``SystemExit`` too; there is no justified use in library code.
- ``except Exception`` / ``except BaseException`` whose handler both
  *ignores the error* (the bound name — if any — is never read, and the
  body never calls ``traceback.format_exc``/``sys.exc_info``/a logger's
  ``.exception`` and never ``raise``\\ s) *and* is trivial: every
  statement is ``pass``/``...``/``continue``/``break`` or a ``return``
  of a side-effect-free expression (constants, names, attribute chains,
  container displays thereof).

Handlers that record the error, re-raise, or do real recovery work stay
silent.  Narrow handlers (``except KeyError`` …) are out of scope — a
specific exception type is itself the justification.  Deliberate
boundary swallows (environment probes and the like) carry a
``# simlint: ignore[R7] -- why`` or live in ``ANALYSIS_baseline.json``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

_BROAD = {"Exception", "BaseException", "builtins.Exception", "builtins.BaseException"}
# Calls that *observe* the in-flight exception without binding it.
_OBSERVER_SUFFIXES = ("format_exc", "exc_info", "print_exc")


class FaultSwallowRule:
    rule_id = "R7"
    name = "fault-swallow"
    zones = ("src/repro",)
    description = (
        "bare `except:` or an `except Exception` that silently swallows "
        "the error; catch narrowly, record the failure, or re-raise"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield ctx.finding(
                    self,
                    node,
                    "bare `except:` catches KeyboardInterrupt/SystemExit too; "
                    "catch a specific exception (at most `Exception`)",
                )
                continue
            caught = _caught_types(ctx, node.type)
            if not (caught & _BROAD):
                continue
            if _observes_error(ctx, node):
                continue
            if not all(_is_trivial_stmt(s) for s in node.body):
                continue
            what = next(iter(caught & _BROAD)).rsplit(".", 1)[-1]
            yield ctx.finding(
                self,
                node,
                f"`except {what}` swallows the error without recording it; "
                "catch narrowly, log/store the failure, or count it as failed",
            )


def _caught_types(ctx: FileContext, node: ast.AST) -> set[str]:
    """Resolved dotted names of the caught exception type(s)."""
    elts = node.elts if isinstance(node, ast.Tuple) else [node]
    out: set[str] = set()
    for e in elts:
        dn = ctx.resolve(e)
        if dn is not None:
            out.add(dn)
    return out


def _observes_error(ctx: FileContext, handler: ast.ExceptHandler) -> bool:
    """True when the handler body reads the bound exception, captures it
    through a traceback/exc_info/logger call, or re-raises."""
    bound = handler.name
    for node in ast.walk(handler):
        if node is handler:
            continue
        if isinstance(node, ast.Raise):
            return True
        if bound and isinstance(node, ast.Name) and node.id == bound:
            if isinstance(node.ctx, ast.Load):
                return True
        if isinstance(node, ast.Call):
            target = ctx.resolve_call(node)
            if target is not None and (
                target.endswith(_OBSERVER_SUFFIXES) or target.endswith(".exception")
            ):
                return True
    return False


def _is_trivial_stmt(stmt: ast.stmt) -> bool:
    if isinstance(stmt, (ast.Pass, ast.Continue, ast.Break)):
        return True
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
        return True  # docstring / `...`
    if isinstance(stmt, ast.Return):
        return stmt.value is None or _is_simple_expr(stmt.value)
    return False


def _is_simple_expr(node: ast.expr) -> bool:
    """Side-effect-free fallback value: constants, names, attribute
    chains, and tuple/list/set/dict displays built from those."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return True
    if isinstance(node, ast.Attribute):
        return _is_simple_expr(node.value)
    if isinstance(node, ast.UnaryOp):
        return _is_simple_expr(node.operand)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(_is_simple_expr(e) for e in node.elts)
    if isinstance(node, ast.Dict):
        return all(k is not None and _is_simple_expr(k) for k in node.keys) and all(
            _is_simple_expr(v) for v in node.values
        )
    return False
