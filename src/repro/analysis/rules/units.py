"""R3 ``units-suffix`` / mixed-unit arithmetic — millisecond discipline.

Every quantity feeding the Eq.-2/3 math is in milliseconds; wall-clock
measurements surface as seconds.  Two checks keep the two families from
silently mixing (the classic 1000x bug):

1. **boundary naming** — time-valued names that cross module boundaries
   (function/method parameters and dataclass fields in ``core``/``eval``/
   ``serving``) must carry an explicit unit suffix (``_ms``, ``_s``,
   ``_us``, ``_ns``).  "Time-valued" is judged by the name itself: exact
   words like ``deadline``/``latency``/``makespan`` or suffixes like
   ``_time``/``_latency``/``_deadline``.  Private helpers (leading
   underscore scope) are exempt — the contract is about *boundaries*.
2. **mixed arithmetic** — an ``_ms``-suffixed operand may not meet an
   ``_s``-suffixed one in ``+``/``-``/comparison without an explicit
   conversion (multiplication/division are how conversions are written,
   so they are exempt).

Pre-existing accepted names (e.g. ``Request.true_time``, grandfathered
with its documented c1-unit semantics) ride the committed
``ANALYSIS_baseline.json`` rather than inline suppressions.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

_UNIT_SUFFIXES = ("_ms", "_s", "_us", "_ns", "_sec", "_seconds", "_millis")

_TIME_EXACT = {
    "deadline",
    "duration",
    "elapsed",
    "latency",
    "makespan",
    "timeout",
}
_TIME_SUFFIXES = (
    "_time",
    "_times",
    "_latency",
    "_latencies",
    "_deadline",
    "_duration",
    "_timeout",
    "_elapsed",
)

# unit classes for the mixed-arithmetic check
_MS_SUFFIXES = ("_ms", "_millis")
_S_SUFFIXES = ("_s", "_sec", "_seconds")


def _has_unit_suffix(name: str) -> bool:
    return name.endswith(_UNIT_SUFFIXES)


def _is_time_name(name: str) -> bool:
    return name in _TIME_EXACT or name.endswith(_TIME_SUFFIXES)


def _unit_of(node: ast.AST) -> str | None:
    """'ms' | 's' when the expression is a unit-suffixed name/attribute."""
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    else:
        return None
    if name.endswith(_MS_SUFFIXES):
        return "ms"
    if name.endswith(_S_SUFFIXES):
        return "s"
    return None


class UnitsRule:
    rule_id = "R3"
    name = "units-suffix"
    zones = ("src/repro/core", "src/repro/eval", "src/repro/serving")
    description = (
        "time-valued names crossing module boundaries carry _ms/_s "
        "suffixes; _ms and _s operands never mix without conversion"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._check_boundaries(ctx)
        yield from self._check_mixing(ctx)

    # -- 1. boundary naming ---------------------------------------------
    def _check_boundaries(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name.startswith("_"):
                    continue  # private helper — not a module boundary
                args = (
                    node.args.posonlyargs + node.args.args + node.args.kwonlyargs
                )
                for a in args:
                    if a.arg in ("self", "cls"):
                        continue
                    if _is_time_name(a.arg) and not _has_unit_suffix(a.arg):
                        yield ctx.finding(
                            self,
                            a,
                            f"parameter `{a.arg}` of public `{node.name}()` "
                            "is time-valued but carries no unit suffix; "
                            f"name it `{a.arg}_ms` (or `_s`)",
                        )
            elif isinstance(node, ast.ClassDef):
                for stmt in node.body:
                    if not isinstance(stmt, ast.AnnAssign):
                        continue
                    tgt = stmt.target
                    if not isinstance(tgt, ast.Name):
                        continue
                    if tgt.id.startswith("_"):
                        continue
                    if _is_time_name(tgt.id) and not _has_unit_suffix(tgt.id):
                        yield ctx.finding(
                            self,
                            stmt,
                            f"field `{node.name}.{tgt.id}` is time-valued "
                            "but carries no unit suffix; name it "
                            f"`{tgt.id}_ms` (or `_s`)",
                        )

    # -- 2. mixed-unit arithmetic ---------------------------------------
    def _check_mixing(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)
            ):
                pairs = [(node.left, node.right)]
            elif isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                pairs = list(zip(operands, operands[1:]))
            else:
                continue
            for left, right in pairs:
                ul, ur = _unit_of(left), _unit_of(right)
                if ul is not None and ur is not None and ul != ur:
                    yield ctx.finding(
                        self,
                        node,
                        f"mixing `_{ul}` and `_{ur}` operands in "
                        "+/-/comparison without an explicit conversion "
                        "(multiply by the factor first)",
                    )
                    break
