"""R1 ``determinism-wallclock`` — no wall-clock or global-RNG calls in
replay-determinism zones.

The eval pipeline's serial ≡ parallel replay guarantee (DESIGN.md §8) and
the seeded trace regeneration contract both die silently the moment a
sim/scheduler/eval module reads the wall clock or an unseeded RNG.  Flags:

- ``time.time``/``time.monotonic``/``time.perf_counter`` (+ ``_ns``
  variants) and ``datetime.now``/``utcnow``/``today`` — legitimate
  wall-clock *measurement* sites (scheduler-overhead timing, CLI progress)
  carry an explicit ``# simlint: ignore[R1] -- ...`` justification;
- any call through the stdlib ``random`` module (process-global state);
- legacy global ``numpy.random.*`` functions (``seed``/``shuffle``/...);
- ``numpy.random.default_rng()`` with *no* seed argument.

Seeded ``default_rng(seed)`` construction and passing
``numpy.random.Generator`` objects around are the approved idiom and are
not flagged.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

_WALLCLOCK = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.process_time",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.date.today",
}

_NUMPY_GLOBAL = {
    "seed",
    "random",
    "rand",
    "randn",
    "randint",
    "random_sample",
    "choice",
    "shuffle",
    "permutation",
    "uniform",
    "normal",
    "exponential",
    "poisson",
}


class DeterminismRule:
    rule_id = "R1"
    name = "determinism-wallclock"
    zones = (
        "src/repro/core",
        "src/repro/eval",
        "src/repro/serving",
        "src/repro/launch",
    )
    description = (
        "wall-clock, stdlib-random and unseeded numpy RNG calls are banned "
        "in sim/scheduler/eval-replay modules"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.resolve_call(node)
            if target is None:
                continue
            if target in _WALLCLOCK:
                yield ctx.finding(
                    self,
                    node,
                    f"wall-clock call `{target}()` in a determinism zone; "
                    "sim/eval code must run on virtual time (suppress real "
                    "measurement sites with a justification)",
                )
            elif target.startswith("random."):
                yield ctx.finding(
                    self,
                    node,
                    f"stdlib `{target}()` uses process-global RNG state; "
                    "thread a seeded `numpy.random.Generator` instead",
                )
            elif target in ("numpy.random.default_rng", "np.random.default_rng"):
                if not node.args and not node.keywords:
                    yield ctx.finding(
                        self,
                        node,
                        "`numpy.random.default_rng()` without a seed is "
                        "entropy-seeded; pass the replayed seed explicitly",
                    )
            elif (
                target.startswith("numpy.random.")
                and target.rsplit(".", 1)[-1] in _NUMPY_GLOBAL
            ):
                yield ctx.finding(
                    self,
                    node,
                    f"global `{target}()` mutates/reads shared numpy RNG "
                    "state; use a seeded `numpy.random.Generator`",
                )
