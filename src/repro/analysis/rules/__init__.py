"""Rule implementations for the ``repro.analysis`` pass (DESIGN.md §9).

One module per contract family; :data:`repro.analysis.registry.ALL_RULES`
assembles them in rule-id order.
"""

from __future__ import annotations

from .determinism import DeterminismRule
from .prng import PrngKeyReuseRule
from .units import UnitsRule
from .replay import ReplayOrderRule
from .hotpath import HotPathAllocRule
from .tracer import TracerHygieneRule
from .faultswallow import FaultSwallowRule

__all__ = [
    "DeterminismRule",
    "PrngKeyReuseRule",
    "UnitsRule",
    "ReplayOrderRule",
    "HotPathAllocRule",
    "TracerHygieneRule",
    "FaultSwallowRule",
]
