"""R6 ``tracer-hygiene`` — no Python control flow on traced values and no
host callbacks inside ``@jax.jit`` / Pallas-kernel bodies.

A Python ``if``/``while`` on a traced array raises
``TracerBoolConversionError`` at trace time — or worse, silently bakes one
branch into the compiled program when the value happens to be concrete
during tracing.  Host callbacks (``print``, ``.item()``, ``np.asarray``)
force a device sync and break the "HLO is free of host round-trips"
property the roofline/profiling tier relies on (see the kernels' module
docstrings).

What counts as a jit/kernel body (AST-only heuristics):

- functions decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)`` —
  names listed in a literal ``static_argnames`` are treated as static;
- functions whose name is passed (directly or through
  ``functools.partial``) as the first argument to ``pl.pallas_call`` —
  their *positional* parameters are refs/traced operands, while
  keyword-only parameters are the compile-time config the
  ``partial(...)`` binds (the repo-wide kernel idiom).

Inside such a body the rule flags ``if``/``while`` whose test reads a
traced parameter (``.shape``/``.ndim``/``.dtype``/``.size`` attribute
chains are static and stay silent — shape-driven branching is fine),
``print``/``float``/``int``/``bool`` applied to a traced parameter,
``.item()`` calls, and anything from ``jax.experimental.host_callback``.
Use ``jax.lax.cond``/``jnp.where``/``pl.when`` or hoist the branch to a
static kwarg instead.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_JIT_NAMES = {"jax.jit", "jax.pmap", "pl.pallas_call"}
_CAST_CALLS = {"print", "float", "int", "bool"}


class TracerHygieneRule:
    rule_id = "R6"
    name = "tracer-hygiene"
    zones = (
        "src/repro/kernels",
        "src/repro/models",
        "src/repro/serving",
        "src/repro/launch",
    )
    description = (
        "Python if/while on traced values or host callbacks inside "
        "jit/Pallas bodies; use lax.cond/jnp.where/pl.when"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if "jax" not in ctx.source:
            return
        kernel_names = _pallas_kernel_names(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            traced = self._traced_params(ctx, node, kernel_names)
            if traced is None:
                continue
            yield from self._check_body(ctx, node, traced)

    # -- classification -------------------------------------------------
    def _traced_params(
        self,
        ctx: FileContext,
        fn: ast.FunctionDef | ast.AsyncFunctionDef,
        kernel_names: set[str],
    ) -> set[str] | None:
        """Traced parameter names, or None when fn is not a jit/kernel body."""
        static: set[str] = set()
        is_traced_fn = False
        for dec in fn.decorator_list:
            target = ctx.resolve(dec)
            if target in ("jax.jit", "jax.pmap"):
                is_traced_fn = True
            elif isinstance(dec, ast.Call):
                call_target = ctx.resolve_call(dec)
                inner = dec.args[0] if dec.args else None
                if call_target in ("jax.jit", "jax.pmap") or (
                    call_target in ("functools.partial", "partial")
                    and inner is not None
                    and ctx.resolve(inner) in ("jax.jit", "jax.pmap")
                ):
                    is_traced_fn = True
                    static |= _literal_static_argnames(dec)
        positional_only = False
        if fn.name in kernel_names:
            is_traced_fn = True
            positional_only = True  # kw-only params are compile-time config
        if not is_traced_fn:
            return None
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        if not positional_only:
            params += [a.arg for a in fn.args.kwonlyargs]
        return {p for p in params if p not in static and p not in ("self", "cls")}

    # -- body checks -----------------------------------------------------
    def _check_body(
        self,
        ctx: FileContext,
        fn: ast.FunctionDef | ast.AsyncFunctionDef,
        traced: set[str],
    ) -> Iterator[Finding]:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                name = _traced_name_in(node.test, traced)
                if name is not None:
                    kw = "while" if isinstance(node, ast.While) else "if"
                    yield ctx.finding(
                        self,
                        node,
                        f"Python `{kw}` on traced value `{name}` inside "
                        f"`{fn.name}`; use jax.lax.cond/jnp.where/pl.when "
                        "or make it a static kwarg",
                    )
            elif isinstance(node, ast.Call):
                target = ctx.resolve_call(node)
                fname = node.func.id if isinstance(node.func, ast.Name) else None
                if fname in _CAST_CALLS and any(
                    isinstance(a, ast.Name) and a.id in traced for a in node.args
                ):
                    yield ctx.finding(
                        self,
                        node,
                        f"host-side `{fname}()` of a traced value inside "
                        f"`{fn.name}` forces a sync at trace time",
                    )
                elif isinstance(node.func, ast.Attribute) and node.func.attr == "item":
                    yield ctx.finding(
                        self,
                        node,
                        f"`.item()` inside `{fn.name}` is a host round-trip; "
                        "keep the value on device",
                    )
                elif target is not None and "host_callback" in target:
                    yield ctx.finding(
                        self,
                        node,
                        f"host callback `{target}` inside `{fn.name}`; "
                        "jit/kernel bodies must stay device-only",
                    )


def _traced_name_in(test: ast.AST, traced: set[str]) -> str | None:
    """First traced param read by ``test``, ignoring static attribute
    chains (``x.shape[0]`` etc.)."""
    stack = [test]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            continue  # prune: static metadata access
        if isinstance(node, ast.Compare) and _is_none_identity(node):
            continue  # prune: `x is None` is decided before tracing
        if isinstance(node, ast.Name) and node.id in traced:
            return node.id
        stack.extend(ast.iter_child_nodes(node))
    return None


def _is_none_identity(node: ast.Compare) -> bool:
    """``x is None`` / ``x is not None`` — the optional-argument idiom;
    identity against None is resolved on the Python side, never traced."""
    return all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) and all(
        isinstance(c, ast.Constant) and c.value is None for c in node.comparators
    )


def _literal_static_argnames(dec: ast.Call) -> set[str]:
    out: set[str] = set()
    for kw in dec.keywords:
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
    return out


def _pallas_kernel_names(ctx: FileContext) -> set[str]:
    """Function names passed (directly or via functools.partial, possibly
    through one local alias) as the first argument to ``pl.pallas_call``."""
    partial_of: dict[str, str] = {}  # local name -> wrapped function name
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if ctx.resolve_call(node.value) in ("functools.partial", "partial"):
                inner = node.value.args[0] if node.value.args else None
                if isinstance(inner, ast.Name):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            partial_of[tgt.id] = inner.id
    out: set[str] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = ctx.resolve_call(node)
        if target is None or not target.endswith("pallas_call"):
            continue
        if not node.args:
            continue
        first = node.args[0]
        if isinstance(first, ast.Name):
            out.add(partial_of.get(first.id, first.id))
        elif isinstance(first, ast.Call) and ctx.resolve_call(first) in (
            "functools.partial",
            "partial",
        ):
            inner = first.args[0] if first.args else None
            if isinstance(inner, ast.Name):
                out.add(inner.id)
    return out
