"""R5 ``hotpath-alloc`` — the vectorized hot path stays allocation-lean.

PR 2 rebuilt the arrival/dispatch path around bulk numpy passes
(``BinScoreModel.score_many`` + ``HullQueue.insert_many``); its perf floor
is CI-gated via ``BENCH_sched.json``.  The contract: inside the hot
functions, *per-item loops must not allocate containers* — one bulk
allocation per call is the approved shape, a dict/list/set birth per
request is the regression this rule catches before the benchmark does.

Scope is an explicit allowlist of (file suffix, qualified function) pairs
— the scheduler arrival path and the event-loop inner loop — so ordinary
code keeps full freedom.  Within those functions the rule flags, *inside
any loop body*: container literals/displays, ``list``/``dict``/``set``
constructor calls, comprehensions, and ``lambda`` creation.  Allocations
that are semantically required (per-request feasibility state, the
coalescing buffers) carry inline ``# simlint: ignore[R5]`` justifications
— the suppression is the documentation.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

# (path suffix, qualified scope) — the PR-2 scheduler hot path plus the
# PR-7 array event engine (the whole point of which is bulk columnar
# work; a per-event container birth there is a regression)
HOT_FUNCTIONS: tuple[tuple[str, str], ...] = (
    ("core/scheduler.py", "OrlojScheduler.on_arrivals"),
    ("core/scheduler.py", "OrlojScheduler.next_batch"),
    ("core/eventloop.py", "run_event_loop"),
    ("core/eventloop.py", "run_event_loop.try_dispatch"),
    ("core/eventloop.py", "_array_loop"),
    ("core/eventloop.py", "_array_loop.try_dispatch"),
)

_CTOR_CALLS = {"list", "dict", "set"}
_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_SCOPE_BARRIERS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class HotPathAllocRule:
    rule_id = "R5"
    name = "hotpath-alloc"
    zones = ("src/repro/core",)
    description = (
        "per-item container allocation inside the vectorized scheduler/"
        "event-loop hot path (PR 2 contract)"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        hot_scopes = {
            scope for suffix, scope in HOT_FUNCTIONS if ctx.path.endswith(suffix)
        }
        if not hot_scopes:
            return
        index = _function_index(ctx.tree)
        seen: set[tuple[int, int]] = set()  # dedupe under nested loops
        for qual, fn in index.items():
            if qual not in hot_scopes:
                continue
            for loop in _scoped_nodes(fn.body):
                if not isinstance(loop, _LOOPS):
                    continue
                for node in _scoped_nodes(loop.body):
                    kind = _alloc_kind(node)
                    pos = (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))
                    if kind is not None and pos not in seen:
                        seen.add(pos)
                        yield ctx.finding(
                            self,
                            node,
                            f"{kind} allocated inside a `{qual}` loop body — "
                            "the hot path allocates in bulk, once per call "
                            "(PR 2 vectorization contract)",
                        )


def _alloc_kind(node: ast.AST) -> str | None:
    if isinstance(node, ast.ListComp):
        return "list comprehension"
    if isinstance(node, ast.SetComp):
        return "set comprehension"
    if isinstance(node, ast.DictComp):
        return "dict comprehension"
    if isinstance(node, ast.List):
        return "list literal"
    if isinstance(node, ast.Dict):
        return "dict literal"
    if isinstance(node, ast.Set):
        return "set literal"
    if isinstance(node, ast.Lambda):
        return "lambda"
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in _CTOR_CALLS
    ):
        return f"{node.func.id}() call"
    return None


def _scoped_nodes(body: list[ast.stmt]) -> Iterator[ast.AST]:
    """Pre-order walk not descending into nested defs (a nested helper is
    its own hot-list entry if it matters)."""
    stack: list[ast.AST] = list(reversed(body))
    while stack:
        node = stack.pop()
        if isinstance(node, _SCOPE_BARRIERS):
            continue
        yield node
        stack.extend(reversed(list(ast.iter_child_nodes(node))))


def _function_index(
    tree: ast.Module,
) -> dict[str, ast.FunctionDef | ast.AsyncFunctionDef]:
    out: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                out[qual] = child
                visit(child, qual)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}.{child.name}" if prefix else child.name)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out
