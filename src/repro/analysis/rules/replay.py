"""R4 ``replay-order`` — no unordered-set iteration in replay-critical code.

The serial ≡ parallel replay guarantee and bitwise trace regeneration
(DESIGN.md §8) require every loop whose body touches event ordering or
result aggregation to run in a deterministic order.  ``dict`` iteration is
insertion-ordered in CPython and the codebase leans on that deliberately;
``set``/``frozenset`` iteration however follows hash-table layout, which
for str keys changes with ``PYTHONHASHSEED`` — the classic
AccaSim-style nondeterministic-replay bug.

The rule flags iteration (``for``/comprehension generators) and
order-leaking conversions (``list()``/``tuple()``/``enumerate()``/
``zip()``) over expressions it can prove set-typed:

- ``{a, b}`` literals, set comprehensions, ``set(...)``/``frozenset(...)``;
- set operators (``|``/``&``/``-``/``^``) and set methods
  (``.union``/``.intersection``/``.difference``/``.symmetric_difference``);
- ``d.pop(k, set())`` / ``d.get(k, set())`` / ``d.setdefault(k, set())`` —
  the stored-or-default pattern the scheduler uses for feasibility sets;
- local names last bound to any of the above, and parameters/locals
  annotated as sets.

``sorted(...)`` (and other order-insensitive reducers: ``min``/``max``/
``sum``/``len``/``any``/``all``) are the approved remedies and stay
silent.  The analysis is per-scope and flow-insensitive across branches;
it intentionally misses sets that arrive through attributes or call
boundaries — those are covered by the replay regression tests.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding

_SET_METHODS = {"union", "intersection", "difference", "symmetric_difference"}
_DEFAULTING_METHODS = {"pop", "get", "setdefault"}
_ORDER_LEAK_CALLS = {"list", "tuple", "enumerate", "zip", "iter", "reversed"}
_SCOPE_BARRIERS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)


class ReplayOrderRule:
    rule_id = "R4"
    name = "replay-order"
    zones = ("src/repro/core", "src/repro/eval", "src/repro/serving")
    description = (
        "iterating an unordered set where order can leak into event "
        "ordering or aggregation; wrap in sorted(...)"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._check_scope(ctx, ctx.tree.body, set())
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                set_names = {
                    a.arg
                    for a in (
                        node.args.posonlyargs + node.args.args + node.args.kwonlyargs
                    )
                    if a.annotation is not None and _is_set_annotation(a.annotation)
                }
                yield from self._check_scope(ctx, node.body, set_names)

    def _check_scope(
        self, ctx: FileContext, body: list[ast.stmt], set_names: set[str]
    ) -> Iterator[Finding]:
        for node in _walk_scope(body):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        if _is_set_expr(node.value, set_names):
                            set_names.add(tgt.id)
                        else:
                            set_names.discard(tgt.id)
            elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
                if _is_set_annotation(node.annotation):
                    set_names.add(node.target.id)
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if _is_set_expr(node.iter, set_names):
                    yield self._flag(ctx, node.iter, "for-loop")
            elif isinstance(
                node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
            ):
                for gen in node.generators:
                    if _is_set_expr(gen.iter, set_names):
                        yield self._flag(ctx, gen.iter, "comprehension")
            elif isinstance(node, ast.Call):
                fn_name = node.func.id if isinstance(node.func, ast.Name) else None
                if fn_name in _ORDER_LEAK_CALLS:
                    for arg in node.args:
                        if _is_set_expr(arg, set_names):
                            yield self._flag(ctx, arg, f"{fn_name}() conversion")

    def _flag(self, ctx: FileContext, node: ast.AST, where: str) -> Finding:
        return ctx.finding(
            self,
            node,
            f"unordered set iterated via {where}; order can differ between "
            "runs (PYTHONHASHSEED) — wrap in sorted(...) or use an "
            "insertion-ordered dict",
        )


def _walk_scope(body: list[ast.stmt]) -> Iterator[ast.AST]:
    """Pre-order walk that does not descend into nested def/class/lambda
    (each scope is analyzed separately with its own binding table)."""
    stack: list[ast.AST] = list(reversed(body))
    while stack:
        node = stack.pop()
        if isinstance(node, _SCOPE_BARRIERS):
            continue
        yield node
        stack.extend(reversed(list(ast.iter_child_nodes(node))))


def _is_set_annotation(node: ast.AST) -> bool:
    base = node.value if isinstance(node, ast.Subscript) else node
    name = base.id if isinstance(base, ast.Name) else getattr(base, "attr", None)
    return name in ("set", "frozenset", "Set", "FrozenSet", "AbstractSet", "MutableSet")


def _is_set_expr(node: ast.AST, set_names: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)
    ):
        return _is_set_expr(node.left, set_names) or _is_set_expr(
            node.right, set_names
        )
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in _SET_METHODS:
                return True
            if (
                node.func.attr in _DEFAULTING_METHODS
                and len(node.args) >= 2
                and _is_set_expr(node.args[1], set_names)
            ):
                return True
    return False
