"""R2 ``prng-key-reuse`` — JAX PRNG key discipline.

The functional-PRNG contract this repo's kernel/model tier relies on:

- after ``split(key, ...)`` the *parent* binding is dead — using it again
  (sampling, folding, re-splitting, or passing it onward) risks colliding
  with the split's own children.  ``fold_in(key, 7)`` after
  ``split(key, 8)`` is the canonical collision (the ``models/ssm.py``
  probe this rule was built around: ``fold_in(k, i)`` and ``split(k, n)[i]``
  are derived from the same hash family);
- ``fold_in(key, data)`` with *distinct* data values is the approved way
  to derive many children from one parent, so folding does not retire the
  key — but a folded parent must not also be consumed by a sampler or
  re-split;
- a key consumed by a sampler (``normal``/``randint``/...) is spent: any
  further ``split``/``fold_in``/sampler use of the same binding yields
  correlated streams.

Detection is a per-function linear scan.  Rebinding
(``rng, sub = jax.random.split(rng)``) clears the name, so the canonical
carry idiom stays silent; ``if``/``else`` branches are analyzed
independently then merged (exclusive per-branch uses stay silent,
use-after-branch is caught); loop bodies are scanned twice so loop-carried
reuse (``for i in ...: x = normal(rng)``) is caught.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from ..core import FileContext, Finding

_SAMPLERS = {
    "ball",
    "bernoulli",
    "beta",
    "bits",
    "categorical",
    "cauchy",
    "chisquare",
    "choice",
    "dirichlet",
    "exponential",
    "gamma",
    "gumbel",
    "laplace",
    "loggamma",
    "logistic",
    "maxwell",
    "multivariate_normal",
    "normal",
    "orthogonal",
    "pareto",
    "permutation",
    "poisson",
    "randint",
    "rayleigh",
    "t",
    "truncated_normal",
    "uniform",
    "weibull_min",
}

# mark of a key binding -> use kinds that violate the contract
_VIOLATES = {
    "split": {"split", "fold", "sampler", "other"},
    "folded": {"split", "sampler"},
    "consumed": {"split", "fold", "sampler"},
}

_VERB = {"split": "split", "folded": "folded (fold_in)", "consumed": "consumed by a sampler"}


@dataclasses.dataclass
class _State:
    marks: dict[str, tuple[str, int]] = dataclasses.field(default_factory=dict)

    def copy(self) -> "_State":
        return _State(dict(self.marks))

    def merge(self, other: "_State") -> None:
        self.marks.update(other.marks)

    def rebind(self, name: str) -> None:
        self.marks.pop(name, None)


def _use_kind(ctx: FileContext, call: ast.Call) -> str:
    target = ctx.resolve_call(call)
    if target == "jax.random.split":
        return "split"
    if target == "jax.random.fold_in":
        return "fold"
    if (
        target is not None
        and target.startswith("jax.random.")
        and target.rsplit(".", 1)[-1] in _SAMPLERS
    ):
        return "sampler"
    return "other"


def _key_arg(call: ast.Call) -> str | None:
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    for kw in call.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            return kw.value.id
    return None


def _assigned_names(node: ast.stmt) -> list[str]:
    targets: list[ast.expr] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    out: list[str] = []
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, ast.Name):
                out.append(sub.id)
    return out


class PrngKeyReuseRule:
    rule_id = "R2"
    name = "prng-key-reuse"
    zones = ("src", "tests", "examples", "benchmarks")
    description = (
        "a jax.random key that was split must not be reused; folded or "
        "sampler-consumed keys must not also feed other derivations"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if "jax" not in ctx.source:  # cheap pre-filter
            return
        seen: set[tuple[int, str]] = set()
        out: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_block(ctx, node.body, _State(), seen, out)
        out.sort(key=lambda f: (f.line, f.col))
        yield from out

    # -- linear scan ----------------------------------------------------
    def _scan_block(
        self,
        ctx: FileContext,
        stmts: list[ast.stmt],
        state: _State,
        seen: set[tuple[int, str]],
        out: list[Finding],
    ) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue  # nested defs get their own top-level scan
            if isinstance(stmt, ast.If):
                s1, s2 = state.copy(), state.copy()
                self._scan_block(ctx, stmt.body, s1, seen, out)
                self._scan_block(ctx, stmt.orelse, s2, seen, out)
                state.merge(s1)
                state.merge(s2)
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                self._scan_header(ctx, stmt, state, seen, out)
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    for name in (
                        n.id for n in ast.walk(stmt.target) if isinstance(n, ast.Name)
                    ):
                        state.rebind(name)
                # two passes: the second sees the first's marks, i.e.
                # loop-carried single-use violations
                for _ in range(2):
                    self._scan_block(ctx, stmt.body, state, seen, out)
                self._scan_block(ctx, stmt.orelse, state, seen, out)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                self._scan_header(ctx, stmt, state, seen, out)
                self._scan_block(ctx, stmt.body, state, seen, out)
                continue
            if isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody):
                    self._scan_block(ctx, block, state, seen, out)
                for handler in stmt.handlers:
                    self._scan_block(ctx, handler.body, state, seen, out)
                continue
            self._scan_exprs(ctx, [stmt], state, seen, out)
            for name in _assigned_names(stmt):
                state.rebind(name)

    def _scan_header(self, ctx, stmt, state, seen, out) -> None:
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            roots: list[ast.AST] = [stmt.iter]
        elif isinstance(stmt, ast.While):
            roots = [stmt.test]
        else:
            roots = [item.context_expr for item in stmt.items]
        self._scan_exprs(ctx, roots, state, seen, out)

    def _scan_exprs(
        self,
        ctx: FileContext,
        roots: list[ast.AST],
        state: _State,
        seen: set[tuple[int, str]],
        out: list[Finding],
    ) -> None:
        calls = [
            n for root in roots for n in ast.walk(root) if isinstance(n, ast.Call)
        ]
        # 1) uses of already-marked bindings
        for call in calls:
            kind = _use_kind(ctx, call)
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                if not isinstance(arg, ast.Name):
                    continue
                got = state.marks.get(arg.id)
                if got is None:
                    continue
                mark, line = got
                if kind in _VIOLATES[mark]:
                    self._emit(
                        ctx, seen, out, arg,
                        f"PRNG key `{arg.id}` was {_VERB[mark]} on line {line} "
                        f"and is used again here ({kind} use); derive a fresh "
                        "child key instead of reusing the binding",
                    )
        # 2) new marks from this statement
        for call in calls:
            kind = _use_kind(ctx, call)
            if kind == "other":
                continue
            nm = _key_arg(call)
            if nm is None:
                continue
            mark = {"split": "split", "fold": "folded", "sampler": "consumed"}[kind]
            prev = state.marks.get(nm)
            # split dominates folded/consumed; never downgrade a mark
            if prev is None or mark == "split":
                state.marks[nm] = (mark, call.lineno)

    def _emit(
        self,
        ctx: FileContext,
        seen: set[tuple[int, str]],
        out: list[Finding],
        node: ast.AST,
        message: str,
    ) -> None:
        key = (getattr(node, "lineno", 0), message)
        if key in seen:
            return
        seen.add(key)
        out.append(ctx.finding(self, node, message))
