"""Rule registry: the canonical, ordered catalogue of simlint rules.

Rules register here (not in the CLI) so library users, the test fixtures
and the CLI all agree on what "all rules" means.  Adding a rule is: write
the class, append it to :data:`ALL_RULES`, add its fixtures, document the
contract in DESIGN.md §9.
"""

from __future__ import annotations

from typing import Sequence

from .core import Rule
from .rules import (
    DeterminismRule,
    FaultSwallowRule,
    HotPathAllocRule,
    PrngKeyReuseRule,
    ReplayOrderRule,
    TracerHygieneRule,
    UnitsRule,
)

ALL_RULES: tuple[Rule, ...] = (
    DeterminismRule(),
    PrngKeyReuseRule(),
    UnitsRule(),
    ReplayOrderRule(),
    HotPathAllocRule(),
    TracerHygieneRule(),
    FaultSwallowRule(),
)

_BY_ID = {r.rule_id: r for r in ALL_RULES}
_BY_NAME = {r.name: r for r in ALL_RULES}


def get_rules(selectors: Sequence[str] | None = None) -> tuple[Rule, ...]:
    """Rules by id ("R2") or name ("prng-key-reuse"); all when None."""
    if not selectors:
        return ALL_RULES
    out: list[Rule] = []
    for sel in selectors:
        rule = _BY_ID.get(sel) or _BY_NAME.get(sel)
        if rule is None:
            known = ", ".join(sorted(_BY_ID))
            raise KeyError(f"unknown rule {sel!r}; known ids: {known}")
        if rule not in out:
            out.append(rule)
    return tuple(out)
