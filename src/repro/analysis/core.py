"""Visitor core of the ``repro.analysis`` pass.

A :class:`Rule` sees one parsed module at a time through a
:class:`FileContext` that pre-computes what every rule needs: the
repo-relative posix path (zone matching), raw source lines (suppression
comments), an import-alias map (so ``import time as _t; _t.perf_counter``
still resolves to ``time.perf_counter``) and a qualified-scope index
(``OrlojScheduler.on_arrivals``) for stable baseline fingerprints.

Suppression contract (DESIGN.md §9): a finding on line ``L`` is silenced
when line ``L`` — or a standalone comment line directly above it — carries
``# simlint: ignore[<id>, ...]`` naming the rule id (or ``*``).  A ``--``
justification is part of the convention; ``--check`` rejects bare
suppressions.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Iterable, Iterator, Protocol, Sequence

__all__ = [
    "FileContext",
    "Finding",
    "Rule",
    "Suppression",
    "analyze_paths",
    "analyze_source",
    "dotted_name",
    "iter_python_files",
]

_SUPPRESS_RE = re.compile(
    r"#\s*simlint:\s*(?P<verb>ignore|skip-file)"
    r"(?:\[(?P<ids>[^\]]*)\])?"
    r"(?:\s*--\s*(?P<why>.*))?"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete source location."""

    rule: str  # "R1" .. "R6"
    name: str  # e.g. "determinism-wallclock"
    path: str  # repo-relative posix path (or a virtual path in tests)
    line: int  # 1-indexed
    col: int
    scope: str  # qualified enclosing scope, "<module>" at top level
    message: str

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# simlint: ignore[...]`` comment."""

    line: int  # line the suppression *applies to*
    rule_ids: frozenset[str]  # {"*"} for a blanket ignore
    justified: bool

    def covers(self, finding: Finding) -> bool:
        return finding.line == self.line and (
            "*" in self.rule_ids or finding.rule in self.rule_ids
        )


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class FileContext:
    """Everything rules need about one module, computed once."""

    def __init__(self, path: str, source: str, tree: ast.Module | None = None):
        self.path = Path(path).as_posix()
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree if tree is not None else ast.parse(source, filename=path)
        self.aliases = _import_aliases(self.tree)
        self._scopes = _scope_index(self.tree)
        self.suppressions = _parse_suppressions(self.lines)
        self.skip_file = any(
            m and m.group("verb") == "skip-file"
            for m in (_SUPPRESS_RE.search(ln) for ln in self.lines[:5])
        )

    # -- zone matching -------------------------------------------------
    def in_zone(self, prefixes: Sequence[str]) -> bool:
        return any(
            self.path.startswith(p.rstrip("/") + "/") or self.path == p
            for p in prefixes
        )

    # -- name resolution -----------------------------------------------
    def resolve_call(self, node: ast.Call) -> str | None:
        """Fully-qualified dotted name of a call target, alias-expanded."""
        return self.resolve(node.func)

    def resolve(self, node: ast.AST) -> str | None:
        dn = dotted_name(node)
        if dn is None:
            return None
        head, _, rest = dn.partition(".")
        real = self.aliases.get(head, head)
        return real + ("." + rest if rest else "")

    # -- scopes ---------------------------------------------------------
    def scope_of(self, node: ast.AST) -> str:
        line = getattr(node, "lineno", 0)
        best = "<module>"
        best_span = None
        for qual, (lo, hi) in self._scopes.items():
            if lo <= line <= hi and (best_span is None or lo >= best_span):
                best, best_span = qual, lo
        return best

    # -- findings -------------------------------------------------------
    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(
            rule=rule.rule_id,
            name=rule.name,
            path=self.path,
            line=getattr(node, "lineno", 0),
            col=getattr(node, "col_offset", 0),
            scope=self.scope_of(node),
            message=message,
        )

    def is_suppressed(self, finding: Finding) -> bool:
        return any(s.covers(finding) for s in self.suppressions)

    def suppression_for(self, finding: Finding) -> Suppression | None:
        for s in self.suppressions:
            if s.covers(finding):
                return s
        return None


class Rule(Protocol):
    """One machine-checked contract.  Implementations are stateless."""

    rule_id: str  # "R1"
    name: str  # "determinism-wallclock"
    zones: tuple[str, ...]  # path prefixes the rule applies to
    description: str

    def check(self, ctx: FileContext) -> Iterator[Finding]: ...


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name != "*":
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _scope_index(tree: ast.Module) -> dict[str, tuple[int, int]]:
    """qualname -> (first line, last line) for every def/class."""
    out: dict[str, tuple[int, int]] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                end = getattr(child, "end_lineno", child.lineno)
                out[qual] = (child.lineno, end or child.lineno)
                visit(child, qual)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def _parse_suppressions(lines: Sequence[str]) -> list[Suppression]:
    out: list[Suppression] = []
    for i, raw in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m or m.group("verb") != "ignore":
            continue
        ids = frozenset(
            s.strip() for s in (m.group("ids") or "*").split(",") if s.strip()
        ) or frozenset({"*"})
        justified = bool((m.group("why") or "").strip())
        # A standalone comment line suppresses the next line instead.
        target = i + 1 if raw.lstrip().startswith("#") else i
        out.append(Suppression(line=target, rule_ids=ids, justified=justified))
    return out


def iter_python_files(paths: Sequence[str]) -> Iterator[Path]:
    seen: set[Path] = set()
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            candidates: Iterable[Path] = [root]
        elif root.is_dir():
            candidates = sorted(root.rglob("*.py"))
        else:
            candidates = []
        for f in candidates:
            if any(part.startswith(".") or part == "__pycache__" for part in f.parts):
                continue
            if f not in seen:
                seen.add(f)
                yield f


def analyze_source(
    source: str,
    path: str,
    rules: Sequence[Rule],
    *,
    keep_suppressed: bool = False,
) -> tuple[list[Finding], list[tuple[Finding, Suppression]]]:
    """Run ``rules`` over one source blob.  Returns (active findings,
    suppressed findings with the suppression that silenced each)."""
    ctx = FileContext(path, source)
    if ctx.skip_file:
        return [], []
    active: list[Finding] = []
    silenced: list[tuple[Finding, Suppression]] = []
    for rule in rules:
        if rule.zones and not ctx.in_zone(rule.zones):
            continue
        for f in rule.check(ctx):
            sup = ctx.suppression_for(f)
            if sup is not None:
                silenced.append((f, sup))
                if keep_suppressed:
                    active.append(f)
            else:
                active.append(f)
    active.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return active, silenced


def analyze_paths(
    paths: Sequence[str],
    rules: Sequence[Rule],
    *,
    on_error: Callable[[str, Exception], None] | None = None,
) -> tuple[list[Finding], list[tuple[Finding, Suppression]]]:
    findings: list[Finding] = []
    silenced: list[tuple[Finding, Suppression]] = []
    for f in iter_python_files(paths):
        try:
            source = f.read_text(encoding="utf-8")
            got, sil = analyze_source(source, str(f), rules)
        except (SyntaxError, UnicodeDecodeError) as exc:
            if on_error is not None:
                on_error(str(f), exc)
            continue
        findings.extend(got)
        silenced.extend(sil)
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.rule))
    return findings, silenced
