"""Reporters: human-readable text and machine-readable JSON.

The human reporter prints ``path:line:col [Rx/name] message`` grouped by
file (the format editors and CI logs both parse); the JSON reporter emits
the full finding list plus the baseline diff so downstream tooling (or
the next PR's dashboards) can consume the gate's verdict directly.
"""

from __future__ import annotations

import json
from collections import Counter
from typing import IO, Sequence

from .baseline import fingerprint
from .core import Finding, Rule, Suppression

__all__ = ["render_human", "render_json"]


def render_human(
    out: IO[str],
    findings: Sequence[Finding],
    new: Sequence[Finding],
    stale: Sequence[str],
    silenced: Sequence[tuple[Finding, Suppression]],
    *,
    verbose: bool = False,
) -> None:
    new_fps = Counter(fingerprint(f) for f in new)
    budget = Counter(new_fps)
    by_file: dict[str, list[Finding]] = {}
    for f in findings:
        by_file.setdefault(f.path, []).append(f)
    for path in sorted(by_file):
        out.write(f"{path}\n")
        for f in by_file[path]:
            fp = fingerprint(f)
            if budget.get(fp, 0) > 0:
                budget[fp] -= 1
                tag = "NEW "
            else:
                tag = "base" if not verbose else "baseline"
            out.write(
                f"  {f.line}:{f.col}  [{f.rule}/{f.name}] ({tag}) {f.message}\n"
            )
    if verbose and silenced:
        out.write(f"# {len(silenced)} suppressed finding(s):\n")
        for f, sup in silenced:
            why = "justified" if sup.justified else "NO JUSTIFICATION"
            out.write(f"#   {f.location()} [{f.rule}] {why}\n")
    if stale:
        out.write(
            f"# {len(stale)} stale baseline entr{'y' if len(stale) == 1 else 'ies'} "
            "(fixed findings — prune with --write-baseline):\n"
        )
        for fp in stale:
            out.write(f"#   {fp}\n")
    out.write(
        f"# {len(findings)} finding(s): {len(new)} new, "
        f"{len(findings) - len(new)} baselined, {len(silenced)} suppressed\n"
    )


def render_json(
    out: IO[str],
    findings: Sequence[Finding],
    new: Sequence[Finding],
    stale: Sequence[str],
    silenced: Sequence[tuple[Finding, Suppression]],
    rules: Sequence[Rule],
) -> None:
    new_set = {id(f) for f in new}
    doc = {
        "tool": "repro.analysis",
        "rules": [
            {"id": r.rule_id, "name": r.name, "description": r.description}
            for r in rules
        ],
        "findings": [
            {
                "rule": f.rule,
                "name": f.name,
                "path": f.path,
                "line": f.line,
                "col": f.col,
                "scope": f.scope,
                "message": f.message,
                "new": id(f) in new_set,
            }
            for f in findings
        ],
        "suppressed": [
            {
                "rule": f.rule,
                "path": f.path,
                "line": f.line,
                "justified": sup.justified,
            }
            for f, sup in silenced
        ],
        "stale_baseline": list(stale),
        "summary": {
            "total": len(findings),
            "new": len(new),
            "baselined": len(findings) - len(new),
            "suppressed": len(silenced),
        },
    }
    json.dump(doc, out, indent=2)
    out.write("\n")
