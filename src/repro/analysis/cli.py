"""``python -m repro.analysis`` — the simlint CLI and CI gate.

Exit status contract (what the ``static-analysis`` CI job keys off):

- ``0`` — no findings beyond the committed baseline (and, under
  ``--check``, every suppression carries a justification);
- ``1`` — at least one *new* finding (or an unjustified suppression under
  ``--check``);
- ``2`` — usage/environment error (unparseable file, unknown rule).

The pass never imports the analyzed code (AST-only), so it runs in
milliseconds with no jax/numpy in the environment.
"""

from __future__ import annotations

import argparse
import sys
from typing import Sequence

from .baseline import Baseline, diff_against_baseline
from .core import analyze_paths
from .registry import ALL_RULES, get_rules
from .report import render_human, render_json

DEFAULT_BASELINE = "ANALYSIS_baseline.json"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (determinism / units / "
        "JAX hygiene contracts — DESIGN.md §9)",
    )
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to analyze (default: src tests)")
    ap.add_argument("--check", action="store_true",
                    help="gate mode: exit 1 on any new finding or "
                    "unjustified suppression")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the JSON report instead of the human one")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: every finding counts as new")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings as the new baseline")
    ap.add_argument("--rules", nargs="*", default=None, metavar="RULE",
                    help="restrict to specific rule ids/names (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("-v", "--verbose", action="store_true")
    return ap


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            zones = ", ".join(r.zones) if r.zones else "everywhere"
            print(f"{r.rule_id}  {r.name:<22} {r.description}")
            print(f"    zones: {zones}")
        return 0

    try:
        rules = get_rules(args.rules)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    errors: list[str] = []

    def on_error(path: str, exc: Exception) -> None:
        errors.append(f"{path}: {exc}")

    findings, silenced = analyze_paths(args.paths, rules, on_error=on_error)
    for msg in errors:
        print(f"error: cannot analyze {msg}", file=sys.stderr)
    if errors:
        return 2

    baseline = (
        Baseline.empty()
        if args.no_baseline
        else Baseline.load(args.baseline)
    )
    new, stale = diff_against_baseline(findings, baseline)

    if args.write_baseline:
        Baseline.from_findings(findings).save(args.baseline)
        print(
            f"# wrote {args.baseline}: {len(findings)} accepted finding(s)",
            file=sys.stderr,
        )
        return 0

    if args.as_json:
        render_json(sys.stdout, findings, new, stale, silenced, rules)
    else:
        render_human(
            sys.stdout, findings, new, stale, silenced, verbose=args.verbose
        )

    if args.check:
        unjustified = [(f, s) for f, s in silenced if not s.justified]
        for f, _ in unjustified:
            print(
                f"error: {f.location()} [{f.rule}] suppression lacks a "
                "`-- justification`",
                file=sys.stderr,
            )
        if new or unjustified:
            return 1
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
