"""``repro.analysis`` — the repo's own static-analysis pass (DESIGN.md §9).

The simulator/scheduler/eval stack only reproduces the paper because it
obeys contracts that ordinary linters cannot express: seeded determinism
(serial ≡ parallel replay), millisecond time-unit discipline feeding the
Eq.-2/3 math, the allocation-free vectorized hot path from PR 2, and JAX
PRNG/tracer hygiene in the kernel tier.  This package turns those prose
contracts into AST-level CI gates:

==  =====================  ==============================================
ID  name                   contract
==  =====================  ==============================================
R1  determinism-wallclock  no wall-clock / global-RNG calls reachable
                           from sim, scheduler or eval-replay modules
R2  prng-key-reuse         a ``jax.random`` key that was split/folded or
                           consumed by a sampler is never used again
R3  units-suffix           time-valued names crossing module boundaries
                           carry ``_ms``/``_s``; no mixed-unit arithmetic
R4  replay-order           no iteration over unordered sets where order
                           can leak into event ordering or aggregation
R5  hotpath-alloc          no per-request dict/list/set churn inside the
                           vectorized scheduler / event-loop hot path
R6  tracer-hygiene         no Python control flow on traced values or
                           host callbacks inside jit / Pallas bodies
==  =====================  ==============================================

Usage::

    python -m repro.analysis --check src tests     # CI gate
    python -m repro.analysis --list-rules          # rule catalogue
    python -m repro.analysis --write-baseline src tests

Findings are suppressed per line with ``# simlint: ignore[R1] -- reason``
(the justification after ``--`` is required by ``--check``) and
pre-existing accepted findings live in the committed
``ANALYSIS_baseline.json``; only *new* findings fail the build.

The pass is AST-only: it imports neither the analyzed modules nor jax, so
it runs in milliseconds on a bare CI container.
"""

from __future__ import annotations

from .core import FileContext, Finding, Rule, analyze_paths, analyze_source
from .registry import ALL_RULES, get_rules
from .baseline import Baseline, diff_against_baseline, fingerprint

__all__ = [
    "ALL_RULES",
    "Baseline",
    "FileContext",
    "Finding",
    "Rule",
    "analyze_paths",
    "analyze_source",
    "diff_against_baseline",
    "fingerprint",
    "get_rules",
]
