"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers, partitions and compiles, and extract the roofline
inputs (FLOPs, bytes, collective traffic, per-device memory).

MUST set the placeholder-device flag before ANY jax import (jax locks the
device count at first init):
"""

import os

if os.environ.get("REPRO_DRYRUN_DEVICES", "1") != "0":
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )
# (REPRO_DRYRUN_DEVICES=0 lets the test suite import the pure helpers in
# this module without forcing 512 placeholder devices onto the process —
# smoke tests must see 1 device.)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, InputShape, for_shape, get_config
from ..models import Model
from ..models.config import ModelConfig
from ..models.sharding import (
    cache_specs,
    input_batch_specs,
    param_specs,
    to_named,
)
from ..optim import AdamWConfig, adamw_init, adamw_update
from .mesh import make_production_mesh

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(",
)
SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        sm = SHAPE_RE.match(line)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + float(n * nbytes)
    return out


# --------------------------------------------------------------- inputs
def input_specs(cfg: ModelConfig, shape: InputShape, model: Model) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    f = jnp.float32
    i = jnp.int32
    front = cfg.n_frontend_tokens
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.frontend == "audio":
            batch["frontend_embeds"] = sd((b, s, model.frontend_dim), f)
        elif cfg.frontend == "vision":
            batch["frontend_embeds"] = sd((b, front, model.frontend_dim), f)
            batch["tokens"] = sd((b, s - front), i)
        else:
            batch["tokens"] = sd((b, s), i)
        if shape.kind == "train":
            batch["labels"] = sd((b, s), i)
        return {"batch": batch}
    # decode: one new token against a cache of seq_len
    tok = (
        sd((b, 1, model.frontend_dim), f)
        if cfg.frontend == "audio"
        else sd((b, 1), i)
    )
    cache = jax.eval_shape(
        lambda: model.init_cache(b, cache_len=s, dtype=jnp.bfloat16)
    )
    return {"tokens": tok, "cache": cache, "pos": sd((), i)}


# ------------------------------------------------------------ dry runs
@dataclasses.dataclass
class DryRunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    seconds: float
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    per_device_memory: dict = dataclasses.field(default_factory=dict)
    error: str = ""
    n_params: int = 0
    # Scan-corrected totals (lax.scan bodies are counted once by
    # cost_analysis; these add body × (L−1) derived from an L0-layer
    # unrolled-vs-scanned compile pair — see run_with_correction).
    flops_corrected: float = 0.0
    bytes_corrected: float = 0.0
    collective_corrected: dict = dataclasses.field(default_factory=dict)
    n_layers: int = 0
    scanned: bool = False

    def row(self) -> str:
        st = "OK " if self.ok else "FAIL"
        return (
            f"{self.arch:18s} {self.shape:12s} {self.mesh:10s} {st} "
            f"{self.seconds:7.1f}s flops={self.flops:.3e} "
            f"coll={sum(self.collective_bytes.values()):.3e}B"
        )


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis(compiled) -> tuple[float, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, nbytes


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    keep_hlo: bool = False,
    save: bool = True,
    unroll: bool = False,
    cfg_override=None,
    tag: str = "",
    decode_cache_layout: str = "",
    moe_ff_axis: str = "",
    serve_params_bf16: bool = False,
) -> DryRunResult:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    cfg = for_shape(get_config(arch), shape)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    model = Model(cfg)
    t0 = time.time()  # simlint: ignore[R1] -- measures real compile time (the artifact's `seconds` field)
    res = DryRunResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False, seconds=0)
    try:
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        if serve_params_bf16 and shape.kind != "train":
            # Serving deployments store bf16 weights (halves the parameter
            # stream per decode step; §Perf).
            params_shape = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
                if l.dtype == jnp.float32
                else l,
                params_shape,
            )
        res.n_params = sum(
            int(np.prod(l.shape)) for l in jax.tree.leaves(params_shape)
        )
        pspecs = param_specs(cfg, params_shape, mesh, moe_ff_axis=moe_ff_axis or None)
        p_sh = to_named(mesh, pspecs)
        ins = input_specs(cfg, shape, model)
        # Active mesh context so P-only with_sharding_constraint inside
        # blocks (fsdp_weight_gather) resolves during lowering.
        mesh_ctx = jax.set_mesh(mesh)

        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            opt_specs = {
                "m": pspecs,
                "v": pspecs,
                "step": P(),
            }
            o_sh = to_named(mesh, opt_specs)
            bspecs = input_batch_specs(
                cfg, mesh, ins["batch"], shape.global_batch
            )
            b_sh = to_named(mesh, bspecs)

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
                return params, opt_state, loss

            fn = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            with mesh_ctx:
                lowered = fn.lower(params_shape, opt_shape, ins["batch"])
        elif shape.kind == "prefill":
            bspecs = input_batch_specs(cfg, mesh, ins["batch"], shape.global_batch)
            b_sh = to_named(mesh, bspecs)
            out_sh = NamedSharding(
                mesh, batch_logits_spec(cfg, mesh, shape.global_batch)
            )

            def prefill(params, batch):
                logits, _ = model.prefill(params, batch, cache_len=shape.seq_len)
                return logits

            fn = jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=out_sh)
            with mesh_ctx:
                lowered = fn.lower(params_shape, ins["batch"])
        else:  # decode
            seq_shard = shape.name == "long_500k"
            seq_axis = "data"
            if decode_cache_layout == "seq_model":
                seq_shard, seq_axis = True, "model"
            elif decode_cache_layout == "seq_data":
                seq_shard, seq_axis = True, "data"
            elif decode_cache_layout == "batch":
                seq_shard = False
            cspecs = cache_specs(
                cfg, mesh, ins["cache"], shape.global_batch, seq_shard, seq_axis
            )
            c_sh = to_named(mesh, cspecs)
            tspec = input_batch_specs(
                cfg, mesh, ins["tokens"], shape.global_batch
            )
            t_sh = to_named(mesh, tspec)
            out_sh = NamedSharding(
                mesh, batch_logits_spec(cfg, mesh, shape.global_batch)
            )

            def serve_step(params, tokens, cache, pos):
                return model.decode_step(params, tokens, cache, pos)

            fn = jax.jit(
                serve_step,
                in_shardings=(p_sh, t_sh, c_sh, NamedSharding(mesh, P())),
                out_shardings=(out_sh, c_sh),
                donate_argnums=(2,),
            )
            with mesh_ctx:
                lowered = fn.lower(
                    params_shape, ins["tokens"], ins["cache"], ins["pos"]
                )

        compiled = lowered.compile()
        res.flops, res.bytes_accessed = _cost_analysis(compiled)
        res.per_device_memory = _memory_analysis(compiled)
        hlo = compiled.as_text()
        res.collective_bytes = parse_collective_bytes(hlo)
        if keep_hlo:
            (ART_DIR / f"{arch}_{shape_name}_{mesh_name}.hlo").write_text(hlo)
        res.ok = True
    except Exception:
        res.error = traceback.format_exc()[-4000:]
    res.seconds = time.time() - t0  # simlint: ignore[R1] -- measures real compile time (the artifact's `seconds` field)
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        out = dataclasses.asdict(res)
        stem = f"{arch}_{shape_name}_{mesh_name}" + (f"_{tag}" if tag else "")
        (ART_DIR / f"{stem}.json").write_text(json.dumps(out, indent=2))
    return res


def batch_logits_spec(cfg: ModelConfig, mesh, global_batch: int) -> P:
    from ..models.sharding import _div, batch_spec

    lead = batch_spec(cfg, mesh, global_batch, 1)[0]
    return P(lead, None, _div(cfg.vocab_size, mesh, "model"))


def run_with_correction(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    l0: int = 3,
    keep_hlo: bool = False,
    save: bool = True,
    tag: str = "",
    **kw,
) -> DryRunResult:
    """Full dry-run + scan-FLOP correction.

    1. Compile the full model with scanned layers (fast; proves lowering,
       sharding and memory at the real layer count).
    2. Compile an L0-layer variant twice — unrolled and scanned.  The
       difference isolates the exact per-layer HLO cost (validated: for
       olmo-1b, nonlayer+body×L matches the fully-unrolled compile).
    3. corrected = full + body × (L − 1).
    """
    full = run_one(arch, shape_name, multi_pod, keep_hlo=keep_hlo, save=False, **kw)
    cfg = for_shape(get_config(arch), SHAPES[shape_name])
    full.n_layers = cfg.n_layers
    # scan *unit*: xlstm scans over groups of `slstm_every` blocks
    unit = 1
    if cfg.block_pattern == "xlstm":
        unit = cfg.slstm_every if cfg.n_layers % cfg.slstm_every == 0 else 0
    full.scanned = cfg.scan_layers and unit != 0
    n_units = cfg.n_layers // max(unit, 1)
    if not full.ok or not full.scanned or n_units < 2:
        full.flops_corrected = full.flops
        full.bytes_corrected = full.bytes_accessed
        full.collective_corrected = dict(full.collective_bytes)
        _save(full, save, tag)
        return full

    l0_units = min(l0, n_units)
    if unit > 1:
        # xlstm groups are 8 blocks each — 2 units is already a 16-block
        # unrolled compile; keep the correction pair affordable.
        l0_units = min(l0_units, 2)
    l0_layers = l0_units * unit
    small_override = dict(kw.pop("cfg_override", None) or {})
    small_override["n_layers"] = l0_layers
    small_scan = run_one(
        arch, shape_name, multi_pod, save=False, cfg_override=small_override, **kw
    )
    small_unroll = run_one(
        arch,
        shape_name,
        multi_pod,
        save=False,
        unroll=True,
        cfg_override=small_override,
        **kw,
    )
    if small_scan.ok and small_unroll.ok and l0_units > 1:
        per_unit_flops = (small_unroll.flops - small_scan.flops) / (l0_units - 1)
        per_unit_bytes = (
            small_unroll.bytes_accessed - small_scan.bytes_accessed
        ) / (l0_units - 1)
        full.flops_corrected = full.flops + per_unit_flops * (n_units - 1)
        full.bytes_corrected = full.bytes_accessed + per_unit_bytes * (n_units - 1)
        coll = dict(full.collective_bytes)
        for k in set(small_unroll.collective_bytes) | set(small_scan.collective_bytes):
            d = (
                small_unroll.collective_bytes.get(k, 0.0)
                - small_scan.collective_bytes.get(k, 0.0)
            ) / (l0_units - 1)
            coll[k] = coll.get(k, 0.0) + d * (n_units - 1)
        full.collective_corrected = coll
        full.seconds += small_scan.seconds + small_unroll.seconds
    else:
        full.flops_corrected = full.flops
        full.bytes_corrected = full.bytes_accessed
        full.collective_corrected = dict(full.collective_bytes)
    _save(full, save, tag)
    return full


def _save(res: DryRunResult, save: bool, tag: str = "") -> None:
    if not save:
        return
    ART_DIR.mkdir(parents=True, exist_ok=True)
    stem = f"{res.arch}_{res.shape}_{res.mesh}" + (f"_{tag}" if tag else "")
    (ART_DIR / f"{stem}.json").write_text(
        json.dumps(dataclasses.asdict(res), indent=2)
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape id or 'all'")
    ap.add_argument(
        "--mesh", default="both", choices=["single", "multi", "both"]
    )
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument(
        "--fast", action="store_true", help="skip the scan-FLOP correction compiles"
    )
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [a for a in ARCHS if a != "orloj_gpt"] if args.arch == "all" else [
        args.arch.replace("-", "_")
    ]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                if args.skip_existing:
                    mesh_name = "2x16x16" if multi else "16x16"
                    p = ART_DIR / f"{arch}_{shape}_{mesh_name}.json"
                    if p.exists() and json.loads(p.read_text()).get("ok"):
                        print(f"skip {arch} {shape} {mesh_name} (cached)", flush=True)
                        continue
                if args.fast:
                    r = run_one(arch, shape, multi, keep_hlo=args.keep_hlo)
                else:
                    r = run_with_correction(arch, shape, multi, keep_hlo=args.keep_hlo)
                print(r.row(), flush=True)
                if not r.ok:
                    print(r.error, flush=True)
                results.append(r)
    n_ok = sum(r.ok for r in results)
    print(f"\n{n_ok}/{len(results)} dry-runs OK")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
