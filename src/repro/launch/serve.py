"""Serving driver: the paper's system end to end, with REAL model execution.

``python -m repro.launch.serve --scheduler orloj --n 200``

Profiles the model's Eq.-3 latency curve on this machine, generates a
length-skewed request trace (the paper's dynamic-NLP case), serves it with
the selected scheduler against real jitted execution, and reports the
finish rate.
"""

from __future__ import annotations

import argparse

import numpy as np

from ..core import (
    ClipperScheduler,
    ClockworkScheduler,
    EDFScheduler,
    EmpiricalDistribution,
    NexusScheduler,
    OrlojScheduler,
    SchedulerConfig,
)
from ..configs import get_config
from ..serving.engine import EngineConfig, ServingEngine


def make_scheduler(name: str, lm, hist, batch_sizes):
    warm = np.concatenate(list(hist.values()))
    if name == "orloj":
        dists = {
            app: EmpiricalDistribution.from_samples(xs, n_bins=12)
            for app, xs in hist.items()
            if len(xs) >= 2
        }
        return OrlojScheduler(
            lm, cfg=SchedulerConfig(batch_sizes=batch_sizes), initial_dists=dists
        )
    cls = {
        "clockwork": ClockworkScheduler,
        "nexus": NexusScheduler,
        "clipper": ClipperScheduler,
        "edf": EDFScheduler,
    }[name]
    return cls(lm, batch_sizes=batch_sizes, init_samples=warm)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="orloj_gpt")
    ap.add_argument(
        "--scheduler",
        default="orloj",
        choices=["orloj", "clockwork", "nexus", "clipper", "edf", "all"],
    )
    ap.add_argument("--n", type=int, default=150)
    ap.add_argument("--slo-scale", type=float, default=3.0)
    ap.add_argument("--utilization", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.n_params_estimate > 500e6:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 8192))
    ecfg = EngineConfig()
    engine = ServingEngine(cfg, ecfg, seed=args.seed)
    print(f"profiling {cfg.name} latency curve ...")
    lm = engine.profile_latency_model()
    print(f"Eq.3 fit: c0={lm.c0:.2f} ms, c1={lm.c1*1e3:.3f} ms/ktok")

    # Bimodal length distribution: chat-style short prompts + long documents.
    def length_sampler(rng):
        if rng.random() < 0.7:
            return int(np.clip(rng.normal(40, 12), 4, 256))
        return int(np.clip(rng.normal(200, 30), 4, 256))

    names = (
        ["orloj", "clockwork", "nexus", "clipper"]
        if args.scheduler == "all"
        else [args.scheduler]
    )
    for name in names:
        reqs, hist = engine.make_requests(
            args.n,
            lm,
            length_sampler=length_sampler,
            slo_scale=args.slo_scale,
            utilization=args.utilization,
            seed=args.seed,
        )
        sched = make_scheduler(name, lm, hist, ecfg.batch_sizes)
        res = engine.serve(reqs, sched)
        print(f"{name:10s} {res.summary()}")


if __name__ == "__main__":
    main()
