"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
import and only then calls ``make_production_mesh``.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
