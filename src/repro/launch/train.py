"""Training driver: ``python -m repro.launch.train --arch olmo_1b --steps 50``.

On this CPU container it trains the *reduced* variant by default (the full
configs are exercised via the dry-run); pass ``--full`` on real hardware.
Composes the whole substrate: config → model → sharded data pipeline →
AdamW → checkpointing.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..configs import get_config
from ..data import DataConfig, make_train_iterator
from ..models import Model
from ..models.sharding import param_specs, to_named
from ..optim import AdamWConfig, adamw_init, adamw_update
from .mesh import make_debug_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="orloj_gpt")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 8192))
    if cfg.frontend:
        raise SystemExit(
            f"{args.arch} needs frontend embeddings; use the dry-run or serve driver"
        )
    model = Model(cfg)
    mesh = make_debug_mesh()
    print(f"arch={cfg.name} params≈{cfg.n_params_estimate/1e6:.1f}M mesh={dict(mesh.shape)}")

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))
    opt_state = adamw_init(params)

    pspecs = to_named(mesh, param_specs(cfg, jax.eval_shape(lambda: params), mesh))
    params = jax.tree.map(jax.device_put, params, pspecs)

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch
    )
    it = make_train_iterator(data_cfg, mesh)

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss

    start = 0
    if args.ckpt_dir:
        got = latest_step(args.ckpt_dir)
        if got is not None:
            params = restore_checkpoint(args.ckpt_dir, got, jax.eval_shape(lambda: params))
            start = got
            print(f"restored step {got}")

    losses = []
    t0 = time.time()  # simlint: ignore[R1] -- real ms/step throughput logging; training state itself is PRNG-seeded
    for step in range(start, args.steps):
        batch = next(it)
        params, opt_state, loss = train_step(params, opt_state, batch)
        losses.append(float(loss))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every  # simlint: ignore[R1] -- real ms/step throughput logging
            print(f"step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} {dt*1e3:.0f} ms/step")
            t0 = time.time()  # simlint: ignore[R1] -- real ms/step throughput logging
        if args.ckpt_dir and (step + 1) % 100 == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params)

    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params)
    first = np.mean(losses[: max(len(losses) // 5, 1)])
    last = np.mean(losses[-max(len(losses) // 5, 1) :])
    print(f"loss {first:.4f} → {last:.4f} ({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
