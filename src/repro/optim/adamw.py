"""AdamW with global-norm clipping and cosine LR schedule (pure JAX pytrees).

Optimizer state mirrors the parameter pytree (m, v per leaf) so it inherits
the parameter sharding (including FSDP sharding over the data axis for the
large models) without extra plumbing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: AdamWConfig, params: Params, grads: Params, state: dict
) -> tuple[Params, dict]:
    step = state["step"] + 1
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        p2 = p - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        ).astype(p.dtype)
        return p2, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
