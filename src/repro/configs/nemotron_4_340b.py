"""Nemotron-4-340B: 96-layer dense decoder, GQA (8 KV), squared-ReLU MLP.
[arXiv:2402.16819]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    norm="layernorm",
    mlp="relu2",
    loss_chunk=256,
    remat=True,
    source="arXiv:2402.16819",
)
