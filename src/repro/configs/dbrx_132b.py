"""DBRX-base (132B): fine-grained MoE, 16 experts top-4, GQA.
[hf:databricks/dbrx-base]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    norm="layernorm",
    mlp="swiglu",
    n_experts=16,
    top_k=4,
    loss_chunk=512,
    remat=True,
    source="hf:databricks/dbrx-base",
)
