"""Hymba-1.5B: hybrid blocks with parallel attention + Mamba heads,
sliding-window attention, SSM state 16.  (Meta tokens are not modelled;
see DESIGN.md.)  [arXiv:2411.13676]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    norm="rmsnorm",
    mlp="swiglu",
    block_pattern="hymba",
    ssm_state=16,
    sliding_window=1024,
    source="arXiv:2411.13676",
)
