"""Paper-scale example model (~100M): the kind of dynamic NLP model ORLOJ
serves (GPT/BART class, Table 1).  Used by the end-to-end examples, the
real-execution serving engine, and the engine-substrate eval tier
(``repro.eval.substrate`` registers it as ``orloj_gpt``, served at
``CONFIG.reduced()`` toy sizes so engine cells run on CPU)."""
from ..models.config import ModelConfig

# Bucket/batch grid the serving examples and the paper-size engine profile
# serve this model with (one compiled program per (bucket, batch) shape).
SERVE_BUCKETS = (32, 64, 128, 256)
SERVE_BATCH_SIZES = (1, 2, 4, 8)

CONFIG = ModelConfig(
    name="orloj-gpt",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    norm="layernorm",
    mlp="gelu",
    source="paper Table 1 (GPT-class)",
)
