"""Paper-scale example model (~100M): the kind of dynamic NLP model ORLOJ
serves (GPT/BART class, Table 1).  Used by the end-to-end examples and the
real-execution serving engine."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="orloj-gpt",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    norm="layernorm",
    mlp="gelu",
    source="paper Table 1 (GPT-class)",
)
