"""xLSTM-1.3B: 48 blocks, mLSTM (matrix memory, chunkwise-parallel) with
every 8th block an sLSTM (scalar memory, sequential recurrence); no FFN
(d_ff = 0).  [arXiv:2405.04517]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    mlp="none",
    block_pattern="xlstm",
    slstm_every=8,
    source="arXiv:2405.04517",
)
