"""Architecture registry + assigned input shapes.

Ten architectures assigned from the public pool (each config cites its
source), plus the paper-scale example model.  ``get_config(name)`` returns
the full published configuration; ``get_config(name).reduced()`` the
CPU-smoke variant.  ``for_shape`` applies shape-driven adaptations (e.g.
the sliding-window variant that makes dense attention sub-quadratic for
``long_500k`` — see DESIGN.md §Shape-coverage).
"""

from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCHS = [
    "glm4_9b",
    "musicgen_large",
    "dbrx_132b",
    "arctic_480b",
    "internvl2_1b",
    "olmo_1b",
    "nemotron_4_340b",
    "hymba_1_5b",
    "xlstm_1_3b",
    "granite_34b",
    "orloj_gpt",  # paper-scale example model (~100M)
]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f".{name}", __package__)
    return mod.CONFIG


def for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config adaptation.

    ``long_500k`` requires sub-quadratic attention: SSM/hybrid archs run
    natively (O(1) state / built-in SWA); full-attention archs switch to the
    sliding-window variant (ring-buffer KV cache, window 8192).
    """
    if shape.name == "long_500k" and cfg.uses_attention and not cfg.sliding_window:
        cfg = dataclasses.replace(cfg, sliding_window=8_192)
    return cfg
