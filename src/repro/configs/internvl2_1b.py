"""InternVL2-1B: InternViT vision encoder (stub frontend; 256 patch
embeddings supplied by input_specs) + Qwen2-0.5B-style LM backbone.
[arXiv:2404.16821]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    norm="rmsnorm",
    mlp="swiglu",
    frontend="vision",
    n_frontend_tokens=256,
    loss_chunk=512,
    source="arXiv:2404.16821",
)
