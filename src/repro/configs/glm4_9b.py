"""GLM-4-9B: dense decoder, RoPE, GQA (2 KV heads). [hf:THUDM/glm-4-9b]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10_000.0,
    loss_chunk=512,
    remat=True,
    source="hf:THUDM/glm-4-9b",
)
