"""Granite-34B-code: llama-architecture dense decoder, MQA (1 KV head).
[arXiv:2405.04324]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    norm="rmsnorm",
    mlp="swiglu",
    remat=True,
    source="arXiv:2405.04324",
)
