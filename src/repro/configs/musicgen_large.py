"""MusicGen-large: decoder-only LM over EnCodec audio tokens.
The EnCodec frontend is a stub (input_specs supplies frame embeddings);
the 48-layer transformer backbone is fully implemented. [arXiv:2306.05284]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    mlp="gelu",
    frontend="audio",
    source="arXiv:2306.05284",
)
