"""Training data pipeline: synthetic corpus + sharded batch iterator.

The corpus is a Zipf-distributed token stream with short-range Markov
structure (so the loss actually decreases — useful for the end-to-end
training example), packed into fixed-length rows.  Batches are placed onto
the mesh with the same (pod, data)-sharded layout the train step expects,
so the pipeline composes with pjit without host-side gymnastics.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32_000
    seq_len: int = 512
    batch_size: int = 8
    zipf_a: float = 1.3
    markov_order: int = 2
    seed: int = 0


class SyntheticCorpus:
    """Zipf unigrams re-weighted by a sparse bigram transition table."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = ranks ** (-cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # Each token prefers a small random successor set.
        self.succ = self.rng.integers(0, v, size=(v, 4))

    def sample_row(self) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, np.int32)
        out[0] = self.rng.choice(cfg.vocab_size, p=self.unigram)
        for i in range(1, cfg.seq_len + 1):
            if self.rng.random() < 0.7:  # Markov continuation
                out[i] = self.succ[out[i - 1], self.rng.integers(0, 4)]
            else:
                out[i] = self.rng.choice(cfg.vocab_size, p=self.unigram)
        return out

    def batch(self) -> dict[str, np.ndarray]:
        rows = np.stack([self.sample_row() for _ in range(self.cfg.batch_size)])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_train_iterator(
    cfg: DataConfig, mesh: Mesh | None = None
) -> Iterator[dict[str, jax.Array]]:
    corpus = SyntheticCorpus(cfg)
    if mesh is not None:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        sharding = NamedSharding(mesh, P(dp if len(dp) > 1 else (dp[0] if dp else None), None))
    while True:
        b = corpus.batch()
        if mesh is not None:
            b = {k: jax.device_put(v, sharding) for k, v in b.items()}
        yield b
