"""Flash attention (prefill/training) Pallas TPU kernel.

Tiling: grid (B, H, S/bq, S/bk); the (bq × hd) query tile, (bk × hd) K/V
tiles and the f32 accumulator live in VMEM.  Online softmax carries
(m, l, acc) across the innermost k-block dimension — the classic
flash-attention recurrence re-tiled for the MXU (128-aligned tiles).

Per-request ``lengths`` implement the padded-batch execution model the
ORLOJ scheduler reasons about: all requests run at the batch's padded
length (Eq. 3–4), the mask keeps short requests numerically exact.

Supports causal masking, GQA (KV heads < Q heads) and sliding windows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    lengths_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    causal: bool,
    window: int,
    sm_scale: float,
    block_q: int,
    block_k: int,
    n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < lengths_ref[0, 0]
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[:, 0] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd); lengths: (B,) int32."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    assert h % kv == 0
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_k = s // block_q, s // block_k
    grid = (b, h, n_q, n_k)
    qpk = h // kv
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(
        _kernel,
        causal=causal,
        window=window,
        sm_scale=1.0 / np.sqrt(hd),
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, qi, ki: (bi, 0)),  # lengths
            pl.BlockSpec(
                (1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // qpk, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd), lambda bi, hi, qi, ki: (bi, hi // qpk, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths2d, q, k, v)
