"""Fused RMSNorm Pallas TPU kernel.

Row tiles (bt × d) in VMEM; the reduction, rsqrt and scale are fused in one
pass (one HBM read + one write per element instead of the 3+ passes an
unfused lowering can take).  f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """x: (T, d); scale: (d,)."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(t // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
