"""MoE top-k gating Pallas TPU kernel: fused softmax + iterative top-k.

Row tiles (bt × E) in VMEM.  top_k is small (≤ 4 in the assigned archs), so
an unrolled iterative max (k passes over the row, masking the previous
argmax) beats a full sort and stays vector-unit friendly.  Gates are
renormalised over the selected experts, matching the router semantics of
DBRX/Arctic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logits_ref, gates_ref, idx_ref, *, top_k: int):
    x = logits_ref[...].astype(jnp.float32)  # (bt, E)
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    work = p
    gsum = jnp.zeros((p.shape[0],), jnp.float32)
    gates = []
    idxs = []
    for _ in range(top_k):
        best = jnp.argmax(work, axis=-1)  # (bt,)
        val = jnp.max(work, axis=-1)
        gates.append(val)
        idxs.append(best.astype(jnp.int32))
        gsum = gsum + val
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, work.shape, 1) == best[:, None]
        )
        work = jnp.where(onehot, -1.0, work)
    g = jnp.stack(gates, axis=-1) / jnp.maximum(gsum, 1e-9)[:, None]
    gates_ref[...] = g.astype(gates_ref.dtype)
    idx_ref[...] = jnp.stack(idxs, axis=-1)


def moe_gating_pallas(
    logits: jax.Array,
    top_k: int,
    *,
    block_rows: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """logits: (T, E) → (gates (T, k) f32, idx (T, k) int32)."""
    t, e = logits.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    return pl.pallas_call(
        functools.partial(_kernel, top_k=top_k),
        grid=(t // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, top_k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, top_k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, top_k), jnp.float32),
            jax.ShapeDtypeStruct((t, top_k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
