"""Public jit'd wrappers for the Pallas kernels.

``use_pallas=True`` runs the Pallas kernel (interpret mode on CPU; compiled
on a real TPU where ``interpret=False`` is passed through); ``False`` runs
the pure-jnp oracle — the wrappers keep signatures identical so the model
layer can switch per deployment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .moe_gating import moe_gating_pallas
from .rmsnorm import rmsnorm_pallas

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_pallas", "block_q", "block_k")
)
def flash_attention(
    q,
    k,
    v,
    lengths=None,
    *,
    causal: bool = True,
    window: int = 0,
    use_pallas: bool = True,
    block_q: int = 128,
    block_k: int = 128,
):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd); lengths: (B,) or None."""
    if lengths is None:
        lengths = jnp.full((q.shape[0],), q.shape[2], jnp.int32)
    if not use_pallas:
        return ref.flash_attention_ref(
            q, k, v, causal=causal, lengths=lengths, window=window
        )
    s = q.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    blk = max(block_q, block_k)
    pad = (-s) % blk
    if pad:
        # Pad keys/queries up to the tile size; `lengths` masks padded keys
        # and padded query rows are sliced off below.
        padcfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, padcfg), jnp.pad(k, padcfg), jnp.pad(v, padcfg)
    out = flash_attention_pallas(
        q,
        k,
        v,
        lengths,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=not _ON_TPU,
    )
    return out[:, :, :s] if pad else out


@functools.partial(jax.jit, static_argnames=("use_pallas", "block_k"))
def decode_attention(q, k_cache, v_cache, valid_len, *, use_pallas: bool = True, block_k: int = 256):
    """q: (B, H, hd); caches: (B, KV, S, hd); valid_len: (B,)."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k_cache, v_cache, valid_len)
    return decode_attention_pallas(
        q, k_cache, v_cache, valid_len, block_k=block_k, interpret=not _ON_TPU
    )


@functools.partial(jax.jit, static_argnames=("use_pallas", "eps"))
def rmsnorm(x, scale, *, eps: float = 1e-6, use_pallas: bool = True):
    """x: (..., d) — flattened to rows internally."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if not use_pallas:
        out = ref.rmsnorm_ref(x2, scale, eps)
    else:
        out = rmsnorm_pallas(x2, scale, eps=eps, interpret=not _ON_TPU)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("top_k", "use_pallas"))
def moe_gating(logits, top_k: int, *, use_pallas: bool = True):
    """logits: (T, E) → (gates (T,k), idx (T,k))."""
    if not use_pallas:
        return ref.moe_gating_ref(logits, top_k)
    return moe_gating_pallas(logits, top_k, interpret=not _ON_TPU)
