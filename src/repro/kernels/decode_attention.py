"""GQA flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Tiling: grid (B, KV, S/bk).  For each KV head, the q-group tile
(q_per_kv × hd) stays resident in VMEM while K/V cache tiles (bk × hd)
stream through; (m, l, acc) carry the online softmax across cache blocks —
flash-decoding adapted to the TPU memory hierarchy (the cache streams
HBM→VMEM; the group matmul feeds the MXU).

``valid_len`` masks unwritten cache slots (the serving engine's ring
buffer / partially-filled cache).

Cache lengths need not be multiples of ``block_k``: the block size is
rounded down to the largest divisor of ``S`` not exceeding the requested
one, so any cache length is served (at reduced streaming efficiency when
``S`` has no large divisor — keep caches multiples of 128 for the MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    valid_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    sm_scale: float,
    block_k: int,
    n_k: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (g, bk)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < valid_ref[0, 0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[:, 0] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_len: jax.Array,
    *,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """q: (B, H, hd); k/v_cache: (B, KV, S, hd); valid_len: (B,) int32.

    ``interpret=None`` (default) auto-detects: compiled on TPU, Pallas
    interpreter elsewhere.  Pass True/False to force either mode (tests
    pin the interpreter for determinism off-accelerator)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, hd = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    assert h % kv == 0
    g = h // kv
    if s <= 0:
        raise ValueError(f"cache length must be positive, got S={s}")
    block_k = min(block_k, s)
    # Largest divisor of S not exceeding the requested block size: keeps
    # the grid exact (no partially-out-of-bounds cache tiles) for caches
    # whose length is not a multiple of block_k, e.g. S=300 @ bk=256.
    while s % block_k:
        block_k -= 1
    n_k = s // block_k
    qg = q.reshape(b, kv, g, hd)
    valid2d = valid_len.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, sm_scale=1.0 / np.sqrt(hd), block_k=block_k, n_k=n_k
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, ci, ki: (bi, 0)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, ci, ki: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, ci, ki: (bi, ci, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, ci, ki: (bi, ci, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, ci, ki: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid2d, qg, k_cache, v_cache)
    return out.reshape(b, h, hd)
