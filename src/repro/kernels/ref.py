"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    lengths: jax.Array | None = None,
    window: int = 0,
) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) → (B, H, S, hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    qg = q.reshape(b, kv, h // kv, s, hd)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window > 0:
        mask &= j > i - window
    mask = jnp.broadcast_to(mask[None], (b, s, s))
    if lengths is not None:
        mask &= (j[None] < lengths[:, None, None])
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows → zero output (not NaN)
    probs = jnp.where(mask[:, None, None], probs, 0.0)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs.astype(q.dtype), v)
    return out.reshape(b, h, s, hd)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid_len: jax.Array,
) -> jax.Array:
    """q: (B, H, hd); k/v_cache: (B, KV, S, hd); valid_len: (B,) → (B, H, hd)."""
    b, h, hd = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    qg = q.reshape(b, kv, h // kv, hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg, k_cache).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    valid = jnp.arange(s)[None] < valid_len[:, None]  # (B, S)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # empty cache rows (valid_len == 0) → zero output (not uniform/NaN)
    probs = jnp.where(valid[:, None, None], probs, 0.0).astype(q.dtype)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, v_cache)
    return out.reshape(b, h, hd)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def moe_gating_ref(logits: jax.Array, top_k: int):
    """logits: (T, E) → (gates (T,k) normalised, idx (T,k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx.astype(jnp.int32)
