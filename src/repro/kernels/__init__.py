"""Pallas TPU kernels for the serving data plane's compute hot spots.

Each kernel ships three artifacts:
- ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
  VMEM tiling (TPU is the *target*; on this CPU container they are
  validated in ``interpret=True`` mode);
- ``ref.py``    — pure-jnp oracles;
- ``ops.py``    — jit'd public wrappers with a ``use_pallas`` switch.

Kernel-level tie-in to the paper: ``flash_attention`` takes *per-request
lengths* for a padded batch — the exact execution model ORLOJ schedules
around (Eq. 4: the batch runs at the padded max; masking keeps short
requests correct while the straggler determines the latency).
"""

from .ops import (
    decode_attention,
    flash_attention,
    moe_gating,
    rmsnorm,
)

__all__ = ["flash_attention", "decode_attention", "rmsnorm", "moe_gating"]
