"""Mixture-of-experts layer (DBRX-style fine-grained top-k; Arctic's
dense-residual variant is composed in blocks.py).

Dispatch strategy (TPU adaptation, see DESIGN.md): instead of the classic
Mesh-TF one-hot dispatch einsum — whose (tokens × experts × capacity)
contraction costs more FLOPs than the experts themselves — we compute
capacity slots with a cumulative-count and use scatter/gather:

    slot(token, k) = expert_id · C + (# earlier assignments to expert_id)

Tokens beyond capacity C = ceil(T·top_k·cf / E) are dropped (standard
capacity-factor semantics).  Expert matmuls are dense (E, C, d) × (E, d, f)
einsums — MXU-shaped, correct active-FLOP accounting, and shardable with
experts on the model axis.  The scatter/gather moves bytes, not FLOPs, so
the roofline's compute term reflects real MoE arithmetic.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init

Params = dict[str, Any]


def init_moe(rng, d: int, ff: int, n_experts: int) -> Params:
    ks = jax.random.split(rng, 4)
    return {
        "router": _init(ks[0], (d, n_experts)),
        "w_gate": _init(ks[1], (n_experts, d, ff)),
        "w_up": _init(ks[2], (n_experts, d, ff)),
        "w_down": _init(ks[3], (n_experts, ff, d), scale=1.0 / np.sqrt(ff)),
    }


def capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = math.ceil(n_tokens * top_k * cf / n_experts)
    return max(8, (c + 7) // 8 * 8)  # pad to a multiple of 8 for layout


def moe_apply(
    params: Params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, aux_loss).  aux_loss is the Switch load-balance
    loss (E · Σ_e fraction_e · mean_prob_e)."""
    bsz, s, d = x.shape
    dtype = x.dtype
    n_experts = params["router"].shape[1]
    t = bsz * s
    xt = x.reshape(t, d)

    logits = (xt @ params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch Transformer).
    frac = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], n_experts, dtype=jnp.float32), axis=0
    )
    aux = n_experts * jnp.sum(frac * probs.mean(axis=0))

    # Capacity slots via cumulative assignment counts.
    r = t * top_k
    flat_experts = expert_ids.reshape(r)  # token-major: (t0k0, t0k1, t1k0, ...)
    flat_gates = gate_vals.reshape(r).astype(dtype)
    flat_tokens = jnp.repeat(jnp.arange(t), top_k)
    onehot = jax.nn.one_hot(flat_experts, n_experts, dtype=jnp.int32)  # (R, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(r), flat_experts
    ]  # (R,)
    cap = capacity(t, top_k, n_experts, capacity_factor)
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_experts * cap + pos_in_e, n_experts * cap)

    # Scatter tokens into (E·C [+1 dump row], d) buffer.
    buf = jnp.zeros((n_experts * cap + 1, d), dtype)
    buf = buf.at[slot].add(xt[flat_tokens])
    xb = buf[: n_experts * cap].reshape(n_experts, cap, d)

    # Expert FFN (SwiGLU), dense per-expert matmuls.
    g = jnp.einsum("ecd,edf->ecf", xb, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, params["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))

    # Gather back and combine with gates.
    yflat = yb.reshape(n_experts * cap, d)
    y_rows = jnp.where(
        keep[:, None], yflat[jnp.minimum(slot, n_experts * cap - 1)], 0.0
    )
    y = jnp.zeros((t, d), dtype).at[flat_tokens].add(y_rows * flat_gates[:, None])
    return y.reshape(bsz, s, d), aux


def moe_ref(params: Params, x: jax.Array, *, top_k: int) -> jax.Array:
    """Dense oracle: every expert runs on every token (no capacity drops).
    Used by tests to validate the dispatch path."""
    bsz, s, d = x.shape
    dtype = x.dtype
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->etf", xt, params["w_gate"].astype(dtype))
    u = jnp.einsum("td,edf->etf", xt, params["w_up"].astype(dtype))
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, params["w_down"].astype(dtype))
    mask = jax.nn.one_hot(expert_ids, params["router"].shape[1], dtype=jnp.float32)
    w = (gate_vals[..., None] * mask).sum(1)  # (T, E)
    y = jnp.einsum("te,etd->td", w.astype(dtype), ye)
    return y.reshape(bsz, s, d)
