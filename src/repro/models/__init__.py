"""Composable JAX model substrate: the worker-side "data plane" of the
ORLOJ serving framework.  Dense / MoE / SSM / hybrid decoder architectures
with GQA attention, RoPE, sliding windows, expert routing and recurrent
state — all as pure-functional JAX with explicit parameter pytrees, ready
for pjit sharding (see repro.models.sharding and repro.launch)."""

from .config import ModelConfig
from .model import Model

__all__ = ["ModelConfig", "Model"]
