"""Decoder blocks: standard attention+MLP/MoE, Hymba hybrid (parallel
attention ∥ Mamba heads), and xLSTM (mLSTM / sLSTM cells)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm
from .config import ModelConfig
from .layers import (
    attention_apply,
    attention_decode,
    init_attention,
    init_kv_cache,
    init_mlp,
    init_norm,
    mlp_apply,
    norm_apply,
)

Params = dict[str, Any]


def block_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.block_pattern == "xlstm":
        return (
            "slstm"
            if (layer_idx % cfg.slstm_every) == cfg.slstm_every - 1
            else "mlstm"
        )
    if cfg.block_pattern == "hymba":
        return "hymba"
    return "attn"


# ------------------------------------------------------------------ init
def init_block(rng, cfg: ModelConfig, layer_idx: int) -> Params:
    kind = block_kind(cfg, layer_idx)
    d = cfg.d_model
    ks = jax.random.split(rng, 8)
    p: Params = {"norm1": init_norm(ks[0], d, cfg.norm)}
    if kind == "mlstm":
        p["cell"] = ssm.init_mlstm(ks[1], d, cfg.n_heads)
        return p
    if kind == "slstm":
        p["cell"] = ssm.init_slstm(ks[1], d, cfg.n_heads)
        return p
    p["attn"] = init_attention(
        ks[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    )
    if kind == "hymba":
        p["mamba"] = ssm.init_mamba(ks[2], d, cfg.ssm_state)
        p["norm_attn"] = init_norm(ks[3], d, "rmsnorm")
        p["norm_ssm"] = init_norm(ks[4], d, "rmsnorm")
    p["norm2"] = init_norm(ks[5], d, cfg.norm)
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(ks[6], d, cfg.d_ff, cfg.n_experts)
        if cfg.moe_dense_residual:
            p["mlp"] = init_mlp(ks[7], d, cfg.d_ff, cfg.mlp)
    elif cfg.mlp != "none":
        p["mlp"] = init_mlp(ks[6], d, cfg.d_ff, cfg.mlp)
    return p


# --------------------------------------------------------------- forward
def _tp_only_constraints(params: Params) -> Params:
    """Constrain weight leaves to their tensor-parallel-only layout: GSPMD
    then materialises them via a weight all-gather over the FSDP axis
    rather than partial-summing activations (§Perf pair 2)."""
    from jax.sharding import PartitionSpec as P

    specs = {
        "wq": P(None, "model", None),
        "wk": P(None, "model", None),
        "wv": P(None, "model", None),
        "wo": P("model", None, None),
        "w_gate": P(None, "model"),
        "w_up": P(None, "model"),
        "w_down": P("model", None),
    }

    def rule(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        spec = specs.get(name)
        if spec is not None and len(spec) == leaf.ndim:
            # (A bf16-cast-before-gather variant was measured and REFUTED:
            # GSPMD hoists the convert after the gather, so the all-gather
            # stays f32 while the cast breaks the partial-sum elimination —
            # see EXPERIMENTS.md §Perf pair 2, iteration 4.)
            try:
                return jax.lax.with_sharding_constraint(leaf, spec)
            except Exception:
                return leaf
        return leaf

    return jax.tree_util.tree_map_with_path(rule, params)


def block_apply(
    params: Params, x: jax.Array, cfg: ModelConfig, layer_idx: int
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (x, aux_loss)."""
    if cfg.fsdp_weight_gather:
        params = _tp_only_constraints(params)
    kind = block_kind(cfg, layer_idx)
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(params["norm1"], x, cfg.norm)
    # Bound the unrolled cross-chunk carry to ≤32 iterations regardless of
    # sequence length (HLO size / compile time), growing the chunk instead.
    chunk = max(cfg.mlstm_chunk, x.shape[1] // 32)
    if kind == "mlstm":
        return x + ssm.mlstm_apply(params["cell"], h, chunk), aux
    if kind == "slstm":
        return x + ssm.slstm_apply(params["cell"], h, cfg.n_heads), aux

    attn_out = attention_apply(
        params["attn"],
        h,
        n_kv=cfg.n_kv_heads,
        rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window,
        softcap=cfg.logit_softcap,
        repeat_kv=cfg.gqa_repeat_kv,
    )
    if kind == "hymba":
        ssm_out = ssm.mamba_apply(params["mamba"], h, chunk)
        mix = 0.5 * (
            norm_apply(params["norm_attn"], attn_out, "rmsnorm")
            + norm_apply(params["norm_ssm"], ssm_out, "rmsnorm")
        )
        x = x + mix
    else:
        x = x + attn_out

    h2 = norm_apply(params["norm2"], x, cfg.norm)
    if cfg.is_moe:
        y, aux = moe_lib.moe_apply(
            params["moe"], h2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor
        )
        if cfg.moe_dense_residual:
            y = y + mlp_apply(params["mlp"], h2, cfg.mlp)
        x = x + y
    elif cfg.mlp != "none":
        x = x + mlp_apply(params["mlp"], h2, cfg.mlp)
    return x, aux


# ----------------------------------------------------------------- cache
def init_block_cache(
    cfg: ModelConfig, layer_idx: int, batch: int, cache_len: int, dtype=jnp.bfloat16
) -> Params:
    kind = block_kind(cfg, layer_idx)
    d = cfg.d_model
    if kind == "mlstm":
        return {"cell": ssm.init_mlstm_cache(batch, d, cfg.n_heads)}
    if kind == "slstm":
        return {"cell": ssm.init_slstm_cache(batch, d)}
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    c: Params = {
        "kv": init_kv_cache(
            batch, cfg.n_kv_heads, eff_len, cfg.resolved_head_dim, dtype
        )
    }
    if kind == "hymba":
        c["mamba"] = ssm.init_mamba_cache(batch, d, cfg.ssm_state)
    return c


def block_decode(
    params: Params,
    x: jax.Array,
    cache: Params,
    pos: jax.Array,
    cfg: ModelConfig,
    layer_idx: int,
) -> tuple[jax.Array, Params]:
    """One-token decode step."""
    kind = block_kind(cfg, layer_idx)
    h = norm_apply(params["norm1"], x, cfg.norm)
    if kind == "mlstm":
        out, c2 = ssm.mlstm_decode(params["cell"], h, cache["cell"])
        return x + out, {"cell": c2}
    if kind == "slstm":
        out, c2 = ssm.slstm_decode(params["cell"], h, cache["cell"], cfg.n_heads)
        return x + out, {"cell": c2}

    attn_out, kv2 = attention_decode(
        params["attn"],
        h,
        cache["kv"],
        pos,
        n_kv=cfg.n_kv_heads,
        rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window,
        softcap=cfg.logit_softcap,
    )
    new_cache: Params = {"kv": kv2}
    if kind == "hymba":
        ssm_out, mc2 = ssm.mamba_decode(params["mamba"], h, cache["mamba"])
        mix = 0.5 * (
            norm_apply(params["norm_attn"], attn_out, "rmsnorm")
            + norm_apply(params["norm_ssm"], ssm_out, "rmsnorm")
        )
        x = x + mix
        new_cache["mamba"] = mc2
    else:
        x = x + attn_out

    h2 = norm_apply(params["norm2"], x, cfg.norm)
    if cfg.is_moe:
        y, _ = moe_lib.moe_apply(
            params["moe"], h2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor
        )
        if cfg.moe_dense_residual:
            y = y + mlp_apply(params["mlp"], h2, cfg.mlp)
        x = x + y
    elif cfg.mlp != "none":
        x = x + mlp_apply(params["mlp"], h2, cfg.mlp)
    return x, new_cache
