"""Core neural layers: norms, rotary embeddings, GQA attention, MLPs.

Pure-functional JAX: ``init_*`` builds parameter pytrees (float32 by
default), ``*_apply`` consumes them.  Everything is shape-polymorphic over
batch/sequence and works under pjit with the PartitionSpecs from
:mod:`repro.models.sharding`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def _init(rng, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(rng, shape, dtype) * scale


# --------------------------------------------------------------- norms
def init_norm(rng, d: int, kind: str) -> Params:
    if kind == "nonparam_ln":
        return {}
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    raise ValueError(kind)


def norm_apply(params: Params, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
        y = y * params["scale"]
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"] + params["bias"]
    return y.astype(dtype)


# ---------------------------------------------------------------- rope
def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) tables for ``positions`` (any leading shape)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(angles), jnp.cos(angles)


def rope_apply(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Apply rotary embedding.  x: (..., seq, heads, head_dim); sin/cos
    broadcastable to (..., seq, 1, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


# ----------------------------------------------------------- attention
def init_attention(rng, d: int, n_heads: int, n_kv: int, head_dim: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "wq": _init(k1, (d, n_heads, head_dim)),
        "wk": _init(k2, (d, n_kv, head_dim)),
        "wv": _init(k3, (d, n_kv, head_dim)),
        "wo": _init(k4, (n_heads, head_dim, d), scale=1.0 / np.sqrt(n_heads * head_dim)),
    }


def _gqa_scores(q: jax.Array, k: jax.Array, n_kv: int) -> jax.Array:
    """q: (B,S,H,hd), k: (B,T,KV,hd) → scores (B, KV, q_per_kv, S, T)."""
    b, s, h, hd = q.shape
    qg = q.reshape(b, s, n_kv, h // n_kv, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(hd).astype(np.float32)


def attention_apply(
    params: Params,
    x: jax.Array,
    *,
    n_kv: int,
    rope_theta: float,
    sliding_window: int = 0,
    positions: jax.Array | None = None,
    softcap: float = 0.0,
    repeat_kv: bool = False,
) -> jax.Array:
    """Full (training / prefill) causal GQA attention.  x: (B, S, d).

    ``repeat_kv=True`` broadcasts K/V to the full head count before the
    score einsums: all attention tensors are then (B, S, H, ·) and shard
    cleanly on the head axis (the (kv, group) reshape of the baseline
    formulation forces GSPMD reshards when kv ∤ mesh_model)."""
    b, s, _ = x.shape
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if positions is None:
        positions = jnp.arange(s)[None, :]
    sin, cos = rope_tables(positions, q.shape[-1], rope_theta)
    q = rope_apply(q, sin, cos)
    k = rope_apply(k, sin, cos)
    h = q.shape[2]

    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if sliding_window > 0:
        mask &= j > i - sliding_window

    if repeat_kv:
        rep = h // n_kv
        k = jnp.repeat(k, rep, axis=2)  # (B, S, H, hd)
        v = jnp.repeat(v, rep, axis=2)
        scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
        scores = scores / np.sqrt(q.shape[-1]).astype(np.float32)
        if softcap > 0:
            scores = jnp.tanh(scores / softcap) * softcap
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        ctx = jnp.einsum("bhst,bthk->bshk", probs, v)
        return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dtype))

    scores = _gqa_scores(q, k, n_kv).astype(jnp.float32)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    ctx = jnp.einsum("bkgst,btkh->bskgh", probs, v).reshape(b, s, h, -1)
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dtype))


def init_kv_cache(
    batch: int, n_kv: int, cache_len: int, head_dim: int, dtype=jnp.bfloat16
) -> Params:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
    }


def attention_decode(
    params: Params,
    x: jax.Array,
    cache: Params,
    pos: jax.Array,
    *,
    n_kv: int,
    rope_theta: float,
    sliding_window: int = 0,
    softcap: float = 0.0,
) -> tuple[jax.Array, Params]:
    """One-token decode with a KV cache.  x: (B, 1, d); ``pos`` scalar int.

    With ``sliding_window > 0`` the cache is a ring buffer of length W
    (positions are absolute for RoPE; the slot is ``pos mod W``) — this is
    the sub-quadratic/sub-linear long-context variant.
    """
    b, one, _ = x.shape
    dtype = x.dtype
    cache_len = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    posv = jnp.full((b, 1), pos)
    sin, cos = rope_tables(posv, q.shape[-1], rope_theta)
    q = rope_apply(q, sin, cos)
    k = rope_apply(k, sin, cos)

    slot = jnp.where(sliding_window > 0, pos % cache_len, pos)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    scores = _gqa_scores(q, new_k.astype(dtype), n_kv).astype(jnp.float32)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    idx = jnp.arange(cache_len)
    valid = idx <= jnp.minimum(pos, cache_len - 1) if sliding_window == 0 else (
        idx < jnp.minimum(pos + 1, cache_len)
    )
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    h = q.shape[2]
    ctx = jnp.einsum("bkgst,btkh->bskgh", probs, new_v.astype(dtype)).reshape(b, one, h, -1)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dtype))
    return out, {"k": new_k, "v": new_v}


# ------------------------------------------------------------------ mlp
def init_mlp(rng, d: int, ff: int, kind: str) -> Params:
    if kind == "none":
        return {}
    ks = jax.random.split(rng, 3)
    if kind == "swiglu":
        return {
            "w_gate": _init(ks[0], (d, ff)),
            "w_up": _init(ks[1], (d, ff)),
            "w_down": _init(ks[2], (ff, d), scale=1.0 / np.sqrt(ff)),
        }
    return {
        "w_up": _init(ks[0], (d, ff)),
        "w_down": _init(ks[1], (ff, d), scale=1.0 / np.sqrt(ff)),
    }


def mlp_apply(params: Params, x: jax.Array, kind: str) -> jax.Array:
    dtype = x.dtype
    if kind == "none":
        return jnp.zeros_like(x)
    if kind == "swiglu":
        g = x @ params["w_gate"].astype(dtype)
        u = x @ params["w_up"].astype(dtype)
        return (jax.nn.silu(g) * u) @ params["w_down"].astype(dtype)
    u = x @ params["w_up"].astype(dtype)
    if kind == "gelu":
        u = jax.nn.gelu(u)
    elif kind == "relu2":  # Nemotron-4 squared ReLU
        u = jnp.square(jax.nn.relu(u))
    else:
        raise ValueError(kind)
    return u @ params["w_down"].astype(dtype)


# ------------------------------------------------------------ embedding
def init_embedding(rng, vocab: int, d: int) -> Params:
    return {"table": _init(rng, (vocab, d), scale=1.0)}


def embed_apply(params: Params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed_apply(params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,vd->bsv", x, params["table"].astype(x.dtype))
