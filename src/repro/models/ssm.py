"""State-space and recurrent cells: Mamba (Hymba's parallel-SSM head),
mLSTM and sLSTM (xLSTM blocks).

TPU adaptation notes (see DESIGN.md): the CUDA selective-scan kernel of
Mamba and the fused mLSTM kernels are re-expressed as *chunkwise-parallel*
computations — within a chunk we use ``jax.lax.associative_scan`` (Mamba)
or dense intra-chunk matmuls (mLSTM, MXU-friendly), and chunks are combined
with a short, unrolled sequential carry.  This keeps the HLO free of
while-loops for the scan-heavy paths (so ``cost_analysis`` FLOPs are
meaningful) and maps the recurrence onto the systolic units instead of
emulating warp-level CUDA tricks.  The sLSTM recurrence is inherently
sequential (gate recurrence on h_{t-1}); it uses ``lax.scan`` and we account
for its trip count explicitly in the roofline.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init

Params = dict[str, Any]


def _chunked(x: jax.Array, chunk: int) -> tuple[jax.Array, int]:
    s = x.shape[1]
    chunk = min(chunk, s)
    if s % chunk:
        # round chunk down to a divisor of s
        while s % chunk:
            chunk -= 1
    return x, chunk


# =====================================================================
# Mamba (selective SSM) — Hymba's parallel SSM head
# =====================================================================
def init_mamba(rng, d: int, n_state: int, dt_rank: int = 16, conv_w: int = 4) -> Params:
    ks = jax.random.split(rng, 8)
    return {
        "in_x": _init(ks[0], (d, d)),
        "in_z": _init(ks[1], (d, d)),
        "conv": _init(ks[2], (conv_w, d), scale=1.0 / np.sqrt(conv_w)),
        "w_b": _init(ks[3], (d, n_state)),
        "w_c": _init(ks[4], (d, n_state)),
        "w_dt_lo": _init(ks[5], (d, dt_rank)),
        "w_dt_hi": _init(ks[6], (dt_rank, d)),
        "dt_bias": jnp.zeros((d,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, n_state + 1, dtype=jnp.float32))[None, :]
        * jnp.ones((d, 1), jnp.float32),
        "d_skip": jnp.ones((d,), jnp.float32),
        "out": _init(ks[7], (d, d)),
    }


def _mamba_scan(a: jax.Array, b: jax.Array, h0: jax.Array, chunk: int):
    """h_t = a_t · h_{t-1} + b_t, chunkwise-parallel.

    a, b: (B, S, d, N); h0: (B, d, N).  Returns (h_all (B,S,d,N), h_last).
    """
    _, chunk = _chunked(a, chunk)
    bsz, s, d, n = a.shape
    nc = s // chunk
    a_c = a.reshape(bsz, nc, chunk, d, n)
    b_c = b.reshape(bsz, nc, chunk, d, n)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    outs = []
    h = h0
    for c in range(nc):  # unrolled short carry (≤ 32 iterations)
        acum, bcum = jax.lax.associative_scan(
            combine, (a_c[:, c], b_c[:, c]), axis=1
        )
        h_t = acum * h[:, None] + bcum  # (B, chunk, d, N)
        outs.append(h_t)
        h = h_t[:, -1]
    return jnp.concatenate(outs, axis=1), h


def mamba_apply(
    params: Params, x: jax.Array, chunk: int = 256
) -> jax.Array:
    """x: (B, S, d) → (B, S, d)."""
    dtype = x.dtype
    xb = x @ params["in_x"].astype(dtype)
    z = x @ params["in_z"].astype(dtype)
    # causal depthwise conv, window w
    w = params["conv"].shape[0]
    pad = jnp.pad(xb, ((0, 0), (w - 1, 0), (0, 0)))
    xc = sum(
        pad[:, i : i + x.shape[1]] * params["conv"][i].astype(dtype)
        for i in range(w)
    )
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(
        (xc @ params["w_dt_lo"].astype(dtype)) @ params["w_dt_hi"].astype(dtype)
        + params["dt_bias"].astype(dtype)
    )  # (B,S,d)
    a = jnp.exp(
        -jnp.exp(params["a_log"].astype(jnp.float32))[None, None] * dt[..., None].astype(jnp.float32)
    )  # (B,S,d,N)
    bmat = xc @ params["w_b"].astype(dtype)  # (B,S,N)
    cmat = xc @ params["w_c"].astype(dtype)  # (B,S,N)
    bterm = (dt * xc)[..., None] * bmat[:, :, None, :]  # (B,S,d,N)

    h0 = jnp.zeros((x.shape[0], x.shape[2], bmat.shape[-1]), a.dtype)
    h_all, _ = _mamba_scan(a, bterm.astype(a.dtype), h0, chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all.astype(dtype), cmat)
    y = y + xc * params["d_skip"].astype(dtype)
    y = y * jax.nn.silu(z)
    return y @ params["out"].astype(dtype)


def init_mamba_cache(batch: int, d: int, n_state: int, conv_w: int = 4, dtype=jnp.float32) -> Params:
    return {
        "h": jnp.zeros((batch, d, n_state), dtype),
        "conv": jnp.zeros((batch, conv_w - 1, d), dtype),
    }


def mamba_decode(
    params: Params, x: jax.Array, cache: Params
) -> tuple[jax.Array, Params]:
    """One-step decode.  x: (B, 1, d)."""
    dtype = x.dtype
    xb = x[:, 0] @ params["in_x"].astype(dtype)  # (B, d)
    z = x[:, 0] @ params["in_z"].astype(dtype)
    w = params["conv"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(dtype), xb[:, None]], axis=1)  # (B,w,d)
    xc = jnp.einsum("bwd,wd->bd", hist, params["conv"].astype(dtype))
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(
        (xc @ params["w_dt_lo"].astype(dtype)) @ params["w_dt_hi"].astype(dtype)
        + params["dt_bias"].astype(dtype)
    )
    a = jnp.exp(
        -jnp.exp(params["a_log"].astype(jnp.float32))[None] * dt[..., None].astype(jnp.float32)
    )  # (B,d,N)
    bmat = xc @ params["w_b"].astype(dtype)
    cmat = xc @ params["w_c"].astype(dtype)
    h = a * cache["h"].astype(a.dtype) + ((dt * xc)[..., None] * bmat[:, None, :]).astype(a.dtype)
    y = jnp.einsum("bdn,bn->bd", h.astype(dtype), cmat) + xc * params["d_skip"].astype(dtype)
    y = y * jax.nn.silu(z)
    out = (y @ params["out"].astype(dtype))[:, None]
    new_cache = {"h": h.astype(cache["h"].dtype), "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache


# =====================================================================
# mLSTM (xLSTM matrix-memory block) — chunkwise parallel
# =====================================================================
def init_mlstm(rng, d: int, n_heads: int) -> Params:
    hd = d // n_heads
    ks = jax.random.split(rng, 7)
    return {
        "wq": _init(ks[0], (d, n_heads, hd)),
        "wk": _init(ks[1], (d, n_heads, hd)),
        "wv": _init(ks[2], (d, n_heads, hd)),
        "w_i": _init(ks[3], (d, n_heads)),
        "w_f": _init(ks[4], (d, n_heads)),
        "w_o": _init(ks[5], (d, d)),
        "out": _init(ks[6], (d, d)),
    }


def mlstm_apply(params: Params, x: jax.Array, chunk: int = 256) -> jax.Array:
    """Chunkwise-parallel mLSTM.  x: (B, S, d).

    Recurrence per head:  C_t = f_t C_{t-1} + i_t k_t v_tᵀ,
                          n_t = f_t n_{t-1} + i_t k_t,
                          h_t = (C_tᵀ q_t) / max(|n_t·q_t|, 1).
    Gates: f = sigmoid, i = sigmoid (stabilised variant; see module note).
    """
    bsz, s, d = x.shape
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(dtype))
    hd = q.shape[-1]
    k = k / np.sqrt(hd).astype(np.float32)
    igate = jax.nn.sigmoid(x @ params["w_i"].astype(dtype)).transpose(0, 2, 1)  # (B,H,S)
    fgate = jax.nn.sigmoid(x @ params["w_f"].astype(dtype)).transpose(0, 2, 1)

    _, chunk = _chunked(x, chunk)
    nc = s // chunk

    def c_split(t):
        return t.reshape(t.shape[0], t.shape[1], nc, chunk, *t.shape[3:])

    qc, kc, vc = c_split(q), c_split(k), c_split(v)
    ic = igate.reshape(bsz, -1, nc, chunk)
    fc = fgate.reshape(bsz, -1, nc, chunk)
    logf = jnp.log(fc.astype(jnp.float32) + 1e-9)
    lcum = jnp.cumsum(logf, axis=-1)  # (B,H,nc,chunk) cumulative log-decay

    n_heads_ = q.shape[1]
    c_state = jnp.zeros((bsz, n_heads_, hd, hd), jnp.float32)
    n_state = jnp.zeros((bsz, n_heads_, hd), jnp.float32)
    outs = []
    for c in range(nc):
        lc = lcum[:, :, c]  # (B,H,chunk)
        ltot = lc[..., -1:]  # (B,H,1)
        qf = qc[:, :, c].astype(jnp.float32)
        kf = kc[:, :, c].astype(jnp.float32)
        vf = vc[:, :, c].astype(jnp.float32)
        iw = ic[:, :, c].astype(jnp.float32)
        # intra-chunk: scores_ij = (q_i·k_j)·exp(L_i − L_j)·i_j for j ≤ i
        scores = jnp.einsum("bhik,bhjk->bhij", qf, kf)
        decay = jnp.exp(lc[:, :, :, None] - lc[:, :, None, :])
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(tri[None, None], scores * decay * iw[:, :, None, :], 0.0)
        intra = jnp.einsum("bhij,bhjk->bhik", w, vf)
        # inter-chunk: h_i += exp(L_i) · q_i @ C_prev ; n likewise
        qdec = qf * jnp.exp(lc)[..., None]
        inter = jnp.einsum("bhik,bhkl->bhil", qdec, c_state)
        num = intra + inter
        # normaliser n_i = Σ_{j≤i} exp(L_i − L_j)·i_j·k_j + exp(L_i)·n_prev
        wn = jnp.where(tri[None, None], decay * iw[:, :, None, :], 0.0)
        n_all = jnp.einsum("bhij,bhjk->bhik", wn, kf) + jnp.exp(lc)[..., None] * n_state[
            :, :, None, :
        ]
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhik,bhik->bhi", qf, n_all)), 1.0)
        outs.append((num / denom[..., None]).astype(dtype))
        # carry states
        kdec = kf * jnp.exp(ltot - lc)[..., None] * iw[..., None]
        c_state = jnp.exp(ltot)[..., None] * c_state + jnp.einsum(
            "bhjk,bhjl->bhkl", kdec, vf
        )
        n_state = jnp.exp(ltot) * n_state + kdec.sum(axis=2)
    h = jnp.concatenate(outs, axis=2)  # (B,H,S,hd)
    h = h.transpose(0, 2, 1, 3).reshape(bsz, s, d)
    o = jax.nn.sigmoid(x @ params["w_o"].astype(dtype))
    return (h * o) @ params["out"].astype(dtype)


def init_mlstm_cache(batch: int, d: int, n_heads: int, dtype=jnp.float32) -> Params:
    hd = d // n_heads
    return {
        "c": jnp.zeros((batch, n_heads, hd, hd), dtype),
        "n": jnp.zeros((batch, n_heads, hd), dtype),
    }


def mlstm_decode(
    params: Params, x: jax.Array, cache: Params
) -> tuple[jax.Array, Params]:
    dtype = x.dtype
    xt = x[:, 0]
    q = jnp.einsum("bd,dhk->bhk", xt, params["wq"].astype(dtype)).astype(jnp.float32)
    k = jnp.einsum("bd,dhk->bhk", xt, params["wk"].astype(dtype)).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", xt, params["wv"].astype(dtype)).astype(jnp.float32)
    hd = q.shape[-1]
    k = k / np.sqrt(hd)
    i = jax.nn.sigmoid(xt @ params["w_i"].astype(dtype)).astype(jnp.float32)  # (B,H)
    f = jax.nn.sigmoid(xt @ params["w_f"].astype(dtype)).astype(jnp.float32)
    c = f[..., None, None] * cache["c"] + i[..., None, None] * jnp.einsum(
        "bhk,bhl->bhkl", k, v
    )
    n = f[..., None] * cache["n"] + i[..., None] * k
    num = jnp.einsum("bhk,bhkl->bhl", q, c)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), 1.0)
    h = (num / denom[..., None]).reshape(xt.shape[0], -1).astype(dtype)
    o = jax.nn.sigmoid(xt @ params["w_o"].astype(dtype))
    out = ((h * o) @ params["out"].astype(dtype))[:, None]
    return out, {"c": c.astype(cache["c"].dtype), "n": n.astype(cache["n"].dtype)}


# =====================================================================
# sLSTM (xLSTM scalar-memory block) — sequential scan
# =====================================================================
def init_slstm(rng, d: int, n_heads: int) -> Params:
    hd = d // n_heads
    ks = jax.random.split(rng, 3)
    return {
        # input projections for gates i, f, z, o
        "w_in": _init(ks[0], (d, 4, d)),
        # block-diagonal recurrent weights per head: (4, H, hd, hd)
        "r": _init(ks[1], (4, n_heads, hd, hd), scale=1.0 / np.sqrt(hd)),
        "out": _init(ks[2], (d, d)),
    }


def _slstm_step(params: Params, carry, xg, n_heads: int):
    h, c, n = carry  # h, c, n: (B, d) float32
    bsz, d = h.shape
    hd = d // n_heads
    hh = h.reshape(bsz, n_heads, hd)
    rec = jnp.einsum("bhk,ghkl->gbhl", hh, params["r"].astype(jnp.float32)).reshape(
        4, bsz, d
    )
    g = xg + rec  # (4, B, d)
    i = jax.nn.sigmoid(g[0])
    f = jax.nn.sigmoid(g[1])
    z = jnp.tanh(g[2])
    o = jax.nn.sigmoid(g[3])
    c2 = f * c + i * z
    n2 = jnp.maximum(f * n + i, 1.0)
    h2 = o * (c2 / n2)
    return (h2, c2, n2), h2


def slstm_apply(params: Params, x: jax.Array, n_heads: int) -> jax.Array:
    bsz, s, d = x.shape
    dtype = x.dtype
    xg = jnp.einsum("bsd,dge->gbse", x, params["w_in"].astype(dtype)).astype(
        jnp.float32
    )  # (4,B,S,d)
    carry = (
        jnp.zeros((bsz, d), jnp.float32),
        jnp.zeros((bsz, d), jnp.float32),
        jnp.zeros((bsz, d), jnp.float32),
    )

    def step(carry, xt):
        return _slstm_step(params, carry, xt, n_heads)

    _, hs = jax.lax.scan(step, carry, xg.transpose(2, 0, 1, 3))  # scan over S
    h = hs.transpose(1, 0, 2).astype(dtype)  # (B,S,d)
    return h @ params["out"].astype(dtype)


def init_slstm_cache(batch: int, d: int, dtype=jnp.float32) -> Params:
    return {
        "h": jnp.zeros((batch, d), dtype),
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
    }


def slstm_decode(
    params: Params, x: jax.Array, cache: Params, n_heads: int
) -> tuple[jax.Array, Params]:
    dtype = x.dtype
    xg = jnp.einsum("bd,dge->gbe", x[:, 0], params["w_in"].astype(dtype)).astype(
        jnp.float32
    )
    carry = (
        cache["h"].astype(jnp.float32),
        cache["c"].astype(jnp.float32),
        cache["n"].astype(jnp.float32),
    )
    (h2, c2, n2), _ = _slstm_step(params, carry, xg, n_heads)
    out = (h2.astype(dtype) @ params["out"].astype(dtype))[:, None]
    return out, {
        "h": h2.astype(cache["h"].dtype),
        "c": c2.astype(cache["c"].dtype),
        "n": n2.astype(cache["n"].dtype),
    }
