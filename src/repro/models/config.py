"""Model configuration: one dataclass covering all assigned arch families."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 → d_model // n_heads

    # Attention
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 → full attention; >0 → ring-buffer window
    causal: bool = True

    # Norm / MLP family
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    mlp: str = "swiglu"  # swiglu | gelu | relu2 | none
    logit_softcap: float = 0.0

    # Mixture-of-experts
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # Arctic: dense FFN + parallel MoE
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    block_pattern: str = "attn"  # attn | hymba | xlstm
    slstm_every: int = 8  # xLSTM: every n-th block is an sLSTM block
    mlstm_chunk: int = 256  # chunkwise-parallel mLSTM chunk length

    # Modality frontend stub (§carve-out: embeddings provided externally)
    frontend: str = ""  # "" | vision | audio
    n_frontend_tokens: int = 0

    # GQA formulation: False = grouped (b,kv,g,s,t) einsums (baseline);
    # True = broadcast KV to all query heads first, so every attention
    # tensor is sharded on the head axis and GSPMD never reshards
    # (§Perf pair 2 — fixes the involuntary-remat warnings for kv < mesh).
    gqa_repeat_kv: bool = False
    # Numerics / structure
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: bool = False
    # 'nothing' = recompute the whole block in backward (min memory);
    # 'dots'    = save matmul outputs (less recompute traffic; §Perf pair 2)
    remat_policy: str = "nothing"
    # FSDP fix (§Perf pair 2): constrain layer weights to their TP-only
    # layout inside the block so GSPMD all-gathers the (small) weights
    # over `data` instead of partial-summing the (huge) activations.
    fsdp_weight_gather: bool = False
    loss_chunk: int = 0  # 0 → unchunked; else ceil-chunk seq for the loss
    # Reference/citation for the config (model card or paper).
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def uses_attention(self) -> bool:
        return self.block_pattern in ("attn", "hymba")

    @property
    def n_params_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for roofline math."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.mlp == "swiglu":
            mlp = 3 * d * ff
        elif self.mlp == "none":
            mlp = 0
        else:
            mlp = 2 * d * ff
        per_layer = 0
        if self.block_pattern == "xlstm":
            # mLSTM: qkv + gates + out; treated as ~4 d², no FFN
            per_layer = 5 * d * d
        else:
            per_layer = attn
            if self.block_pattern == "hymba":
                per_layer += 4 * d * d + d * 2 * self.ssm_state  # mamba branch
            if self.is_moe:
                per_layer += self.n_experts * 3 * d * ff
                if self.moe_dense_residual:
                    per_layer += mlp
            else:
                per_layer += mlp
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed

    @property
    def n_active_params_estimate(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params_estimate
        d, ff = self.d_model, self.d_ff
        full = self.n_params_estimate
        moe_all = self.n_layers * self.n_experts * 3 * d * ff
        moe_active = self.n_layers * self.top_k * 3 * d * ff
        return full - moe_all + moe_active

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            mlstm_chunk=32,
            slstm_every=2,
            scan_layers=False,
            remat=False,
            dtype="float32",
            name=self.name + "-smoke",
        )
        # keep kv | heads divisibility
        if small["n_heads"] % small["n_kv_heads"]:
            small["n_kv_heads"] = 1
        small.update(overrides)
        return dataclasses.replace(self, **small)
