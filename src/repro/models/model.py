"""Model assembly: embeddings + scanned/unrolled decoder blocks + head.

Exposes the three entry points the launcher lowers:

- ``train_step``-compatible ``loss(params, batch)`` (full forward + xent),
- ``prefill(params, batch)`` (full forward, returns logits + filled cache —
  used by the serving engine),
- ``decode_step(params, tokens, cache, pos)`` (one token, KV/state cache).

Layer stacking: homogeneous architectures are scanned (``lax.scan`` over a
stacked parameter pytree, with optional ``jax.checkpoint`` remat) to keep
compile time and HLO size bounded at 96 layers; heterogeneous stacks
(xLSTM's mLSTM/sLSTM mix) are unrolled.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import (
    block_apply,
    block_decode,
    block_kind,
    init_block,
    init_block_cache,
)
from .config import ModelConfig
from .layers import embed_apply, init_embedding, init_norm, norm_apply, _init

Params = dict[str, Any]


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


class Model:
    def __init__(self, cfg: ModelConfig):
        import dataclasses

        # xLSTM stacks are heterogeneous (mLSTM/sLSTM mix) but periodic:
        # scan over homogeneous *groups* of `slstm_every` blocks when the
        # depth divides evenly; otherwise fall back to unrolling.
        self.unit = 1
        if cfg.block_pattern == "xlstm":
            if cfg.scan_layers and cfg.n_layers % cfg.slstm_every == 0:
                self.unit = cfg.slstm_every
            else:
                cfg = dataclasses.replace(cfg, scan_layers=False)
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    @property
    def n_units(self) -> int:
        return self.cfg.n_layers // self.unit

    # ------------------------------------------------------------ init
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_blocks, k_final, k_head, k_front = jax.random.split(rng, 5)
        params: Params = {}
        if cfg.frontend != "audio":
            params["embed"] = init_embedding(k_embed, cfg.vocab_size, cfg.d_model)
        if cfg.frontend:
            params["frontend_proj"] = _init(
                k_front, (self.frontend_dim, cfg.d_model)
            )
        if cfg.scan_layers:
            unit = self.unit
            rngs = jax.random.split(k_blocks, self.n_units)
            params["blocks"] = jax.vmap(
                lambda r: [
                    init_block(jax.random.fold_in(r, i), cfg, i) for i in range(unit)
                ]
            )(rngs)
        else:
            params["blocks"] = [
                init_block(jax.random.fold_in(k_blocks, i), cfg, i)
                for i in range(cfg.n_layers)
            ]
        params["final_norm"] = init_norm(k_final, cfg.d_model, cfg.norm)
        if not cfg.tie_embeddings:
            params["lm_head"] = _init(
                k_head, (cfg.d_model, cfg.vocab_size), scale=1.0 / np.sqrt(cfg.d_model)
            )
        return params

    @property
    def frontend_dim(self) -> int:
        return {"vision": 1024, "audio": 512}.get(self.cfg.frontend, 0)

    # --------------------------------------------------------- embedding
    def _embed_inputs(self, params: Params, batch: dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        parts = []
        if cfg.frontend:
            emb = batch["frontend_embeds"].astype(self.dtype)
            parts.append(emb @ params["frontend_proj"].astype(self.dtype))
        if "tokens" in batch and cfg.frontend != "audio":
            parts.append(
                embed_apply(params["embed"], batch["tokens"], self.dtype)
                * np.sqrt(cfg.d_model).astype(np.float32)
            )
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    # ----------------------------------------------------------- forward
    def hidden_states(self, params: Params, batch: dict[str, jax.Array]):
        """Full-sequence forward → (hidden (B,S,d), aux_loss)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        aux = jnp.zeros((), jnp.float32)
        if cfg.scan_layers:
            unit = self.unit

            def body(carry, unit_params):
                h, a = carry
                for i in range(unit):
                    h, da = block_apply(unit_params[i], h, cfg, i)
                    a = a + da
                return (h, a), None

            if cfg.remat:
                body = jax.checkpoint(body, policy=_remat_policy(cfg))
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
        else:
            for i, bp in enumerate(params["blocks"]):
                if cfg.remat:
                    fn = jax.checkpoint(
                        functools.partial(block_apply, cfg=cfg, layer_idx=i),
                        policy=_remat_policy(cfg),
                    )
                    x, da = fn(bp, x)
                else:
                    x, da = block_apply(bp, x, cfg, i)
                aux = aux + da
        x = norm_apply(params["final_norm"], x, cfg.norm)
        return x, aux

    def _head(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings:
            table = params["embed"]["table"].astype(h.dtype)
            return jnp.einsum("...d,vd->...v", h, table)
        return jnp.einsum("...d,dv->...v", h, params["lm_head"].astype(h.dtype))

    def logits(self, params: Params, batch: dict[str, jax.Array]) -> jax.Array:
        h, _ = self.hidden_states(params, batch)
        return self._head(params, h)

    # -------------------------------------------------------------- loss
    def loss(self, params: Params, batch: dict[str, jax.Array]) -> jax.Array:
        """Next-token cross entropy; labels < 0 are masked (frontend
        positions, padding).  Vocab-chunked when cfg.loss_chunk > 0."""
        cfg = self.cfg
        h, aux = self.hidden_states(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)

        def xent(h_slice, labels_slice, mask_slice):
            logits = self._head(params, h_slice).astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, labels_slice[..., None], axis=-1
            )[..., 0]
            return jnp.sum((logz - gold) * mask_slice)

        if cfg.loss_chunk and h.shape[1] > cfg.loss_chunk:
            s = h.shape[1]
            n_chunks = -(-s // cfg.loss_chunk)
            pad = n_chunks * cfg.loss_chunk - s
            if pad:
                h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
                labels = jnp.pad(labels, ((0, 0), (0, pad)))
                mask = jnp.pad(mask, ((0, 0), (0, pad)))
            hc = h.reshape(h.shape[0], n_chunks, cfg.loss_chunk, -1)
            lc = labels.reshape(labels.shape[0], n_chunks, cfg.loss_chunk)
            mc = mask.reshape(mask.shape[0], n_chunks, cfg.loss_chunk)
            # Unrolled (not lax.scan): keeps cost_analysis FLOPs exact and
            # lets XLA schedule chunks freely; n_chunks is small.
            total = jnp.zeros((), jnp.float32)
            for idx in range(n_chunks):
                total = total + xent(hc[:, idx], lc[:, idx], mc[:, idx])
        else:
            total = xent(h, labels, mask)
        denom = jnp.maximum(mask.sum(), 1.0)
        return total / denom + 0.01 * aux

    # ------------------------------------------------------------- cache
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.scan_layers:
            one = [
                init_block_cache(cfg, i, batch, cache_len, dtype)
                for i in range(self.unit)
            ]
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_units,) + x.shape), one
            )
        return [
            init_block_cache(cfg, i, batch, cache_len, dtype)
            for i in range(cfg.n_layers)
        ]

    # ----------------------------------------------------------- prefill
    def prefill(self, params: Params, batch: dict[str, jax.Array], cache_len: int):
        """Run the full prompt; return (last-token logits, filled cache).

        For attention blocks the cache is filled from the computed K/V; for
        SSM blocks the final state is materialised by replaying the
        recurrence (cheap, fused by XLA)."""
        # Simple, correct approach: forward for logits; fill cache by
        # running decode steps is wasteful, so instead recompute K/V per
        # layer.  For the serving engine's unit of work (one padded batch),
        # prefill IS the batch execution; decode reuse is exercised by the
        # decode examples and dry-run.
        h, _ = self.hidden_states(params, batch)
        return self._head(params, h[:, -1:]), None

    # ------------------------------------------------------------ decode
    def decode_step(
        self,
        params: Params,
        tokens: jax.Array,
        cache,
        pos: jax.Array,
    ):
        """One-token step.  tokens: (B, 1) int32 (or (B,1,front_dim) embeds
        for audio).  Returns (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = tokens.astype(self.dtype) @ params["frontend_proj"].astype(self.dtype)
        else:
            x = embed_apply(params["embed"], tokens, self.dtype) * np.sqrt(
                cfg.d_model
            ).astype(np.float32)
        if cfg.scan_layers:
            unit = self.unit

            def body(carry, xs):
                h = carry
                unit_params, unit_cache = xs
                new_cs = []
                for i in range(unit):
                    h, c2 = block_decode(unit_params[i], h, unit_cache[i], pos, cfg, i)
                    new_cs.append(c2)
                return h, new_cs

            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        else:
            new_cache = []
            for i, bp in enumerate(params["blocks"]):
                x, c2 = block_decode(bp, x, cache[i], pos, cfg, i)
                new_cache.append(c2)
        x = norm_apply(params["final_norm"], x, cfg.norm)
        return self._head(params, x), new_cache

    # ------------------------------------------------------------- utils
    def param_count(self, params: Params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
