"""Sharding rules: parameter / input / cache PartitionSpecs per arch.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  ``pod`` composes with ``data`` as the outer data-parallel axis.

Strategy (see DESIGN.md §5):
- tensor parallel over ``model``: attention heads, FFN hidden, vocab,
  experts (MoE), SSM inner channels;
- batch over (pod, data); FSDP over ``data`` for ≥8B-parameter models
  (parameters *and* optimizer state);
- ``long_500k`` decode: KV-cache *sequence* axis over ``data`` —
  flash-decoding-style partial softmax, GSPMD inserts the combine;
- axes that do not divide the mesh axis (e.g. MQA's single KV head, xLSTM's
  4 heads) are replicated / sharded on an inner dim instead.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

FSDP_THRESHOLD = 8_000_000_000  # params; above this, shard params over data


def _axsize(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _div(dim: int, mesh: Mesh, axis: str) -> str | None:
    """Return the mesh axis if the dim is divisible by it, else None."""
    n = _axsize(mesh, axis)
    return axis if (n > 1 and dim % n == 0) else None


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def use_fsdp(cfg: ModelConfig) -> bool:
    return cfg.n_params_estimate >= FSDP_THRESHOLD


def _fsdp_axis(cfg: ModelConfig, mesh: Mesh, dim: int) -> str | None:
    if not use_fsdp(cfg):
        return None
    return _div(dim, mesh, "data")


def param_specs(
    cfg: ModelConfig,
    params_shape: Any,
    mesh: Mesh,
    moe_ff_axis: str | None = None,
) -> Any:
    """PartitionSpec pytree matching ``jax.eval_shape(model.init, ...)``.

    ``moe_ff_axis``: serving-time 2-D expert sharding — experts over
    ``model`` *and* the expert FFN hidden dim over this axis (usually
    ``data``, idle at inference).  The w_down contraction then produces one
    small reduce per layer instead of FSDP-gathering every expert weight
    per decode step (§Perf pair 3)."""

    def rule(path, leaf) -> P:
        keys = [
            k.key if hasattr(k, "key") else str(k) for k in path
        ]
        name = keys[-1]
        shape = leaf.shape
        scanned = cfg.scan_layers and "blocks" in keys
        core = shape[1:] if scanned else shape
        spec = _leaf_rule(name, keys, core, cfg, mesh, moe_ff_axis)
        if scanned:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def _leaf_rule(
    name, keys, shape, cfg: ModelConfig, mesh: Mesh, moe_ff_axis: str | None = None
) -> P:
    f = lambda dim: _fsdp_axis(cfg, mesh, dim)
    d = cfg.d_model
    if name == "table":  # embedding (V, d)
        return P(_div(shape[0], mesh, "model"), f(shape[1]))
    if name == "lm_head":  # (d, V)
        return P(f(shape[0]), _div(shape[1], mesh, "model"))
    if name == "frontend_proj":
        return P(None, f(shape[1]))
    if name in ("wq", "wk", "wv") and len(shape) == 3:  # (d, H, hd)
        h_ax = _div(shape[1], mesh, "model")
        if h_ax:
            return P(f(shape[0]), h_ax, None)
        return P(f(shape[0]), None, _div(shape[2], mesh, "model"))
    if name == "wo" and len(shape) == 3:  # (H, hd, d)
        h_ax = _div(shape[0], mesh, "model")
        if h_ax:
            return P(h_ax, None, f(shape[2]))
        return P(None, _div(shape[1], mesh, "model"), f(shape[2]))
    if name in ("w_gate", "w_up") and len(shape) == 2:  # mlp (d, ff)
        return P(f(shape[0]), _div(shape[1], mesh, "model"))
    if name == "w_down" and len(shape) == 2:  # (ff, d)
        return P(_div(shape[0], mesh, "model"), f(shape[1]))
    if name == "router":  # (d, E)
        return P(None, None)
    if name in ("w_gate", "w_up") and len(shape) == 3:  # moe (E, d, ff)
        e_ax = _div(shape[0], mesh, "model")
        if moe_ff_axis:
            return P(e_ax, None, _div(shape[2], mesh, moe_ff_axis))
        if e_ax:
            return P(e_ax, f(shape[1]), None)
        return P(None, f(shape[1]), _div(shape[2], mesh, "model"))
    if name == "w_down" and len(shape) == 3:  # moe (E, ff, d)
        e_ax = _div(shape[0], mesh, "model")
        if moe_ff_axis:
            return P(e_ax, _div(shape[1], mesh, moe_ff_axis), None)
        if e_ax:
            return P(e_ax, None, f(shape[2]))
        return P(None, _div(shape[1], mesh, "model"), f(shape[2]))
    # --- mamba ---
    if name in ("in_x", "in_z", "w_o"):  # (d, d_inner)
        return P(f(shape[0]), _div(shape[1], mesh, "model"))
    if name == "out" and len(shape) == 2:  # (d_inner, d)
        return P(_div(shape[0], mesh, "model"), f(shape[1]))
    if name == "conv":  # (w, d_inner)
        return P(None, _div(shape[1], mesh, "model"))
    if name in ("w_b", "w_c", "w_dt_lo"):  # (d_inner, N/r)
        return P(_div(shape[0], mesh, "model"), None)
    if name == "w_dt_hi":  # (r, d_inner)
        return P(None, _div(shape[1], mesh, "model"))
    if name in ("dt_bias", "d_skip"):  # (d_inner,)
        return P(_div(shape[0], mesh, "model"))
    if name == "a_log":  # (d_inner, N)
        return P(_div(shape[0], mesh, "model"), None)
    # --- mlstm / slstm ---
    if name in ("w_i", "w_f"):  # (d, H)
        return P(f(shape[0]), None)
    if name == "w_in" and len(shape) == 3:  # slstm (d, 4, d)
        return P(f(shape[0]), None, _div(shape[2], mesh, "model"))
    if name == "r" and len(shape) == 4:  # slstm (4, H, hd, hd)
        return P(None, None, None, _div(shape[3], mesh, "model"))
    # norms, biases, scalars → replicated
    return P(*([None] * len(shape)))


# ----------------------------------------------------------- activations
def batch_spec(cfg: ModelConfig, mesh: Mesh, global_batch: int, ndim: int) -> P:
    axes = dp_axes(mesh)
    n = int(np.prod([_axsize(mesh, a) for a in axes])) or 1
    lead = axes if (axes and global_batch % n == 0) else ()
    lead_spec = lead if len(lead) != 1 else lead[0]
    return P(lead_spec if lead else None, *([None] * (ndim - 1)))


def input_batch_specs(
    cfg: ModelConfig, mesh: Mesh, batch_tree: Any, global_batch: int
) -> Any:
    return jax.tree.map(
        lambda leaf: batch_spec(cfg, mesh, global_batch, leaf.ndim), batch_tree
    )


def cache_specs(
    cfg: ModelConfig,
    mesh: Mesh,
    cache_tree: Any,
    global_batch: int,
    seq_shard: bool,
    seq_axis: str = "data",
) -> Any:
    """Sharding for the decode cache.

    ``seq_shard=True``: KV cache *length* over ``seq_axis`` —
    sequence-parallel flash decoding (partial softmax combined by GSPMD).
    Default layout: batch over (pod, data), KV heads over ``model`` where
    divisible (non-divisible GQA head counts replicate — see the §Perf log
    for why seq-sharding beats that for small-KV archs).
    """
    scanned = cfg.scan_layers

    def rule(path, leaf) -> NamedSharding:
        keys = [k.key if hasattr(k, "key") else str(k) for k in path]
        name = keys[-1]
        shape = leaf.shape[1:] if scanned else leaf.shape
        if name in ("k", "v"):  # (B, S, KV, hd)
            if seq_shard:
                batch_ax = (
                    batch_spec(cfg, mesh, global_batch, 1)[0]
                    if seq_axis == "model"
                    else None
                )
                spec = P(batch_ax, _div(shape[1], mesh, seq_axis), None, None)
            else:
                spec = batch_spec(cfg, mesh, global_batch, 4)
                kv_ax = _div(shape[2], mesh, "model")
                spec = P(spec[0], None, kv_ax, None)
        elif name == "h" and len(shape) == 3:  # mamba (B, d_inner, N)
            spec = P(
                None if seq_shard else batch_spec(cfg, mesh, global_batch, 1)[0],
                _div(shape[1], mesh, "model"),
                None,
            )
        elif name == "conv" and len(shape) == 3:  # (B, w-1, d_inner)
            spec = P(
                None if seq_shard else batch_spec(cfg, mesh, global_batch, 1)[0],
                None,
                _div(shape[2], mesh, "model"),
            )
        elif name == "c" and len(shape) == 4:  # mlstm (B, H, hd, hd)
            spec = P(
                None if seq_shard else batch_spec(cfg, mesh, global_batch, 1)[0],
                None,
                None,
                _div(shape[3], mesh, "model"),
            )
        elif name == "n" and len(shape) == 3:  # mlstm (B, H, hd)
            spec = P(
                None if seq_shard else batch_spec(cfg, mesh, global_batch, 1)[0],
                None,
                _div(shape[2], mesh, "model"),
            )
        elif len(shape) == 2:  # slstm h/c/n (B, d)
            spec = P(
                None if seq_shard else batch_spec(cfg, mesh, global_batch, 1)[0],
                _div(shape[1], mesh, "model"),
            )
        else:
            spec = P(*([None] * len(shape)))
        if scanned:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
