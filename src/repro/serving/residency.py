"""Per-worker weights residency: the multi-model serving tier's memory model.

Orloj (§3) assumes the model being scheduled is already resident on the
worker.  Clockwork ("Serving DNNs like Clockwork", PAPERS.md) shows the
production regime is many models sharing workers under memory pressure,
where the SLO killer is the *cold start* — PCIe-loading the weights — not
execution variance.  This module prices that regime for the simulator:

- a :class:`ModelProfile` per zoo architecture (``repro.configs``), with
  weight bytes from ``ModelConfig.n_params_estimate`` at bf16 and a load
  time from a PCIe-style transfer model (``bytes / bandwidth + fixed``);
- a frozen :class:`ResidencyPlan` (the :class:`~repro.serving.faults.FaultPlan`
  pattern: validated knobs, ``to_dict``/``from_dict``, ``start()`` factory)
  describing per-worker capacity in bytes and the eviction policy;
- a mutable :class:`ResidencyState` holding each worker's resident set,
  charged by *both* event engines through ``acquire()`` — fully
  deterministic (no rng, virtual time only), so the scalar oracle loop and
  the array engine stay bit-identical under residency (DESIGN.md §13).

Eviction policies:

``lru``
    Evict the least-recently-*used* model (use = dispatch of a batch for
    it on that worker).  The Clockwork default.
``cost_aware``
    Evict the resident model with the smallest *re-load risk*:
    ``load_ms × observed demand share``.  A cheap-to-reload model that is
    rarely requested is evicted before a 2-GiB hot one even if the hot one
    was touched less recently — the "load time × expected demand" policy
    the multi-model tier puts under test.

The plan is only ever built for multi-model cells; single-model runs pass
``residency=None`` to ``run_event_loop`` and take zero new branches (the
``single-model-noop`` claim gates this bitwise).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from ..configs import ARCHS, get_config

__all__ = [
    "DEFAULT_ROSTER",
    "ModelProfile",
    "ResidencyPlan",
    "ResidencyState",
    "latency_scales",
    "model_roster",
    "zoo_profile",
]

# PCIe-style weights transfer: ~16 GiB/s effective host-to-device bandwidth
# plus a fixed per-load cost (allocation, cudaMalloc-style setup).  A 1B-param
# bf16 model (~2.2 GiB) loads in ~140 ms — the same order as the bimodal
# workloads' long peak, so cold starts genuinely compete with execution.
PCIE_BYTES_PER_MS = 16.0 * 2**30 / 1e3
LOAD_FIXED_MS = 5.0
# Freeing device memory is cheap but not free (unmap + allocator bookkeeping).
EVICT_MS = 1.0

# Zoo roster in model-popularity order (Zipf rank 0 = most popular); the
# first four are the ~1–3 GiB architectures, so small-n multi-model cells
# exercise real eviction churn under a few-GiB worker budget without
# needing a 17-GiB (glm4_9b) worker.
DEFAULT_ROSTER = (
    "olmo_1b",
    "internvl2_1b",
    "hymba_1_5b",
    "xlstm_1_3b",
    "glm4_9b",
    "musicgen_large",
    "granite_34b",
    "dbrx_132b",
    "nemotron_4_340b",
    "arctic_480b",
    "orloj_gpt",
)


def model_roster(n_models: int) -> tuple[str, ...]:
    """First ``n_models`` zoo architectures in popularity order."""
    if n_models < 1:
        raise ValueError(f"n_models must be >= 1, got {n_models}")
    if n_models > len(DEFAULT_ROSTER):
        raise ValueError(
            f"n_models={n_models} exceeds the {len(DEFAULT_ROSTER)}-entry "
            f"config-zoo roster"
        )
    return DEFAULT_ROSTER[:n_models]


def latency_scales(n_models: int) -> tuple[float, ...]:
    """Per-model execution-time multiplier (rank ``i`` runs ``1 + i/4``×).

    A deterministic heterogeneity ladder, not a roofline estimate: it keeps
    the per-model latency *distributions* distinct (so the scheduler's
    per-model score models genuinely differ) without coupling the workload
    shape to zoo parameter counts.  DESIGN.md §13 records the choice.
    """
    return tuple(1.0 + 0.25 * i for i in range(n_models))


def zoo_profile(name: str) -> "ModelProfile":
    """Profile a zoo architecture: bf16 weight bytes + PCIe load time."""
    if name not in ARCHS:
        raise ValueError(f"unknown model {name!r}; zoo has {sorted(ARCHS)}")
    nbytes = 2 * get_config(name).n_params_estimate  # bf16
    return ModelProfile(
        model_id=name,
        nbytes=float(nbytes),
        load_ms=nbytes / PCIE_BYTES_PER_MS + LOAD_FIXED_MS,
        evict_ms=EVICT_MS,
    )


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Residency-relevant facts about one model: footprint and swap costs."""

    model_id: str
    nbytes: float
    load_ms: float
    evict_ms: float = EVICT_MS

    def __post_init__(self) -> None:
        if self.nbytes <= 0.0:
            raise ValueError(f"{self.model_id}: nbytes must be > 0")
        if self.load_ms < 0.0 or self.evict_ms < 0.0:
            raise ValueError(f"{self.model_id}: load/evict cost must be >= 0")


_POLICIES = ("lru", "cost_aware")


@dataclasses.dataclass(frozen=True)
class ResidencyPlan:
    """Frozen description of the per-worker weights cache.

    ``worker_mem`` is the device-memory budget in bytes, identical across
    workers; ``profiles`` the models this run can serve.  Built once per
    eval cell (``FaultPlan`` pattern); ``start(n_workers)`` mints the
    mutable per-run state.
    """

    worker_mem: float
    profiles: tuple[ModelProfile, ...]
    policy: str = "lru"

    def __post_init__(self) -> None:
        if self.policy not in _POLICIES:
            raise ValueError(
                f"unknown eviction policy {self.policy!r}; pick from {_POLICIES}"
            )
        if self.worker_mem <= 0.0:
            raise ValueError(f"worker_mem must be > 0 bytes, got {self.worker_mem}")
        if not self.profiles:
            raise ValueError("a residency plan needs at least one model profile")
        ids = [p.model_id for p in self.profiles]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate model ids in profiles: {ids}")
        for p in self.profiles:
            if p.nbytes > self.worker_mem:
                raise ValueError(
                    f"model {p.model_id!r} ({p.nbytes:.3g} B) can never fit "
                    f"in worker_mem={self.worker_mem:.3g} B"
                )

    @classmethod
    def from_zoo(
        cls, model_ids: Sequence[str], worker_mem: float, policy: str = "lru"
    ) -> "ResidencyPlan":
        return cls(
            worker_mem=float(worker_mem),
            profiles=tuple(zoo_profile(m) for m in model_ids),
            policy=policy,
        )

    def to_dict(self) -> dict:
        return {
            "worker_mem": self.worker_mem,
            "policy": self.policy,
            "models": [
                {
                    "model_id": p.model_id,
                    "nbytes": p.nbytes,
                    "load_ms": p.load_ms,
                    "evict_ms": p.evict_ms,
                }
                for p in self.profiles
            ],
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ResidencyPlan":
        """Build from a JSON-ish mapping, ignoring unknown keys (forward
        compatibility with richer future artifacts, like FaultPlan)."""
        profiles = tuple(
            ModelProfile(
                model_id=m["model_id"],
                nbytes=float(m["nbytes"]),
                load_ms=float(m["load_ms"]),
                evict_ms=float(m.get("evict_ms", EVICT_MS)),
            )
            for m in d.get("models", ())
        )
        return cls(
            worker_mem=float(d.get("worker_mem", 0.0)),
            profiles=profiles,
            policy=str(d.get("policy", "lru")),
        )

    def start(self, n_workers: int) -> "ResidencyState":
        return ResidencyState(self, n_workers)


class ResidencyState:
    """Mutable per-run residency bookkeeping, shared by both event engines.

    Deterministic by construction: no rng, no wall clock — the resident
    sets evolve purely from the sequence of ``acquire`` calls, which both
    engines issue in the identical dispatch order (the bit-identity
    contract).  ``acquire`` returns the *stall* in virtual ms the dispatch
    must charge before execution can start: 0 on a residency hit, else the
    evict cost of every victim plus the model's load time.
    """

    __slots__ = (
        "plan",
        "_profiles",
        "_resident",  # per worker: {model_id: last-use ms}, insertion = LRU order
        "_mem_used",
        "_demand",  # model_id -> acquires so far (cost_aware demand signal)
        "n_loads",
        "n_evicts",
        "n_hits",
        "load_ms_total",
    )

    def __init__(self, plan: ResidencyPlan, n_workers: int) -> None:
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.plan = plan
        self._profiles = {p.model_id: p for p in plan.profiles}
        self._resident: list[dict[str, float]] = [dict() for _ in range(n_workers)]
        self._mem_used = [0.0] * n_workers
        self._demand = {p.model_id: 0 for p in plan.profiles}
        self.n_loads = 0
        self.n_evicts = 0
        self.n_hits = 0
        self.load_ms_total = 0.0

    def resident(self, w: int, model_id: str) -> bool:
        """Read-only residency probe (dispatch policies use this)."""
        return model_id in self._resident[w]

    def _victim(self, w: int) -> str:
        cache = self._resident[w]
        if self.plan.policy == "lru":
            # dict preserves insertion order and ``acquire`` re-inserts on
            # every touch, so the first key is the least recently used
            return next(iter(cache))
        # cost_aware: evict the smallest re-load risk = load_ms × demand
        # share.  Tie-break on (last use, model id) so the choice is total.
        total = max(sum(self._demand[m] for m in cache), 1)
        return min(
            cache,
            key=lambda m: (
                self._profiles[m].load_ms * self._demand[m] / total,
                cache[m],
                m,
            ),
        )

    def acquire(self, w: int, model_id: str, now: float) -> float:
        """Make ``model_id`` resident on worker ``w``; return the stall ms."""
        prof = self._profiles.get(model_id)
        if prof is None:
            raise ValueError(
                f"model {model_id!r} has no profile in the residency plan "
                f"(plan serves {sorted(self._profiles)})"
            )
        self._demand[model_id] += 1
        cache = self._resident[w]
        if model_id in cache:
            del cache[model_id]  # re-insert: newest position = most recent
            cache[model_id] = now
            self.n_hits += 1
            return 0.0
        stall = 0.0
        while self._mem_used[w] + prof.nbytes > self.plan.worker_mem:
            victim = self._victim(w)
            vprof = self._profiles[victim]
            del cache[victim]
            self._mem_used[w] -= vprof.nbytes
            self.n_evicts += 1
            stall += vprof.evict_ms
        cache[model_id] = now
        self._mem_used[w] += prof.nbytes
        self.n_loads += 1
        stall += prof.load_ms
        self.load_ms_total += stall
        return stall
