"""Serving substrate: workloads, traces, batching, replica-pool dispatch,
the fault-injection tier, and the real-execution engine that couples the
ORLOJ scheduler to JAX model execution."""

from .cluster import simulate_cluster
from .faults import FaultPlan, FaultState, finish_probability

__all__ = [
    "FaultPlan",
    "FaultState",
    "finish_probability",
    "simulate_cluster",
]
