"""Serving substrate: workloads, traces, batching, replica-pool dispatch
and the real-execution engine that couples the ORLOJ scheduler to JAX
model execution."""

from .cluster import simulate_cluster

__all__ = ["simulate_cluster"]
