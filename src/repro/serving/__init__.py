"""Serving substrate: workloads, traces, batching and the real-execution
engine that couples the ORLOJ scheduler to JAX model execution."""
