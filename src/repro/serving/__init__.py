"""Serving substrate: workloads, traces, batching, replica-pool dispatch,
the fault-injection tier, weights residency for multi-model serving, and
the real-execution engine that couples the ORLOJ scheduler to JAX model
execution."""

from .cluster import simulate_cluster
from .faults import FaultPlan, FaultState, finish_probability
from .residency import ModelProfile, ResidencyPlan, ResidencyState

__all__ = [
    "FaultPlan",
    "FaultState",
    "finish_probability",
    "ModelProfile",
    "ResidencyPlan",
    "ResidencyState",
    "simulate_cluster",
]
