"""Padded-batch construction for the real-execution engine.

TPU/XLA serve static shapes: sequence lengths are bucketed (multiples of a
bucket size, one compiled program per bucket) and the batch is padded to
``bucket(max_r len_r)`` — the concrete mechanism behind the paper's Eq. 4
(`l = max_r l_r`) on an XLA backend.

``buckets`` is an ascending tuple of supported sequence lengths.  Payloads
longer than the largest bucket cannot be represented: by default batch
construction *raises* rather than silently truncating user tokens; callers
that have already clamped at admission (the engine's request generator
caps lengths at the largest bucket) may pass ``overflow="clamp"`` to
truncate explicitly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.request import Request

__all__ = ["PaddedBatch", "make_padded_batch", "bucket_for", "padded_batch_size"]


def padded_batch_size(k: int, batch_sizes: Sequence[int]) -> int:
    """The batch size actually executed for ``k`` requests: the next
    supported size (XLA static-shape regime; batch-size buckets as in
    Clockwork), or ``k`` itself beyond the largest supported size."""
    if not batch_sizes:
        raise ValueError(
            "batch_sizes is empty: the engine needs at least one supported "
            "batch size to execute anything"
        )
    for bs in batch_sizes:
        if k <= bs:
            return bs
    return k


def bucket_for(length: int, buckets: tuple[int, ...], *, clamp: bool = True) -> int:
    """Smallest bucket holding ``length`` tokens.

    ``buckets`` must be ascending.  For ``length`` beyond the largest
    bucket, returns the largest bucket when ``clamp`` (the request will be
    truncated to fit) and raises otherwise."""
    if length < 0:
        raise ValueError(f"negative sequence length {length}")
    if not buckets:
        raise ValueError(
            "buckets is empty: the engine needs at least one sequence-length "
            "bucket to pad into"
        )
    for b in buckets:
        if length <= b:
            return b
    if clamp:
        return buckets[-1]
    raise ValueError(
        f"sequence length {length} exceeds the largest bucket {buckets[-1]}"
    )


@dataclasses.dataclass
class PaddedBatch:
    tokens: np.ndarray  # (k, bucket) int32, zero-padded
    lengths: np.ndarray  # (k,) int32 — post-clamp payload lengths
    labels_bucket: int
    requests: list[Request]


def make_padded_batch(
    requests: list[Request],
    buckets: tuple[int, ...],
    pad_id: int = 0,
    overflow: str = "error",
) -> PaddedBatch:
    """Pad each request's token payload to the bucket of the batch max.

    ``overflow`` controls payloads longer than the largest bucket:
    ``"error"`` (default) raises; ``"clamp"`` truncates them to the largest
    bucket and reports the clamped length in ``PaddedBatch.lengths``.
    """
    if overflow not in ("error", "clamp"):
        raise ValueError(f"overflow must be 'error' or 'clamp', got {overflow!r}")
    if not requests:
        raise ValueError(
            "cannot build a padded batch from an empty request list: "
            "callers must not dispatch empty batches"
        )
    if not buckets:
        raise ValueError(
            "buckets is empty: the engine needs at least one sequence-length "
            "bucket to pad into"
        )
    lengths = np.array([len(r.payload) for r in requests], np.int32)
    max_bucket = buckets[-1]
    if overflow == "error" and int(lengths.max()) > max_bucket:
        over = [
            (r.rid, int(n)) for r, n in zip(requests, lengths) if n > max_bucket
        ]
        raise ValueError(
            f"payloads exceed the largest bucket ({max_bucket}): "
            f"(rid, len)={over}; reject at admission or pass overflow='clamp'"
        )
    lengths = np.minimum(lengths, max_bucket)
    bucket = bucket_for(int(lengths.max()), buckets)
    tokens = np.full((len(requests), bucket), pad_id, np.int32)
    for i, r in enumerate(requests):
        tokens[i, : lengths[i]] = np.asarray(r.payload, np.int32)[: lengths[i]]
    return PaddedBatch(tokens, lengths, bucket, requests)
