"""Padded-batch construction for the real-execution engine.

TPU/XLA serve static shapes: sequence lengths are bucketed (multiples of a
bucket size, one compiled program per bucket) and the batch is padded to
``bucket(max_r len_r)`` — the concrete mechanism behind the paper's Eq. 4
(`l = max_r l_r`) on an XLA backend.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.request import Request

__all__ = ["PaddedBatch", "make_padded_batch", "bucket_for"]


def bucket_for(length: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if length <= b:
            return b
    return buckets[-1]


@dataclasses.dataclass
class PaddedBatch:
    tokens: np.ndarray  # (k, bucket) int32, zero-padded
    lengths: np.ndarray  # (k,) int32
    labels_bucket: int
    requests: list[Request]


def make_padded_batch(
    requests: list[Request], buckets: tuple[int, ...], pad_id: int = 0
) -> PaddedBatch:
    """Pad each request's token payload to the bucket of the batch max."""
    lengths = np.array([len(r.payload) for r in requests], np.int32)
    bucket = bucket_for(int(lengths.max()), buckets)
    tokens = np.full((len(requests), bucket), pad_id, np.int32)
    for i, r in enumerate(requests):
        tokens[i, : lengths[i]] = np.asarray(r.payload, np.int32)[:bucket]
    lengths = np.minimum(lengths, bucket)
    return PaddedBatch(tokens, lengths, bucket, requests)
