"""Seeded, deterministic fault injection for the event engines (the
robustness tier: ISSUE 8, DESIGN.md §11).

Every cell of the eval grid used to assume a fault-free fleet.  This
module defines the failure model the engines replay:

- **worker crashes** — per-worker renewal process with exponential MTTF
  (``mttf_ms``) and a fixed ``restart_delay_ms``.  A crash aborts the
  in-flight batch; its requests re-enter the scheduler queue through the
  deadline-aware retry gate below.
- **stragglers** — a sampled fraction (``straggler_prob``) of batch
  executions is slowed by ``straggler_factor`` (the data-dependent tail
  the paper's premise is about, § "unpredictable DNNs").
- **admission control** — when ``admission_floor > 0``, an arrival whose
  Eq.-2-style finish probability is already below the floor is rejected
  at the front door (``request.rejected``) instead of thrashing the
  queue.
- **batch timeout** — when ``batch_timeout_ms > 0``, a batch whose
  sampled duration exceeds the timeout is aborted at the deadline and
  its requests go through the same retry gate (the real
  :class:`~repro.serving.engine.ServingEngine` abort path).

Retry gate (deadline-aware backoff): an aborted request with retry
budget left is re-queued at ``now + retry_backoff_ms * 2**retries``
(capped so the retry never lands past the last feasible start), but
only if its finish probability at that instant still clears
``retry_threshold`` — otherwise it is dropped *honestly* as ``failed``
rather than queued to die.

Determinism: the plan owns its own PRNG streams, spawned from
``SeedSequence(seed)`` **independently of the trace and policy rngs** —
child ``w`` drives worker ``w``'s crash renewals and the last child
drives straggler sampling.  Per-worker crash streams plus
dispatch-ordered straggler draws make the draw sequence identical in
the scalar and array engines, which is what lets the bit-identity
equivalence claim extend to every ``FaultPlan``.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Mapping

import numpy as np

from ..core.eventloop import _expected_alone

if TYPE_CHECKING:  # pragma: no cover - typing-only imports
    from ..core.eventloop import SchedulerLike
    from ..core.request import Request

__all__ = ["FaultPlan", "FaultState", "finish_probability"]


def finish_probability(
    scheduler: "SchedulerLike", req: "Request", now: float
) -> float:
    """Eq.-2-style probability that ``req`` can still finish by its
    deadline if its (bs=1) execution started at ``now``.

    Uses the scheduler's learned per-app alone-time distribution when it
    has one (``P[c0 + c1·l_alone <= slack]`` under the empirical CDF),
    degrades to a deterministic 0/1 test against the scalar point
    estimator for baselines, and returns 1.0 for schedulers with no
    latency knowledge at all (benchmark FIFOs) — an optimistic gate is a
    no-op gate, which is the honest default.
    """
    slack = req.deadline - now
    if slack <= 0.0:
        return 0.0
    lm = getattr(scheduler, "latency_model", None)
    c0 = float(lm.c0) if lm is not None else 0.0
    c1 = float(lm.c1) if lm is not None else 1.0
    dists = getattr(scheduler, "_app_dists", None)
    if dists and req.app_id in dists:
        if c1 <= 0.0:
            return 1.0 if c0 <= slack else 0.0
        return float(dists[req.app_id].cdf((slack - c0) / c1))
    est = getattr(scheduler, "est", None)
    if est is not None:
        return 1.0 if c0 + c1 * float(est.value()) <= slack else 0.0
    return 1.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, seeded failure model (all knobs off by default).

    A plan with every knob at its default is *disabled*: the engines
    still thread it through the hook points (the ``fault-free-noop``
    claim exercises exactly this), but no rng is consumed and no fault
    event is ever scheduled, so results are bitwise identical to running
    with no plan at all.
    """

    seed: int = 0
    # worker crashes: exponential MTTF renewal process, off when 0
    mttf_ms: float = 0.0
    restart_delay_ms: float = 0.0
    # retry gate for crash/timeout-aborted requests
    max_retries: int = 2
    retry_backoff_ms: float = 0.0
    retry_threshold: float = 0.0
    # stragglers: multiplicative slowdown on a sampled execution fraction
    straggler_prob: float = 0.0
    straggler_factor: float = 1.0
    # admission control: reject at arrival below this finish probability
    admission_floor: float = 0.0
    # abort batches running longer than this (ServingEngine abort path)
    batch_timeout_ms: float = 0.0

    def __post_init__(self) -> None:
        if self.mttf_ms < 0 or self.restart_delay_ms < 0:
            raise ValueError("mttf_ms/restart_delay_ms must be >= 0")
        if self.max_retries < 0 or self.retry_backoff_ms < 0:
            raise ValueError("max_retries/retry_backoff_ms must be >= 0")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError("straggler_prob must be in [0, 1]")
        if self.straggler_prob > 0 and self.straggler_factor < 1.0:
            raise ValueError("straggler_factor must be >= 1")
        if not 0.0 <= self.admission_floor <= 1.0:
            raise ValueError("admission_floor must be in [0, 1]")
        if self.batch_timeout_ms < 0:
            raise ValueError("batch_timeout_ms must be >= 0")

    def enabled(self) -> bool:
        """True when any fault mechanism can actually fire."""
        return (
            self.mttf_ms > 0.0
            or self.straggler_prob > 0.0
            or self.admission_floor > 0.0
            or self.batch_timeout_ms > 0.0
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "FaultPlan":
        """Build from a spec-level dict, ignoring unknown keys (so old
        eval artifacts stay parseable as the plan grows knobs)."""
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def start(self, n_workers: int) -> "FaultState":
        """Materialize per-run mutable state (rng streams) for a pool."""
        return FaultState(self, n_workers)


class FaultState:
    """Per-run fault machinery: the plan plus its live PRNG streams.

    One :class:`numpy.random.Generator` per worker for crash renewals
    (children ``0..n-1`` of the plan's seed sequence) and one shared
    stream for straggler sampling (child ``n``).  Per-worker crash
    streams mean the *set* of draws depends only on how long each worker
    stays up — not on the interleaving of other events — so the scalar
    and array engines consume identical randomness.
    """

    __slots__ = ("plan", "crashes", "_crash_rngs", "_straggler_rng")

    def __init__(self, plan: FaultPlan, n_workers: int):
        self.plan = plan
        self.crashes = plan.mttf_ms > 0.0
        children = np.random.SeedSequence(plan.seed).spawn(n_workers + 1)
        self._crash_rngs = [
            np.random.default_rng(children[w]) for w in range(n_workers)
        ]
        self._straggler_rng = np.random.default_rng(children[n_workers])

    def next_crash(self, w: int, up_since: float) -> float:
        """Absolute (virtual ms) time of worker ``w``'s next crash given
        it came up at ``up_since``.  Consumes one exponential draw from
        the worker's own stream."""
        return up_since + float(
            self._crash_rngs[w].exponential(self.plan.mttf_ms)
        )

    def straggle(self, dur: float) -> float:
        """Apply the straggler model to a sampled batch duration.
        Consumes one uniform draw per dispatched batch iff the straggler
        knob is on (draws happen in dispatch order — engine-invariant)."""
        p = self.plan
        if p.straggler_prob <= 0.0:
            return dur
        if float(self._straggler_rng.random()) < p.straggler_prob:
            return dur * p.straggler_factor
        return dur

    def admit(
        self,
        scheduler: "SchedulerLike",
        req: "Request",
        now: float,
        queued_ahead: int = 0,
    ) -> bool:
        """Admission gate: accept iff the estimated finish probability
        clears the plan's floor.  Eq.-2 conditions on *when the request
        can start*, not on its arrival instant (at arrival the slack is
        always the full SLO window), so the probability is evaluated at
        ``now`` pushed out by the expected service of the
        ``queued_ahead`` requests already on the picked worker (queue +
        in-flight batch), each costed at the scheduler's own bs=1
        estimate for this request's app.  Consumes no rng."""
        t_start = now
        if queued_ahead > 0:
            lm = getattr(scheduler, "latency_model", None)
            c0 = float(lm.c0) if lm is not None else 0.0
            c1 = float(lm.c1) if lm is not None else 1.0
            t_start = now + queued_ahead * (
                c0 + c1 * _expected_alone(scheduler, req)
            )
        return (
            finish_probability(scheduler, req, t_start)
            >= self.plan.admission_floor
        )

    def retry_decision(
        self, scheduler: "SchedulerLike", req: "Request", now: float
    ) -> tuple[bool, float]:
        """Deadline-aware retry gate for an aborted request.

        Returns ``(retry, t_retry)``.  The retry lands after exponential
        backoff (``retry_backoff_ms * 2**retries``), capped so it never
        backs off past the last start that could still make the deadline
        under the scheduler's own bs=1 estimate.  Retry only when budget
        remains *and* the finish probability at ``t_retry`` clears the
        threshold (with a hard floor of "the deadline has not already
        passed") — otherwise the caller records the request as
        ``failed``.  Deterministic: consumes no rng.
        """
        p = self.plan
        if req.retries >= p.max_retries:
            return False, now
        t_retry = now + p.retry_backoff_ms * (2.0 ** req.retries)
        lm = getattr(scheduler, "latency_model", None)
        c0 = float(lm.c0) if lm is not None else 0.0
        c1 = float(lm.c1) if lm is not None else 1.0
        # latest feasible start under the scheduler's own alone estimate
        latest = req.deadline - (c0 + c1 * _expected_alone(scheduler, req))
        if t_retry > latest:
            t_retry = max(now, latest)
        prob = finish_probability(scheduler, req, t_retry)
        if prob <= 0.0 or prob < p.retry_threshold:
            return False, now
        return True, t_retry
