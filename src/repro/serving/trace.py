"""Arrival traces and request-set generation (paper §5.2).

The paper adapts the Microsoft Azure Functions (MAF) trace, scaled so the
incoming rate matches the system load, and replays the same generated
request set across systems for fairness.  We synthesize an MAF-like rate
process (bursty, heavy-tailed per-minute rates with diurnal-ish modulation)
and generate Poisson arrivals within each minute bucket, then scale the
rate so the offered load hits a target utilisation.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..core.distributions import BatchLatencyModel, EmpiricalDistribution
from ..core.request import Request
from .residency import latency_scales, model_roster
from .workload import AppWorkload, zipf_weights

__all__ = [
    "TraceConfig",
    "azure_like_arrivals",
    "generate_requests",
    "generate_token_requests",
    "offered_rate",
    "sample_alone_times",
    "RequestSet",
]


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 2_000
    utilization: float = 0.8  # offered load vs single-worker capacity
    reference_batch: int = 8  # batch size assumed when computing capacity
    burstiness: float = 0.35  # CV of the per-bucket rate process
    bucket_ms: float = 2_000.0  # rate-modulation bucket
    seed: int = 0
    # Arrival-timestamp quantization (a front-end draining its network
    # queue every tick delivers same-instant bursts): arrivals snap to
    # ``floor(t / tick_ms) * tick_ms``.  0 (default) keeps the raw Poisson
    # timestamps — existing grids are bit-identical.  Quantized traces are
    # what the array engine's coalesced bulk paths feed on; the fleet-scale
    # ``cluster`` grids use it.
    tick_ms: float = 0.0
    # Multi-model serving (DESIGN.md §13): requests target one of
    # ``n_models`` zoo architectures with Zipf(``model_skew``) popularity.
    # 1 (default) keeps the tier fully inert — no model ids are assigned,
    # no extra rng stream is consumed, and existing traces stay
    # bit-identical (the ``single-model-noop`` claim gates this).
    n_models: int = 1
    model_skew: float = 1.1


# Dedicated entropy key for the model-assignment stream: model ids are
# drawn from ``SeedSequence([seed, _MODEL_STREAM])``, never from the
# arrival/alone-time generator, so turning multi-model on cannot perturb
# the base trace (and n_models=1 consumes nothing at all).
_MODEL_STREAM = 0x6D6F646C  # "modl"


def azure_like_arrivals(
    rate_per_ms: float, n: int, cfg: TraceConfig, rng: np.random.Generator
) -> np.ndarray:
    """Arrival times (ms) for ``n`` requests at average ``rate_per_ms``.

    Per-bucket rates are Gamma-distributed around the mean (CV =
    ``burstiness``), mimicking MAF burstiness; arrivals are Poisson within a
    bucket.
    """
    cv = max(cfg.burstiness, 1e-3)
    shape = 1.0 / (cv * cv)
    arrivals: list[float] = []
    t = 0.0
    while len(arrivals) < n:
        lam = rate_per_ms * rng.gamma(shape, cv * cv)
        k = rng.poisson(lam * cfg.bucket_ms)
        if k > 0:
            ts = np.sort(rng.uniform(t, t + cfg.bucket_ms, size=k))
            arrivals.extend(ts.tolist())
        t += cfg.bucket_ms
    return np.asarray(arrivals[:n])


def sample_alone_times(
    apps: Sequence[AppWorkload], rng: np.random.Generator, n: int
) -> tuple[np.ndarray, np.ndarray]:
    """Draw ``(app index, alone time)`` for ``n`` requests — the §5.2
    weight-proportional per-app sampling, shared by the sim and engine
    request generators so both substrates draw from identical mixtures."""
    weights = np.array([a.weight for a in apps], dtype=np.float64)
    weights = weights / weights.sum()
    which = rng.choice(len(apps), size=n, p=weights)
    alone = np.empty(n)
    for i, app in enumerate(apps):
        mask = which == i
        if mask.any():
            alone[mask] = app.sample(rng, int(mask.sum()))
    return which, alone


def offered_rate(
    sizes: np.ndarray,
    latency_model: BatchLatencyModel,
    utilization: float,
    reference_batch: int,
    rng: np.random.Generator,
) -> float:
    """Arrival rate (requests/ms) that offers ``utilization`` of one
    worker batching at ``reference_batch``, with the straggler inflation
    of Eq. 4 (E[max] over the joint size mixture).  ``utilization`` is
    load a *well-batched* worker can sustain — which mis-estimating
    schedulers squander (§2.3).  Shared by the sim and engine request
    generators so "utilization 0.85" means the same thing relative to
    either substrate's latency curve."""
    ref_b = reference_batch
    est_max = float(
        np.mean(
            np.max(rng.choice(sizes, size=(256, ref_b), replace=True), axis=1)
        )
    )
    batch_ms = latency_model.c0 + latency_model.c1 * ref_b * est_max
    return utilization * (ref_b / batch_ms)


@dataclasses.dataclass
class RequestSet:
    """A replayable request set (same arrivals/inputs across systems)."""

    requests: list[Request]
    p99_alone: float
    app_history: dict[str, np.ndarray]  # warm-up samples per app

    def fresh(self) -> list[Request]:
        """Clone with reset bookkeeping so each system sees identical input."""
        return [
            Request(
                app_id=r.app_id,
                release=r.release,
                slo=r.slo,
                true_time=r.true_time,
                cost=r.cost,
                extra_deadlines=r.extra_deadlines,
                payload=r.payload,
                model_id=r.model_id,
                prompt_tokens=r.prompt_tokens,
                out_tokens=r.out_tokens,
            )
            for r in self.requests
        ]

    def initial_dists(self, n_bins: int = 12) -> dict[str, EmpiricalDistribution]:
        return {
            app: EmpiricalDistribution.from_samples(xs, n_bins=n_bins)
            for app, xs in self.app_history.items()
        }

    def warm_samples(self) -> np.ndarray:
        """All warm-up alone-times pooled — the ``init_samples`` the point-
        estimator baselines are seeded with (the same historical data ORLOJ
        gets as ``initial_dists``, §5.2 fairness)."""
        return np.concatenate(list(self.app_history.values()))

    def fingerprint(self) -> tuple:
        """Bitwise-stable identity of the generated set (not of any run's
        bookkeeping): same ``(apps, latency model, slo_scale, TraceConfig)``
        must reproduce this exactly — the §5.2 same-request-set fairness
        premise, enforced by the replay-fairness regression test."""
        per_req = tuple(
            (
                r.app_id,
                r.release,
                r.slo,
                r.true_time,
                r.cost,
                r.extra_deadlines,
                r.model_id,
                r.prompt_tokens,
                r.out_tokens,
            )
            for r in self.requests
        )
        history = tuple(
            (app, self.app_history[app].tobytes())
            for app in sorted(self.app_history)
        )
        return (per_req, self.p99_alone, history)


def generate_requests(
    apps: Sequence[AppWorkload],
    latency_model: BatchLatencyModel,
    slo_scale: float = 3.0,
    cfg: TraceConfig | None = None,
    history_per_app: int = 512,
) -> RequestSet:
    """Generate a request set per the §5.2 methodology.

    Workloads are specified in terms of *standalone (alone) execution time*
    ``a`` — what Table 1 reports.  Under the batch latency model (Eq. 3)
    ``alone = c0 + c1·s`` where ``s`` is the request's intrinsic execution
    size; we invert the profiled curve to recover ``s`` (this is exactly
    what a profiler fitting Eq. 3 does).  ``Request.true_time`` carries
    ``s``; the executor computes ``l_B = c0 + c1·k·max(s)``.

    - SLO = ``slo_scale`` × P99 of the *alone* times of the set (§5.2);
    - arrival rate scaled so offered load ≈ ``utilization`` of one worker
      batching at ``reference_batch``.
    """
    cfg = cfg or TraceConfig()
    rng = np.random.default_rng(cfg.seed)
    which, alone = sample_alone_times(apps, rng, cfg.n_requests)

    # Invert Eq. 3 at k = 1: s = (alone − c0) / c1.
    sizes = np.maximum(alone - latency_model.c0, 0.1) / latency_model.c1

    p99 = float(np.quantile(alone, 0.99))
    slo = slo_scale * p99

    rate = offered_rate(
        sizes, latency_model, cfg.utilization, cfg.reference_batch, rng
    )
    arrivals = azure_like_arrivals(rate, cfg.n_requests, cfg, rng)
    if cfg.tick_ms > 0.0:
        arrivals = np.floor(arrivals / cfg.tick_ms) * cfg.tick_ms

    reqs = [
        Request(
            app_id=apps[w].app_id,
            release=float(at),
            slo=slo,
            true_time=float(s),
        )
        for w, at, s in zip(which, arrivals, sizes)
    ]
    history = {
        a.app_id: np.maximum(
            a.sample(rng, history_per_app) - latency_model.c0, 0.1
        )
        / latency_model.c1
        for a in apps
    }
    if cfg.n_models > 1:
        _assign_models(reqs, cfg)
    return RequestSet(requests=reqs, p99_alone=p99, app_history=history)


def _assign_models(reqs: list[Request], cfg: TraceConfig) -> None:
    """Stamp Zipf-popular model ids and per-model execution scaling.

    Draws come from the dedicated ``_MODEL_STREAM`` generator, so the base
    trace (apps, arrivals, alone times, SLOs) is byte-for-byte the one a
    single-model run of the same seed sees; only ``model_id`` and the
    per-model ``true_time`` multiplier differ.  SLOs stay anchored to the
    *unscaled* alone-time p99 — slower models get proportionally tighter
    deadlines, which is exactly the pressure the multi-model grid studies.
    """
    roster = model_roster(cfg.n_models)
    scales = latency_scales(cfg.n_models)
    probs = zipf_weights(cfg.n_models, cfg.model_skew)
    mrng = np.random.default_rng(np.random.SeedSequence([cfg.seed, _MODEL_STREAM]))
    which = mrng.choice(cfg.n_models, size=len(reqs), p=probs)
    for r, m in zip(reqs, which.tolist()):
        r.model_id = roster[m]
        r.true_time *= scales[m]


def generate_token_requests(
    apps: Sequence[AppWorkload],
    *,
    d0: float,
    d1: float,
    prefill_per_token: float,
    ttft_slo_ms: float,
    tpot_slo_ms: float,
    prompt_lo: int = 16,
    prompt_hi: int = 128,
    cfg: TraceConfig | None = None,
    history_per_app: int = 512,
) -> RequestSet:
    """Generate a token-mode request set (DESIGN.md §12).

    The apps' samplers draw *output lengths in tokens* (the ``tokens``
    family in :mod:`repro.eval.workloads`), the hidden data-dependent
    quantity of autoregressive decode.  Each request's SLO is the implied
    TTFT/TPOT deadline ``ttft + tpot·(out_tokens − 1)`` — derived from the
    hidden length, so token schedulers never read it (§3.1 partial-
    information constraint carried over).  ``app_history`` holds warm-up
    *length* samples, the token-mode analogue of the alone-time history
    (``RequestSet.initial_dists`` then yields per-app length
    distributions for the length-aware scheduler, §5.2 fairness).

    Arrival rate: a worker continuously batching at ``reference_batch`` k
    serves k tokens per ``d0 + d1·k`` ms step, so its request throughput
    is ``k / ((d0 + d1·k) · E[out])``; ``utilization`` scales that.
    """
    cfg = cfg or TraceConfig()
    if cfg.n_models > 1:
        raise ValueError(
            "token-mode traces do not support multi-model serving "
            "(decode batches cannot be residency-managed; DESIGN.md §13)"
        )
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    which, lens = sample_alone_times(apps, rng, n)
    out = np.maximum(np.rint(lens), 1.0)
    prompts = rng.integers(prompt_lo, prompt_hi + 1, size=n)
    # Alone time = own prefill + solo decode; p99 of it anchors reporting
    # (token-mode SLOs come from TTFT/TPOT, not from slo_scale × p99).
    alone = prefill_per_token * prompts + (d0 + d1) * out
    p99 = float(np.quantile(alone, 0.99))

    k = cfg.reference_batch
    rate = cfg.utilization * k / ((d0 + d1 * k) * float(out.mean()))
    arrivals = azure_like_arrivals(rate, n, cfg, rng)
    if cfg.tick_ms > 0.0:
        arrivals = np.floor(arrivals / cfg.tick_ms) * cfg.tick_ms

    reqs = [
        Request(
            app_id=apps[w].app_id,
            release=float(at),
            slo=ttft_slo_ms + tpot_slo_ms * (o - 1.0),
            true_time=float(al),
            prompt_tokens=int(p),
            out_tokens=int(o),
        )
        for w, at, o, p, al in zip(which, arrivals, out, prompts, alone)
    ]
    history = {
        a.app_id: np.maximum(np.rint(a.sample(rng, history_per_app)), 1.0)
        for a in apps
    }
    return RequestSet(requests=reqs, p99_alone=p99, app_history=history)
