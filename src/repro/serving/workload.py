"""Synthetic execution-time distributions mirroring the paper's §5 cases.

The paper evaluates with (a) real model/dataset pairs whose standalone
execution times it reports as mean/P99 (Table 1), and (b) synthesized
multimodal distributions: bimodal with varying per-peak std (Table 2),
1–8-modal (Table 3, Fig. 8), unequal peaks (Fig. 9), and static (constant)
workloads (Table 4).  This module generates per-application sampler objects
for all of those cases.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "AppWorkload",
    "normal_modes",
    "bimodal",
    "k_modal",
    "unequal_bimodal",
    "static",
    "lognormal_from_mean_p99",
    "zipf_weights",
    "REAL_TASKS",
    "real_task",
]


def zipf_weights(n_models: int, skew: float) -> np.ndarray:
    """Zipf-skewed model popularity: ``w_i ∝ 1/(i+1)^skew``, normalized.

    The multi-model tier's popularity prior (DESIGN.md §13): production
    model fleets are heavily rank-skewed (Clockwork §2), so rank 0 of the
    zoo roster soaks most of the traffic and the tail stays cold — the
    regime where eviction policy actually matters.  ``skew=0`` is uniform.
    """
    if n_models < 1:
        raise ValueError(f"n_models must be >= 1, got {n_models}")
    if skew < 0.0:
        raise ValueError(f"model_skew must be >= 0, got {skew}")
    w = 1.0 / np.arange(1, n_models + 1, dtype=np.float64) ** skew
    return w / w.sum()


@dataclasses.dataclass(frozen=True)
class AppWorkload:
    """One application: a sampler over standalone execution times (ms)."""

    app_id: str
    sampler: Callable[[np.random.Generator, int], np.ndarray]
    weight: float = 1.0

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.asarray(self.sampler(rng, n), dtype=np.float64)
        return np.maximum(out, 0.1)  # execution times are positive


def _truncnorm(mu: float, sigma: float):
    def f(rng: np.random.Generator, n: int) -> np.ndarray:
        return np.maximum(rng.normal(mu, sigma, size=n), 0.1)

    return f


def normal_modes(
    mus: Sequence[float], sigmas: Sequence[float], weights: Sequence[float] | None = None
) -> list[AppWorkload]:
    """One app per mode — the paper's 'multiple applications' setting."""
    weights = weights or [1.0] * len(mus)
    return [
        AppWorkload(f"app{i}", _truncnorm(mu, sd), w)
        for i, (mu, sd, w) in enumerate(zip(mus, sigmas, weights))
    ]


# --- Table 2: bimodal with per-peak std -------------------------------------
# Peaks of the *alone* execution time; the short peak is dominated by the
# fixed batch overhead c0 (batching vital), the long peak by data-dependent
# compute (stragglers costly) — the paper's dynamic-DNN regime.  The case id
# std-s scales the base sigma.
_BIMODAL_MUS = (60.0, 200.0)
_BASE_SIGMA = 12.0


def bimodal(std: float | tuple[float, float] = 1.0) -> list[AppWorkload]:
    if isinstance(std, tuple):
        s1, s2 = std
    else:
        s1 = s2 = std
    return normal_modes(_BIMODAL_MUS, (s1 * _BASE_SIGMA, s2 * _BASE_SIGMA))


def unequal_bimodal(more: str = "short", std: float = 1.0) -> list[AppWorkload]:
    """Fig. 9: bimodal with unequal peak weights."""
    w = (0.8, 0.2) if more == "short" else (0.2, 0.8)
    return normal_modes(
        _BIMODAL_MUS, (std * _BASE_SIGMA, std * _BASE_SIGMA), weights=w
    )


# --- Table 3 / Fig. 8: k-modal ----------------------------------------------
def k_modal(k: int, std: float = 1.0, lo: float = 30.0, hi: float = 200.0) -> list[AppWorkload]:
    if k < 1:
        raise ValueError("k >= 1")
    mus = np.linspace(lo, hi, k) if k > 1 else np.array([(lo + hi) / 2])
    return normal_modes(mus, [std * _BASE_SIGMA] * k)


# --- Table 4: static models ---------------------------------------------------
def static(mean: float = 10.0, jitter: float = 0.02) -> list[AppWorkload]:
    """Constant execution time with small hardware jitter (static DNNs)."""
    return [
        AppWorkload("static", lambda rng, n: rng.normal(mean, mean * jitter, size=n))
    ]


# --- Table 1 real tasks -------------------------------------------------------
def lognormal_from_mean_p99(mean: float, p99: float):
    """Fit a lognormal to a (mean, P99) pair.

    mean = exp(mu + s²/2);  p99 = exp(mu + 2.3263 s)
    → solve s from  ln(p99/mean) = 2.3263 s − s²/2.
    """
    z = 2.3263478740408408
    ratio = math.log(max(p99, mean * 1.0001) / mean)
    # s² /2 - z s + ratio = 0 → smallest positive root
    disc = z * z - 2.0 * ratio
    s = z - math.sqrt(max(disc, 0.0))
    if disc < 0:  # extremely heavy tail: cap
        s = z
    mu = math.log(mean) - s * s / 2.0

    def f(rng: np.random.Generator, n: int) -> np.ndarray:
        return rng.lognormal(mu, s, size=n)

    return f


# (model, dataset) -> (mean ms, p99 ms) from Table 1.
REAL_TASKS: dict[str, tuple[float, float]] = {
    "rdinet-cifar": (683.15, 2667.54),
    "skipnet-imagenet": (3.24, 5.56),
    "blenderbot-convai": (200.39, 242.27),
    "blenderbot-cornell": (203.22, 247.04),
    "gpt-convai": (79.47, 143.40),
    "gpt-cornell": (94.84, 161.69),
    "bart-cnn": (774.66, 1101.99),
    "t5-cnn": (552.91, 797.28),
    "fsmt-wmt": (189.30, 319.31),
    "mbart-wmt": (432.38, 729.87),
}


def real_task(name: str) -> list[AppWorkload]:
    """§5.2 methodology: group the dataset into short- and long-running
    requests and mix them — two apps whose lognormals bracket the published
    (mean, P99)."""
    mean, p99 = REAL_TASKS[name]
    # Split: short group at 0.6×mean, long group chosen to keep the overall
    # mean and stretch the tail to P99.
    short_mean = 0.6 * mean
    long_mean = 1.4 * mean
    return [
        AppWorkload("short", lognormal_from_mean_p99(short_mean, 0.75 * p99), 0.5),
        AppWorkload("long", lognormal_from_mean_p99(long_mean, p99), 0.5),
    ]
