"""Real-execution serving engine: ORLOJ scheduling over actual JAX model
inference with measured wall-clock execution times.

This is the paper's full loop running for real on CPU-jitted models:
variable-length requests → Orloj (or baseline) scheduler → padded batch
(bucketed static shapes, one compiled program per bucket) → measured
execution feeds the online profiler.  Time is *hybrid*: the clock advances
by real measured execution during batches and skips idle gaps, so a trace
that spans minutes replays in seconds while every latency that matters is
genuinely measured.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributions import BatchLatencyModel
from ..core.eventloop import SimResult, Worker, run_event_loop, simulate
from ..core.request import Request
from ..core.scheduler import Batch
from ..models import Model, ModelConfig
from .batcher import make_padded_batch, padded_batch_size
from .faults import FaultPlan
from .trace import offered_rate

__all__ = ["EngineConfig", "JaxExecutor", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    buckets: tuple[int, ...] = (32, 64, 128, 256)
    batch_sizes: tuple[int, ...] = (1, 2, 4, 8)
    profile_reps: int = 3
    # When > 0, a batch whose measured execution exceeds this is aborted
    # at the timeout and its requests go through the fault tier's
    # deadline-aware retry gate (DESIGN.md §11) — the real engine's
    # defense against a pathological straggler batch wedging the worker.
    batch_timeout_ms: float = 0.0


class JaxExecutor:
    """Executor for the simulator loop that runs the real model and returns
    the *measured* batch execution time (ms).

    Every served batch is appended to :attr:`measured` as ``(padded_k,
    bucket, measured_ms)`` — the executed shape plus its wall-clock — so
    callers (the real-engine eval tier) can attribute predicted-vs-measured
    drift per batch.  Profiling calls go through :meth:`_run` directly and
    are *not* logged.  The log is a bounded ring (:data:`MEASURED_LOG_CAP`
    most recent batches) so callers that never read it — long-running
    serving processes, the examples — cannot leak memory; use
    :meth:`drain_measured` to read-and-reset it around one serving run."""

    MEASURED_LOG_CAP = 4096

    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._fwd = jax.jit(
            lambda p, batch: self.model.logits(p, batch),
        )
        self._compiled: set[tuple[int, int]] = set()
        self.measured: deque[tuple[int, int, float]] = deque(
            maxlen=self.MEASURED_LOG_CAP
        )

    def drain_measured(self) -> list[tuple[int, int, float]]:
        """Return the ``(padded_k, bucket, measured_ms)`` log and reset it."""
        out = list(self.measured)
        self.measured.clear()
        return out

    def padded_batch_size(self, k: int) -> int:
        return padded_batch_size(k, self.cfg.batch_sizes)

    def _run(self, tokens: np.ndarray) -> tuple[float, int]:
        """Execute one padded batch; returns ``(measured_ms, padded_k)``.

        The padded batch size is what the hardware actually ran — the
        latency model must be fit against it (not the requested k), or the
        scheduler's Eq.-3 estimates diverge from measurements whenever a
        batch is padded up to the next supported size."""
        k = self.padded_batch_size(tokens.shape[0])
        if k > tokens.shape[0]:
            tokens = np.concatenate(
                [tokens, np.zeros((k - tokens.shape[0],) + tokens.shape[1:], tokens.dtype)]
            )
        key = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if key not in self._compiled:
            # warm the cache so compile time never pollutes a measurement
            jax.block_until_ready(self._fwd(self.params, batch))
            self._compiled.add(key)
        t0 = time.perf_counter()  # simlint: ignore[R1] -- this executor's whole job is measuring real batch latency
        jax.block_until_ready(self._fwd(self.params, batch))
        return (time.perf_counter() - t0) * 1e3, k  # simlint: ignore[R1] -- real batch latency measurement

    def __call__(self, batch: Batch, now: float) -> float:
        # Admission (make_requests) caps lengths at the largest bucket, so
        # overflow here is a programming error — fail loudly.
        padded = make_padded_batch(batch.requests, self.cfg.buckets, overflow="error")
        ms, k_pad = self._run(padded.tokens)
        self.measured.append((k_pad, padded.labels_bucket, ms))
        return ms


@dataclasses.dataclass
class _ScaledExecutor:
    """A replica whose hardware is ``scale``× slower than the measured
    backend: the shared executor runs the batch for real, and the measured
    duration is scaled before it reaches the virtual clock.  This is how a
    heterogeneous pool is modelled on one physical backend — accounting is
    still anchored to a real measurement per batch."""

    inner: JaxExecutor
    scale: float

    def __call__(self, batch: Batch, now: float) -> float:
        return self.scale * self.inner(batch, now)


class ServingEngine:
    """Profiles the model's Eq.-3 latency curve, generates length-driven
    requests, and runs any scheduler against real execution.

    **Determinism contract** (the seed hooks the eval tier relies on):
    everything *upstream* of execution is seeded — model parameters from
    ``seed`` (:attr:`seed` records it), request generation from the
    ``seed`` passed to :meth:`make_requests`, zero-padding in the batcher —
    so two engines built with the same config and seed serve byte-identical
    batches.  The measured durations themselves are real wall-clock and
    therefore machine- and run-dependent; that is the point of the engine
    substrate, and downstream consumers must not treat them as stable."""

    def __init__(self, model_cfg: ModelConfig, cfg: EngineConfig | None = None, seed: int = 0):
        self.cfg = cfg or EngineConfig()
        self.seed = seed
        self.model = Model(model_cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.executor = JaxExecutor(self.model, self.params, self.cfg)

    def executor_for(self, scale: float = 1.0) -> JaxExecutor | _ScaledExecutor:
        """Executor factory for pool construction: ``scale == 1`` returns
        the shared measured executor; ``scale > 1`` wraps it so the replica
        appears ``scale``× slower (heterogeneous pools, one real backend)."""
        if scale == 1.0:
            return self.executor
        if scale <= 0.0:
            raise ValueError(f"executor scale must be positive, got {scale}")
        return _ScaledExecutor(self.executor, scale)

    # -------------------------------------------------------- profiling
    def profile_latency_model(self) -> BatchLatencyModel:
        """Fit Eq. 3 (l_B = c0 + c1·k·l) from measured (k, bucket) grid.

        On an XLA backend the 'size' l is the padded bucket length in
        tokens; c1 converts tokens→ms."""
        # The grid over supported batch sizes is complete: any off-grid
        # batch pads up to a supported size before executing, so it would
        # measure an identical shape.  Fitting against the executed size
        # reported by _run keeps the attribution correct by construction
        # (requested k and executed k coincide exactly on this grid).
        xs, ys = [], []
        for bucket in self.cfg.buckets:
            for k in sorted(set(self.cfg.batch_sizes)):
                toks = np.ones((k, bucket), np.int32)
                ts, k_pad = [], k
                for _ in range(self.cfg.profile_reps):
                    ms, k_pad = self.executor._run(toks)
                    ts.append(ms)
                xs.append((k_pad, bucket))
                ys.append(float(np.median(ts)))
        a = np.array([[1.0, k * l] for k, l in xs])
        coef, *_ = np.linalg.lstsq(a, np.array(ys), rcond=None)
        c0, c1 = float(max(coef[0], 0.01)), float(max(coef[1], 1e-6))
        return BatchLatencyModel(c0=c0, c1=c1, bucket=0.0)

    # ------------------------------------------------------ request gen
    def make_requests(
        self,
        n: int,
        lm: BatchLatencyModel,
        *,
        length_sampler: Callable[[np.random.Generator], int],
        slo_scale: float = 3.0,
        utilization: float = 0.7,
        seed: int = 0,
    ) -> tuple[list[Request], dict]:
        """Length-driven requests: the execution-time 'distribution' is the
        real consequence of the token-length distribution (the paper's NLP
        case).  true_time is the request's intrinsic size in c1-units
        (= padded token count), so Eq. 3 reproduces measured latency."""
        from .batcher import bucket_for

        rng = np.random.default_rng(seed)
        lengths = np.array([length_sampler(rng) for _ in range(n)])
        # Admission control: the serving path cannot represent payloads
        # beyond the largest bucket, so cap lengths here (explicitly, once)
        # rather than letting the batcher truncate tokens silently.
        lengths = np.minimum(lengths, max(self.cfg.buckets))
        sizes = np.array(
            [bucket_for(int(l), self.cfg.buckets) for l in lengths], np.float64
        )
        alone = lm.c0 + lm.c1 * sizes
        p99 = float(np.quantile(alone, 0.99))
        slo = slo_scale * p99

        rate = offered_rate(
            sizes, lm, utilization, self.cfg.batch_sizes[-1], rng
        )
        gaps = rng.exponential(1.0 / rate, size=n)
        arrivals = np.cumsum(gaps)

        reqs = []
        for i in range(n):
            tok = rng.integers(1, 1000, size=int(lengths[i])).astype(np.int32)
            reqs.append(
                Request(
                    app_id="short" if lengths[i] <= np.median(lengths) else "long",
                    release=float(arrivals[i]),
                    slo=slo,
                    true_time=float(sizes[i]),
                    payload=tok,
                )
            )
        hist = {
            "short": sizes[lengths <= np.median(lengths)],
            "long": sizes[lengths > np.median(lengths)],
        }
        return reqs, hist

    # ------------------------------------------------------------- run
    def serve(self, requests: Sequence[Request], scheduler) -> SimResult:
        faults = None
        if self.cfg.batch_timeout_ms > 0.0:
            faults = FaultPlan(batch_timeout_ms=self.cfg.batch_timeout_ms)
        return simulate(list(requests), scheduler, self.executor, faults=faults)

    def serve_pool(
        self,
        requests: Sequence[Request],
        schedulers: Sequence,
        policy: str = "least_loaded",
        seed: int = 0,
        horizon: float | None = None,
        charge_scheduler_overhead: bool = False,
        executors: Sequence | None = None,
    ) -> SimResult:
        """Serve one arrival stream across N replica schedulers (§3.1).

        By default all replicas share this engine's measured JAX executor
        (one physical backend timed once per batch); pass ``executors``
        (one per scheduler, e.g. from :meth:`executor_for`) to build a
        heterogeneous pool of fast and scaled-slow replicas.  The front-end
        ``policy`` assigns arrivals to replicas."""
        if executors is None:
            executors = [self.executor] * len(schedulers)
        if len(executors) != len(schedulers):
            raise ValueError(
                f"got {len(schedulers)} schedulers but {len(executors)} executors"
            )
        return run_event_loop(
            list(requests),
            [Worker(s, e) for s, e in zip(schedulers, executors)],
            policy=policy,
            seed=seed,
            horizon=horizon,
            charge_scheduler_overhead=charge_scheduler_overhead,
        )
