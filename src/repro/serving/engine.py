"""Real-execution serving engine: ORLOJ scheduling over actual JAX model
inference with measured wall-clock execution times.

This is the paper's full loop running for real on CPU-jitted models:
variable-length requests → Orloj (or baseline) scheduler → padded batch
(bucketed static shapes, one compiled program per bucket) → measured
execution feeds the online profiler.  Time is *hybrid*: the clock advances
by real measured execution during batches and skips idle gaps, so a trace
that spans minutes replays in seconds while every latency that matters is
genuinely measured.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributions import BatchLatencyModel
from ..core.eventloop import SimResult, Worker, run_event_loop, simulate
from ..core.request import Request
from ..core.scheduler import Batch
from ..models import Model, ModelConfig
from .batcher import make_padded_batch, padded_batch_size

__all__ = ["EngineConfig", "JaxExecutor", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    buckets: tuple[int, ...] = (32, 64, 128, 256)
    batch_sizes: tuple[int, ...] = (1, 2, 4, 8)
    profile_reps: int = 3


class JaxExecutor:
    """Executor for the simulator loop that runs the real model and returns
    the *measured* batch execution time (ms)."""

    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._fwd = jax.jit(
            lambda p, batch: self.model.logits(p, batch),
        )
        self._compiled: set[tuple[int, int]] = set()

    def padded_batch_size(self, k: int) -> int:
        return padded_batch_size(k, self.cfg.batch_sizes)

    def _run(self, tokens: np.ndarray) -> tuple[float, int]:
        """Execute one padded batch; returns ``(measured_ms, padded_k)``.

        The padded batch size is what the hardware actually ran — the
        latency model must be fit against it (not the requested k), or the
        scheduler's Eq.-3 estimates diverge from measurements whenever a
        batch is padded up to the next supported size."""
        k = self.padded_batch_size(tokens.shape[0])
        if k > tokens.shape[0]:
            tokens = np.concatenate(
                [tokens, np.zeros((k - tokens.shape[0],) + tokens.shape[1:], tokens.dtype)]
            )
        key = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if key not in self._compiled:
            # warm the cache so compile time never pollutes a measurement
            jax.block_until_ready(self._fwd(self.params, batch))
            self._compiled.add(key)
        t0 = time.perf_counter()
        jax.block_until_ready(self._fwd(self.params, batch))
        return (time.perf_counter() - t0) * 1e3, k

    def __call__(self, batch: Batch, now: float) -> float:
        # Admission (make_requests) caps lengths at the largest bucket, so
        # overflow here is a programming error — fail loudly.
        padded = make_padded_batch(batch.requests, self.cfg.buckets, overflow="error")
        ms, _ = self._run(padded.tokens)
        return ms


class ServingEngine:
    """Profiles the model's Eq.-3 latency curve, generates length-driven
    requests, and runs any scheduler against real execution."""

    def __init__(self, model_cfg: ModelConfig, cfg: EngineConfig | None = None, seed: int = 0):
        self.cfg = cfg or EngineConfig()
        self.model = Model(model_cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.executor = JaxExecutor(self.model, self.params, self.cfg)

    # -------------------------------------------------------- profiling
    def profile_latency_model(self) -> BatchLatencyModel:
        """Fit Eq. 3 (l_B = c0 + c1·k·l) from measured (k, bucket) grid.

        On an XLA backend the 'size' l is the padded bucket length in
        tokens; c1 converts tokens→ms."""
        # The grid over supported batch sizes is complete: any off-grid
        # batch pads up to a supported size before executing, so it would
        # measure an identical shape.  Fitting against the executed size
        # reported by _run keeps the attribution correct by construction
        # (requested k and executed k coincide exactly on this grid).
        xs, ys = [], []
        for bucket in self.cfg.buckets:
            for k in sorted(set(self.cfg.batch_sizes)):
                toks = np.ones((k, bucket), np.int32)
                ts, k_pad = [], k
                for _ in range(self.cfg.profile_reps):
                    ms, k_pad = self.executor._run(toks)
                    ts.append(ms)
                xs.append((k_pad, bucket))
                ys.append(float(np.median(ts)))
        a = np.array([[1.0, k * l] for k, l in xs])
        coef, *_ = np.linalg.lstsq(a, np.array(ys), rcond=None)
        c0, c1 = float(max(coef[0], 0.01)), float(max(coef[1], 1e-6))
        return BatchLatencyModel(c0=c0, c1=c1, bucket=0.0)

    # ------------------------------------------------------ request gen
    def make_requests(
        self,
        n: int,
        lm: BatchLatencyModel,
        *,
        length_sampler: Callable[[np.random.Generator], int],
        slo_scale: float = 3.0,
        utilization: float = 0.7,
        seed: int = 0,
    ) -> tuple[list[Request], dict]:
        """Length-driven requests: the execution-time 'distribution' is the
        real consequence of the token-length distribution (the paper's NLP
        case).  true_time is the request's intrinsic size in c1-units
        (= padded token count), so Eq. 3 reproduces measured latency."""
        from .batcher import bucket_for

        rng = np.random.default_rng(seed)
        lengths = np.array([length_sampler(rng) for _ in range(n)])
        # Admission control: the serving path cannot represent payloads
        # beyond the largest bucket, so cap lengths here (explicitly, once)
        # rather than letting the batcher truncate tokens silently.
        lengths = np.minimum(lengths, max(self.cfg.buckets))
        sizes = np.array(
            [bucket_for(int(l), self.cfg.buckets) for l in lengths], np.float64
        )
        alone = lm.c0 + lm.c1 * sizes
        p99 = float(np.quantile(alone, 0.99))
        slo = slo_scale * p99

        ref_b = self.cfg.batch_sizes[-1]
        est_max = float(
            np.mean(np.max(rng.choice(sizes, size=(128, ref_b)), axis=1))
        )
        capacity = ref_b / (lm.c0 + lm.c1 * ref_b * est_max)
        rate = utilization * capacity
        gaps = rng.exponential(1.0 / rate, size=n)
        arrivals = np.cumsum(gaps)

        reqs = []
        for i in range(n):
            tok = rng.integers(1, 1000, size=int(lengths[i])).astype(np.int32)
            reqs.append(
                Request(
                    app_id="short" if lengths[i] <= np.median(lengths) else "long",
                    release=float(arrivals[i]),
                    slo=slo,
                    true_time=float(sizes[i]),
                    payload=tok,
                )
            )
        hist = {
            "short": sizes[lengths <= np.median(lengths)],
            "long": sizes[lengths > np.median(lengths)],
        }
        return reqs, hist

    # ------------------------------------------------------------- run
    def serve(self, requests: Sequence[Request], scheduler) -> SimResult:
        return simulate(list(requests), scheduler, self.executor)

    def serve_pool(
        self,
        requests: Sequence[Request],
        schedulers: Sequence,
        policy: str = "least_loaded",
        seed: int = 0,
        horizon: float | None = None,
        charge_scheduler_overhead: bool = False,
    ) -> SimResult:
        """Serve one arrival stream across N replica schedulers (§3.1).

        All replicas share this engine's measured JAX executor (one
        physical backend timed once per batch); the front-end ``policy``
        assigns arrivals to replicas."""
        return run_event_loop(
            list(requests),
            [Worker(s, self.executor) for s in schedulers],
            policy=policy,
            seed=seed,
            horizon=horizon,
            charge_scheduler_overhead=charge_scheduler_overhead,
        )
