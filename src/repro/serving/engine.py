"""Real-execution serving engine: ORLOJ scheduling over actual JAX model
inference with measured wall-clock execution times.

This is the paper's full loop running for real on CPU-jitted models:
variable-length requests → Orloj (or baseline) scheduler → padded batch
(bucketed static shapes, one compiled program per bucket) → measured
execution feeds the online profiler.  Time is *hybrid*: the clock advances
by real measured execution during batches and skips idle gaps, so a trace
that spans minutes replays in seconds while every latency that matters is
genuinely measured.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributions import BatchLatencyModel
from ..core.eventloop import SimResult, Worker, run_event_loop, simulate
from ..core.request import Request
from ..core.scheduler import Batch
from ..models import Model, ModelConfig
from .batcher import bucket_for, make_padded_batch, padded_batch_size
from .faults import FaultPlan
from .trace import offered_rate

__all__ = ["EngineConfig", "JaxExecutor", "DecodeJaxExecutor", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    buckets: tuple[int, ...] = (32, 64, 128, 256)
    batch_sizes: tuple[int, ...] = (1, 2, 4, 8)
    profile_reps: int = 3
    # When > 0, a batch whose measured execution exceeds this is aborted
    # at the timeout and its requests go through the fault tier's
    # deadline-aware retry gate (DESIGN.md §11) — the real engine's
    # defense against a pathological straggler batch wedging the worker.
    batch_timeout_ms: float = 0.0


class JaxExecutor:
    """Executor for the simulator loop that runs the real model and returns
    the *measured* batch execution time (ms).

    Every served batch is appended to :attr:`measured` as ``(padded_k,
    bucket, measured_ms)`` — the executed shape plus its wall-clock — so
    callers (the real-engine eval tier) can attribute predicted-vs-measured
    drift per batch.  Profiling calls go through :meth:`_run` directly and
    are *not* logged.  The log is a bounded ring (:data:`MEASURED_LOG_CAP`
    most recent batches) so callers that never read it — long-running
    serving processes, the examples — cannot leak memory; use
    :meth:`drain_measured` to read-and-reset it around one serving run."""

    MEASURED_LOG_CAP = 4096

    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._fwd = jax.jit(
            lambda p, batch: self.model.logits(p, batch),
        )
        self._compiled: set[tuple[int, int]] = set()
        self.measured: deque[tuple[int, int, float]] = deque(
            maxlen=self.MEASURED_LOG_CAP
        )

    def drain_measured(self) -> list[tuple[int, int, float]]:
        """Return the ``(padded_k, bucket, measured_ms)`` log and reset it."""
        out = list(self.measured)
        self.measured.clear()
        return out

    def padded_batch_size(self, k: int) -> int:
        return padded_batch_size(k, self.cfg.batch_sizes)

    def _run(self, tokens: np.ndarray) -> tuple[float, int]:
        """Execute one padded batch; returns ``(measured_ms, padded_k)``.

        The padded batch size is what the hardware actually ran — the
        latency model must be fit against it (not the requested k), or the
        scheduler's Eq.-3 estimates diverge from measurements whenever a
        batch is padded up to the next supported size."""
        k = self.padded_batch_size(tokens.shape[0])
        if k > tokens.shape[0]:
            tokens = np.concatenate(
                [tokens, np.zeros((k - tokens.shape[0],) + tokens.shape[1:], tokens.dtype)]
            )
        key = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if key not in self._compiled:
            # warm the cache so compile time never pollutes a measurement
            jax.block_until_ready(self._fwd(self.params, batch))
            self._compiled.add(key)
        t0 = time.perf_counter()  # simlint: ignore[R1] -- this executor's whole job is measuring real batch latency
        jax.block_until_ready(self._fwd(self.params, batch))
        return (time.perf_counter() - t0) * 1e3, k  # simlint: ignore[R1] -- real batch latency measurement

    def __call__(self, batch: Batch, now: float) -> float:
        # Admission (make_requests) caps lengths at the largest bucket, so
        # overflow here is a programming error — fail loudly.
        padded = make_padded_batch(batch.requests, self.cfg.buckets, overflow="error")
        ms, k_pad = self._run(padded.tokens)
        self.measured.append((k_pad, padded.labels_bucket, ms))
        return ms


class DecodeJaxExecutor:
    """Measured decode-step executor for the continuous-batching loop
    (DESIGN.md §12): one token step of the running batch = one real
    flash-decode attention call over a ring-buffer KV cache, timed on the
    actual backend.

    The event loop calls :meth:`step_time` once per token step with the
    post-join active set.  The executor keeps a fixed-capacity cache
    ``(max_batch, n_kv_heads, max_cache, head_dim)`` plus per-slot
    ``valid_len``; requests map to slots on join and free them when they
    leave the active set (EOS — reconciled by ``rid`` diff, so the
    executor needs no extra callback).  Empty slots ride along with
    ``valid_len == 0`` (the kernel masks them to zero rows), which keeps
    the decode shape static — one compiled program for the whole run,
    exactly how a serving engine runs its decode kernel.

    Per step the measured cost is
    ``prefill`` (joined prompts through the *prefill executor*'s padded
    forward — the existing :class:`JaxExecutor` path) ``+ decode`` (the
    jitted write-KV-then-attend step at full capacity).

    **Honest scope** — what is and is not real here: batch shapes, cache
    occupancy, masking, and every timed operation are real; the *values*
    (query vectors, cache contents, prompt token ids) are seeded
    synthetic — this executor prices the attention decode step, it does
    not generate text, and it deliberately omits the MLP/sampling cost
    of a full model step.  On CPU hosts the Pallas kernel only runs
    under the (very slow) interpreter, so ``use_pallas=None`` follows
    the kernel-level auto-detect: compiled Pallas on TPU, the jnp
    reference oracle elsewhere — the same numerics, honestly timed on
    what the host can actually run.  Prompts longer than the largest
    prefill bucket are served but their cache entry is truncated to
    ``max_cache`` (a ring buffer keeps the most recent positions)."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        *,
        max_batch: int = 8,
        max_cache: int = 256,
        prefill: JaxExecutor | None = None,
        use_pallas: bool | None = None,
        block_k: int = 256,
        seed: int = 0,
    ):
        if max_batch <= 0 or max_cache <= 0:
            raise ValueError(
                f"max_batch and max_cache must be positive, got "
                f"{max_batch} and {max_cache}"
            )
        self.max_batch = max_batch
        self.max_cache = max_cache
        self.n_heads = model_cfg.n_heads
        self.n_kv = model_cfg.n_kv_heads
        self.head_dim = model_cfg.head_dim or model_cfg.d_model // model_cfg.n_heads
        self.prefill = prefill
        self.use_pallas = (
            jax.default_backend() == "tpu" if use_pallas is None else use_pallas
        )
        self.block_k = block_k
        self._rng = np.random.default_rng(seed)
        self._slot: dict[int, int] = {}  # rid -> cache slot
        self._free = list(range(max_batch - 1, -1, -1))
        shape = (max_batch, self.n_kv, max_cache, self.head_dim)
        self._kc = jnp.zeros(shape, jnp.float32)
        self._vc = jnp.zeros(shape, jnp.float32)
        self._valid = jnp.zeros((max_batch,), jnp.int32)
        self._step = jax.jit(
            self._step_impl, static_argnames=("use_pallas", "block_k")
        )
        # Warm the compile cache so the first measured step is not a
        # compile (mirrors JaxExecutor._run's warm-up discipline).
        self._decode_once()

    # ------------------------------------------------------------ internals
    @staticmethod
    def _step_impl(kc, vc, valid, active, q, nk, nv, *, use_pallas, block_k):
        """Write this step's K/V at each active slot's ring position,
        advance ``valid_len``, attend.  Inactive slots pass through
        untouched and attend over zero valid positions."""
        from ..kernels.ops import decode_attention

        s = kc.shape[2]
        pos = valid % s

        def write(cache, new):
            return jax.vmap(
                lambda c, n, p: jax.lax.dynamic_update_slice(
                    c, n[:, None, :], (0, p, 0)
                )
            )(cache, new, pos)

        sel = active[:, None, None, None]
        kc2 = jnp.where(sel, write(kc, nk), kc)
        vc2 = jnp.where(sel, write(vc, nv), vc)
        valid2 = jnp.where(active, jnp.minimum(valid + 1, s), valid)
        out = decode_attention(
            q, kc2, vc2, valid2, use_pallas=use_pallas, block_k=block_k
        )
        return kc2, vc2, valid2, out

    def _decode_once(self) -> float:
        """One measured decode step at full capacity (ms); mutates the
        cache state of the active slots."""
        b, h, hd = self.max_batch, self.n_heads, self.head_dim
        # Synthetic values are drawn OUTSIDE the timed region: the
        # measurement prices the kernel step, not host-side rng.
        q = jnp.asarray(self._rng.standard_normal((b, h, hd)), jnp.float32)
        nk = jnp.asarray(
            self._rng.standard_normal((b, self.n_kv, hd)), jnp.float32
        )
        nv = jnp.asarray(
            self._rng.standard_normal((b, self.n_kv, hd)), jnp.float32
        )
        active = self._valid > 0  # slots currently holding a request
        t0 = time.perf_counter()  # simlint: ignore[R1] -- real decode-step latency measurement
        kc, vc, valid, out = self._step(
            self._kc, self._vc, self._valid, active, q, nk, nv,
            use_pallas=self.use_pallas, block_k=self.block_k,
        )
        jax.block_until_ready(out)
        ms = (time.perf_counter() - t0) * 1e3  # simlint: ignore[R1] -- real decode-step latency measurement
        self._kc, self._vc, self._valid = kc, vc, valid
        # (B, H, hd) attention output of the last step — synthetic-valued,
        # kept for kernel-integration tests and debugging.
        self.last_out = out
        return ms

    def _prefill_ms(self, joined: Sequence[Request]) -> float:
        """Price the joined prompts through the padded prefill forward and
        seed their cache slots.  Without a prefill executor the forward is
        skipped (decode-only pricing) but slots are still seeded."""
        ms = 0.0
        lens = [max(int(r.prompt_tokens), 1) for r in joined]
        if self.prefill is not None:
            bucket = bucket_for(
                min(max(lens), max(self.prefill.cfg.buckets)),
                self.prefill.cfg.buckets,
            )
            toks = np.zeros((len(joined), bucket), np.int32)
            for i, l in enumerate(lens):
                n_tok = min(l, bucket)
                toks[i, :n_tok] = self._rng.integers(1, 1000, size=n_tok)
            ms, _ = self.prefill._run(toks)
        for r, l in zip(joined, lens):
            if not self._free:
                raise RuntimeError(
                    f"decode executor capacity exceeded: {len(self._slot)} "
                    f"active slots of {self.max_batch}; the token scheduler "
                    f"must admit at most max_batch concurrent requests"
                )
            slot = self._free.pop()
            self._slot[r.rid] = slot
            n_ctx = min(l, self.max_cache)
            kv = self._rng.standard_normal(
                (2, self.n_kv, n_ctx, self.head_dim)
            ).astype(np.float32)
            self._kc = self._kc.at[slot, :, :n_ctx, :].set(kv[0])
            self._vc = self._vc.at[slot, :, :n_ctx, :].set(kv[1])
            self._valid = self._valid.at[slot].set(n_ctx)
        return ms

    def _release_departed(self, active: Sequence[Request]) -> None:
        live = {r.rid for r in active}
        for rid in [r for r in self._slot if r not in live]:
            slot = self._slot.pop(rid)
            self._valid = self._valid.at[slot].set(0)
            self._free.append(slot)

    # ------------------------------------------------------------- API
    def calibrate(self, reps: int = 3) -> float:
        """Median measured decode-step ms at *full* batch capacity — the
        request-generation rate anchor (cache state is restored)."""
        kc, vc, valid = self._kc, self._vc, self._valid
        self._valid = jnp.full((self.max_batch,), self.max_cache, jnp.int32)
        ts = [self._decode_once() for _ in range(reps)]
        self._kc, self._vc, self._valid = kc, vc, valid
        return float(np.median(ts))

    def step_time(
        self, active: Sequence[Request], joined: Sequence[Request], now: float
    ) -> float:
        """Measured ms for one token step: joined prompts' prefill plus
        the full-capacity decode attention step."""
        if not active:
            raise ValueError("step_time called with an empty active set")
        # Departures first (frees slots), then joins (claims them).
        self._release_departed(active)
        ms = self._prefill_ms(joined) if joined else 0.0
        return ms + self._decode_once()


@dataclasses.dataclass
class _ScaledExecutor:
    """A replica whose hardware is ``scale``× slower than the measured
    backend: the shared executor runs the batch for real, and the measured
    duration is scaled before it reaches the virtual clock.  This is how a
    heterogeneous pool is modelled on one physical backend — accounting is
    still anchored to a real measurement per batch."""

    inner: JaxExecutor
    scale: float

    def __call__(self, batch: Batch, now: float) -> float:
        return self.scale * self.inner(batch, now)


class ServingEngine:
    """Profiles the model's Eq.-3 latency curve, generates length-driven
    requests, and runs any scheduler against real execution.

    **Determinism contract** (the seed hooks the eval tier relies on):
    everything *upstream* of execution is seeded — model parameters from
    ``seed`` (:attr:`seed` records it), request generation from the
    ``seed`` passed to :meth:`make_requests`, zero-padding in the batcher —
    so two engines built with the same config and seed serve byte-identical
    batches.  The measured durations themselves are real wall-clock and
    therefore machine- and run-dependent; that is the point of the engine
    substrate, and downstream consumers must not treat them as stable."""

    def __init__(self, model_cfg: ModelConfig, cfg: EngineConfig | None = None, seed: int = 0):
        self.cfg = cfg or EngineConfig()
        self.seed = seed
        self.model = Model(model_cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.executor = JaxExecutor(self.model, self.params, self.cfg)

    def executor_for(self, scale: float = 1.0) -> JaxExecutor | _ScaledExecutor:
        """Executor factory for pool construction: ``scale == 1`` returns
        the shared measured executor; ``scale > 1`` wraps it so the replica
        appears ``scale``× slower (heterogeneous pools, one real backend)."""
        if scale == 1.0:
            return self.executor
        if scale <= 0.0:
            raise ValueError(f"executor scale must be positive, got {scale}")
        return _ScaledExecutor(self.executor, scale)

    # -------------------------------------------------------- profiling
    def profile_latency_model(self) -> BatchLatencyModel:
        """Fit Eq. 3 (l_B = c0 + c1·k·l) from measured (k, bucket) grid.

        On an XLA backend the 'size' l is the padded bucket length in
        tokens; c1 converts tokens→ms."""
        # The grid over supported batch sizes is complete: any off-grid
        # batch pads up to a supported size before executing, so it would
        # measure an identical shape.  Fitting against the executed size
        # reported by _run keeps the attribution correct by construction
        # (requested k and executed k coincide exactly on this grid).
        xs, ys = [], []
        for bucket in self.cfg.buckets:
            for k in sorted(set(self.cfg.batch_sizes)):
                toks = np.ones((k, bucket), np.int32)
                ts, k_pad = [], k
                for _ in range(self.cfg.profile_reps):
                    ms, k_pad = self.executor._run(toks)
                    ts.append(ms)
                xs.append((k_pad, bucket))
                ys.append(float(np.median(ts)))
        a = np.array([[1.0, k * l] for k, l in xs])
        coef, *_ = np.linalg.lstsq(a, np.array(ys), rcond=None)
        c0, c1 = float(max(coef[0], 0.01)), float(max(coef[1], 1e-6))
        return BatchLatencyModel(c0=c0, c1=c1, bucket=0.0)

    # ------------------------------------------------------ request gen
    def make_requests(
        self,
        n: int,
        lm: BatchLatencyModel,
        *,
        length_sampler: Callable[[np.random.Generator], int],
        slo_scale: float = 3.0,
        utilization: float = 0.7,
        seed: int = 0,
    ) -> tuple[list[Request], dict]:
        """Length-driven requests: the execution-time 'distribution' is the
        real consequence of the token-length distribution (the paper's NLP
        case).  true_time is the request's intrinsic size in c1-units
        (= padded token count), so Eq. 3 reproduces measured latency."""
        from .batcher import bucket_for

        rng = np.random.default_rng(seed)
        lengths = np.array([length_sampler(rng) for _ in range(n)])
        # Admission control: the serving path cannot represent payloads
        # beyond the largest bucket, so cap lengths here (explicitly, once)
        # rather than letting the batcher truncate tokens silently.
        lengths = np.minimum(lengths, max(self.cfg.buckets))
        sizes = np.array(
            [bucket_for(int(l), self.cfg.buckets) for l in lengths], np.float64
        )
        alone = lm.c0 + lm.c1 * sizes
        p99 = float(np.quantile(alone, 0.99))
        slo = slo_scale * p99

        rate = offered_rate(
            sizes, lm, utilization, self.cfg.batch_sizes[-1], rng
        )
        gaps = rng.exponential(1.0 / rate, size=n)
        arrivals = np.cumsum(gaps)

        reqs = []
        for i in range(n):
            tok = rng.integers(1, 1000, size=int(lengths[i])).astype(np.int32)
            reqs.append(
                Request(
                    app_id="short" if lengths[i] <= np.median(lengths) else "long",
                    release=float(arrivals[i]),
                    slo=slo,
                    true_time=float(sizes[i]),
                    payload=tok,
                )
            )
        hist = {
            "short": sizes[lengths <= np.median(lengths)],
            "long": sizes[lengths > np.median(lengths)],
        }
        return reqs, hist

    def decode_executor(
        self,
        *,
        max_batch: int = 8,
        max_cache: int = 256,
        use_pallas: bool | None = None,
        seed: int | None = None,
    ) -> DecodeJaxExecutor:
        """Build a :class:`DecodeJaxExecutor` over this engine's model
        dims, wired to the shared measured prefill executor."""
        return DecodeJaxExecutor(
            self.model.cfg,
            max_batch=max_batch,
            max_cache=max_cache,
            prefill=self.executor,
            use_pallas=use_pallas,
            seed=self.seed if seed is None else seed,
        )

    def make_token_requests(
        self,
        n: int,
        decode: DecodeJaxExecutor,
        *,
        mean_out: float = 24.0,
        tpot_scale: float = 2.0,
        ttft_mult: float = 8.0,
        utilization: float = 0.7,
        prompt_lo: int = 16,
        prompt_hi: int = 128,
        seed: int = 0,
    ) -> list[Request]:
        """Token-mode requests anchored to the *measured* decode step:
        geometric output lengths (mean ``mean_out``), uniform prompts,
        TPOT SLO = ``tpot_scale`` × the calibrated full-batch step time,
        TTFT = ``ttft_mult`` × TPOT, arrival rate offering
        ``utilization`` of a worker continuously batching at capacity —
        the engine-substrate analogue of
        :func:`repro.serving.trace.generate_token_requests`."""
        step_ms = decode.calibrate()
        tpot = tpot_scale * step_ms
        ttft = ttft_mult * tpot
        rng = np.random.default_rng(seed)
        out = np.maximum(rng.geometric(1.0 / mean_out, size=n), 1)
        prompts = rng.integers(prompt_lo, prompt_hi + 1, size=n)
        rate = utilization * decode.max_batch / (step_ms * mean_out)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
        return [
            Request(
                app_id="tok",
                release=float(t),
                slo=ttft + tpot * (float(o) - 1.0),
                true_time=float(o) * step_ms,
                prompt_tokens=int(p),
                out_tokens=int(o),
            )
            for t, o, p in zip(arrivals, out, prompts)
        ]

    # ------------------------------------------------------------- run
    def serve_tokens(
        self,
        requests: Sequence[Request],
        scheduler,
        decode: DecodeJaxExecutor,
        *,
        engine: str = "scalar",
    ) -> SimResult:
        """Serve a token-mode request set through the continuous-batching
        loop with measured decode steps (DESIGN.md §12).  The scheduler
        must be a token scheduler (``repro.core.tokensched``) whose
        ``max_batch`` does not exceed the executor's slot capacity."""
        cap = getattr(getattr(scheduler, "cfg", None), "max_batch", None)
        if cap is not None and cap > decode.max_batch:
            raise ValueError(
                f"scheduler admits up to {cap} concurrent requests but the "
                f"decode executor has only {decode.max_batch} cache slots"
            )
        return run_event_loop(
            list(requests), [Worker(scheduler, decode)], engine=engine
        )

    def serve(self, requests: Sequence[Request], scheduler) -> SimResult:
        faults = None
        if self.cfg.batch_timeout_ms > 0.0:
            faults = FaultPlan(batch_timeout_ms=self.cfg.batch_timeout_ms)
        return simulate(list(requests), scheduler, self.executor, faults=faults)

    def serve_pool(
        self,
        requests: Sequence[Request],
        schedulers: Sequence,
        policy: str = "least_loaded",
        seed: int = 0,
        horizon: float | None = None,
        charge_scheduler_overhead: bool = False,
        executors: Sequence | None = None,
    ) -> SimResult:
        """Serve one arrival stream across N replica schedulers (§3.1).

        By default all replicas share this engine's measured JAX executor
        (one physical backend timed once per batch); pass ``executors``
        (one per scheduler, e.g. from :meth:`executor_for`) to build a
        heterogeneous pool of fast and scaled-slow replicas.  The front-end
        ``policy`` assigns arrivals to replicas."""
        if executors is None:
            executors = [self.executor] * len(schedulers)
        if len(executors) != len(schedulers):
            raise ValueError(
                f"got {len(schedulers)} schedulers but {len(executors)} executors"
            )
        return run_event_loop(
            list(requests),
            [Worker(s, e) for s, e in zip(schedulers, executors)],
            policy=policy,
            seed=seed,
            horizon=horizon,
            charge_scheduler_overhead=charge_scheduler_overhead,
        )
