"""Multi-worker scale-out (paper §3.1: "to scale out to a pool of workers
in a cluster setting, different models and their replicas can use ORLOJ in
parallel") — flat pools and the two-level fleet mode.

The replica-pool loop is the N-worker case of the unified engine in
:mod:`repro.core.eventloop`; :func:`simulate_cluster` keeps the historical
call shape (a list of schedulers sharing one executor).  For heterogeneous
pools — per-replica executors, different latency models — build
:class:`~repro.core.eventloop.Worker` pairs and call
:func:`~repro.core.eventloop.run_event_loop` directly.

Flat dispatch policies (see :data:`repro.core.eventloop.DISPATCH_POLICIES`):
``least_loaded``, ``round_robin``, ``jsq_work``, ``p2c``.

**Fleet mode** (DESIGN.md §10): real serving fleets don't run one router
over 10³ replicas — a front-end tier picks a *pool* from cheap aggregate
load signals, and a pool-local router places the request on a replica.
:func:`hierarchical_policy` builds exactly that as a standard event-loop
dispatch callable: the worker list is partitioned into ``n_pools``
contiguous pools; the *inter* level (``p2c``/``jsq_work``/``round_robin``)
chooses a pool from per-pool aggregated backlog (Σ expected queued work,
Σ queue length), and the *intra* level (any flat policy name) chooses the
replica inside the winning pool.  ``p2c`` between pools is the
fleet-realistic default — two aggregate load probes per arrival, never a
full fleet scan — while every replica keeps running its own scheduler
(Orloj within each pool in the paper's framing).
"""

from __future__ import annotations

import itertools
from typing import Callable, Sequence

import numpy as np

from ..core.eventloop import (
    DISPATCH_POLICIES,
    Executor,
    SimResult,
    Worker,
    run_event_loop,
)
from ..core.request import Request
from .faults import FaultPlan
from .residency import ResidencyPlan

__all__ = [
    "DISPATCH_POLICIES",
    "INTER_POOL_POLICIES",
    "ResidencyPlan",
    "Worker",
    "hierarchical_policy",
    "run_event_loop",
    "run_fleet",
    "simulate_cluster",
]

# Front-end (inter-pool) policy names understood by hierarchical_policy.
INTER_POOL_POLICIES = ("p2c", "jsq_work", "round_robin")


def pool_bounds(n_workers: int, n_pools: int) -> list[tuple[int, int]]:
    """Contiguous ``[lo, hi)`` worker ranges of the ``n_pools`` pools, as
    even as possible (the first ``n_workers % n_pools`` pools get one
    extra replica)."""
    if not 1 <= n_pools <= n_workers:
        raise ValueError(
            f"need 1 <= n_pools <= n_workers, got {n_pools} pools over "
            f"{n_workers} workers"
        )
    base, rem = divmod(n_workers, n_pools)
    bounds = []
    lo = 0
    for p in range(n_pools):
        hi = lo + base + (1 if p < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def hierarchical_policy(
    n_workers: int,
    n_pools: int,
    inter: str = "p2c",
    intra: str = "round_robin",
    seed: int = 0,
) -> Callable:
    """Two-level fleet dispatch as an event-loop policy callable.

    The returned ``pick(request, now, pool)`` first selects a pool from
    aggregated backlog (``inter``: one of :data:`INTER_POOL_POLICIES`),
    then a replica within it (``intra``: any flat
    :data:`~repro.core.eventloop.DISPATCH_POLICIES` name).  Aggregate
    backlog of a pool is ``(Σ queued_work, Σ (n_pending + busy +
    pending_offset))`` over its replicas — the same signals the flat
    policies read, summed; ``p2c`` probes two pools, ``jsq_work`` scans
    all of them, ``round_robin`` rotates blindly.

    The policy owns its RNG (seeded by ``seed``), so a fleet run's
    dispatch sequence is deterministic and independent of the event
    loop's own rng consumption.
    """
    if inter not in INTER_POOL_POLICIES:
        raise ValueError(
            f"unknown inter-pool policy {inter!r}; known: "
            f"{list(INTER_POOL_POLICIES)}"
        )
    if intra not in DISPATCH_POLICIES:
        raise ValueError(
            f"unknown intra-pool policy {intra!r}; known: "
            f"{sorted(DISPATCH_POLICIES)}"
        )
    bounds = pool_bounds(n_workers, n_pools)
    rng = np.random.default_rng(seed)
    inter_rr = itertools.cycle(range(n_pools))
    intra_rr = [itertools.cycle(range(lo, hi)) for lo, hi in bounds]

    def pool_backlog(pool, p: int) -> tuple[float, float]:
        lo, hi = bounds[p]
        qw = pool.queued_work
        busy = pool.busy
        off = pool.pending_offset
        work = 0.0
        length = 0.0
        for w in range(lo, hi):
            work += qw[w]
            length += (
                getattr(pool.workers[w].scheduler, "n_pending", 0)
                + busy[w]
                + off[w]
            )
        return (work, length)

    def pick_pool(pool) -> int:
        if n_pools == 1:
            return 0
        if inter == "round_robin":
            return next(inter_rr)
        if inter == "p2c":
            i, j = rng.choice(n_pools, size=2, replace=False)
            i, j = int(i), int(j)
            return i if pool_backlog(pool, i) <= pool_backlog(pool, j) else j
        # jsq_work: full scan over pool aggregates
        best, best_key = 0, pool_backlog(pool, 0)
        for p in range(1, n_pools):
            key = pool_backlog(pool, p)
            if key < best_key:
                best, best_key = p, key
        return best

    def pick_worker(req: Request, now: float, pool, p: int) -> int:
        lo, hi = bounds[p]
        if hi - lo == 1:
            return lo
        if intra == "round_robin":
            return next(intra_rr[p])
        if intra == "residency":
            # Residency before backlog, within the winning pool (DESIGN.md
            # §13): a replica already holding the request's weights beats
            # any warmer-queued cold one; ties fall back to least backlog.
            res = pool.residency
            best, best_key = lo, None
            for w in range(lo, hi):
                load = (
                    getattr(pool.workers[w].scheduler, "n_pending", 0)
                    + pool.busy[w]
                    + pool.pending_offset[w]
                )
                hit = (
                    res is not None
                    and req.model_id is not None
                    and res.resident(w, req.model_id)
                )
                key = (not hit, load, w)
                if best_key is None or key < best_key:
                    best, best_key = w, key
            return best
        if intra == "p2c":
            i, j = rng.choice(hi - lo, size=2, replace=False)
            i, j = lo + int(i), lo + int(j)
            return i if pool.backlog(i) <= pool.backlog(j) else j
        if intra == "jsq_work":
            qw = pool.queued_work
            best, best_w = lo, qw[lo]
            for w in range(lo + 1, hi):
                if qw[w] < best_w:
                    best, best_w = w, qw[w]
            return best
        # least_loaded with rng tie-break, matching the flat policy's shape
        loads = np.array(
            [
                getattr(pool.workers[w].scheduler, "n_pending", 0)
                + pool.busy[w]
                + pool.pending_offset[w]
                for w in range(lo, hi)
            ]
        )
        cands = np.flatnonzero(loads == loads.min())
        return lo + int(rng.choice(cands))

    def pick(req: Request, now: float, pool) -> int:
        return pick_worker(req, now, pool, pick_pool(pool))

    return pick


def run_fleet(
    requests: Sequence[Request],
    workers: Sequence[Worker],
    *,
    n_pools: int,
    inter: str = "p2c",
    intra: str = "round_robin",
    seed: int = 0,
    engine: str = "array",
    horizon: float | None = None,
    faults: "FaultPlan | None" = None,
    residency: "ResidencyPlan | None" = None,
    wall_budget_s: float = 0.0,
) -> SimResult:
    """Drive a two-level fleet: ``inter`` routing across ``n_pools``
    contiguous pools of ``workers``, ``intra`` within the winning pool.
    Defaults to the array engine — fleet scale is what it exists for.

    Under a ``faults`` plan with crashes, requeued work from a dead
    pool's workers re-routes deterministically to live siblings (across
    pool boundaries), so a dead pool drains instead of stranding its
    queue (DESIGN.md §11).  Under a ``residency`` plan,
    ``intra="residency"`` places requests on replicas already holding
    their model's weights (DESIGN.md §13)."""
    return run_event_loop(
        requests,
        list(workers),
        policy=hierarchical_policy(
            len(workers), n_pools, inter=inter, intra=intra, seed=seed
        ),
        seed=seed,
        engine=engine,
        horizon=horizon,
        faults=faults,
        residency=residency,
        wall_budget_s=wall_budget_s,
    )


def simulate_cluster(
    requests: Sequence[Request],
    schedulers: Sequence,
    executor: Executor,
    policy: str | Callable = "least_loaded",
    seed: int = 0,
    horizon: float | None = None,
    charge_scheduler_overhead: bool = False,
    faults: "FaultPlan | None" = None,
) -> SimResult:
    """Drive N replica schedulers (sharing ``executor``) against one
    arrival stream."""
    return run_event_loop(
        requests,
        [Worker(s, executor) for s in schedulers],
        policy=policy,
        seed=seed,
        horizon=horizon,
        charge_scheduler_overhead=charge_scheduler_overhead,
        faults=faults,
    )
