"""Multi-worker scale-out (paper §3.1: "to scale out to a pool of workers
in a cluster setting, different models and their replicas can use ORLOJ in
parallel").

Each replica runs its own ORLOJ (or baseline) scheduler instance; a
front-end load balancer assigns arriving requests to replicas.  Policies:

- ``least_loaded`` — fewest pending requests (power-of-two-choices style
  with full information, the standard serving-tier balancer);
- ``round_robin`` — baseline;
- ``jsq_work`` — least *expected work* queued (Σ per-request E[alone]),
  distribution-aware: uses the same per-app means ORLOJ tracks, so the
  balancer benefits from the paper's profiling substrate too.

The cluster simulator composes the single-worker event loop: one shared
arrival stream, one worker busy-state per replica, non-preemptive batches.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Sequence

import numpy as np

from ..core.request import Request
from ..core.simulator import Executor, SimResult
from ..core.scheduler import Batch

__all__ = ["simulate_cluster"]


def _expected_alone(scheduler, req: Request) -> float:
    dists = getattr(scheduler, "_app_dists", None)
    if dists and req.app_id in dists:
        return float(dists[req.app_id].mean())
    est = getattr(scheduler, "est", None)
    if est is not None:
        return float(est.value())
    return 1.0


def simulate_cluster(
    requests: Sequence[Request],
    schedulers: Sequence,
    executor: Executor,
    policy: str = "least_loaded",
    seed: int = 0,
) -> SimResult:
    """Drive N replica schedulers against one arrival stream."""
    n = len(schedulers)
    rng = np.random.default_rng(seed)
    requests = sorted(requests, key=lambda r: r.release)
    events: list[tuple[float, int, int, object]] = []
    seq = itertools.count()
    ARRIVAL, DONE, WAKE = 0, 1, 2
    for r in requests:
        heapq.heappush(events, (r.release, next(seq), ARRIVAL, r))

    busy = [False] * n
    queued_work = [0.0] * n
    rr = itertools.cycle(range(n))
    worker_busy_time = 0.0
    last_time = 0.0

    def pick(req: Request) -> int:
        if policy == "round_robin":
            return next(rr)
        if policy == "jsq_work":
            return int(np.argmin(queued_work))
        # least_loaded (ties broken randomly)
        loads = np.array([s.n_pending + busy[i] for i, s in enumerate(schedulers)])
        cands = np.flatnonzero(loads == loads.min())
        return int(rng.choice(cands))

    def try_dispatch(w: int, now: float) -> None:
        nonlocal worker_busy_time
        if busy[w]:
            return
        batch, wake = schedulers[w].next_batch(now)
        if batch is not None:
            dur = executor(batch, now)
            for r in batch.requests:
                r.started = now
                queued_work[w] -= _expected_alone(schedulers[w], r)
            busy[w] = True
            worker_busy_time += dur
            heapq.heappush(events, (now + dur, next(seq), DONE, (w, batch)))
        elif wake is not None and np.isfinite(wake) and wake > now:
            heapq.heappush(events, (wake, next(seq), WAKE, w))

    while events:
        now, _, kind, payload = heapq.heappop(events)
        last_time = now
        if kind == ARRIVAL:
            req: Request = payload
            w = pick(req)
            queued_work[w] += _expected_alone(schedulers[w], req)
            schedulers[w].on_arrival(req, now)
            try_dispatch(w, now)
        elif kind == DONE:
            w, batch = payload
            busy[w] = False
            for r in batch.requests:
                r.finished = now
            schedulers[w].on_batch_done(
                batch, now, [r.true_time for r in batch.requests]
            )
            try_dispatch(w, now)
        else:
            try_dispatch(payload, now)

    ok = sum(1 for r in requests if r.ok)
    late = sum(1 for r in requests if r.finished is not None and not r.ok)
    dropped = sum(1 for r in requests if r.dropped is not None)
    unserved = sum(1 for r in requests if r.finished is None and r.dropped is None)
    lat = np.array(
        [r.finished - r.release for r in requests if r.finished is not None]
    )
    return SimResult(
        n_total=len(requests),
        n_finished_ok=ok,
        n_finished_late=late,
        n_dropped=dropped,
        n_unserved=unserved,
        worker_busy=worker_busy_time,
        makespan=last_time * n,  # utilisation across the pool
        latencies=lat,
    )
