"""Multi-worker scale-out (paper §3.1: "to scale out to a pool of workers
in a cluster setting, different models and their replicas can use ORLOJ in
parallel") — compatibility surface.

The replica-pool loop is the N-worker case of the unified engine in
:mod:`repro.core.eventloop`; :func:`simulate_cluster` keeps the historical
call shape (a list of schedulers sharing one executor).  For heterogeneous
pools — per-replica executors, different latency models — build
:class:`~repro.core.eventloop.Worker` pairs and call
:func:`~repro.core.eventloop.run_event_loop` directly.

Dispatch policies (see :data:`repro.core.eventloop.DISPATCH_POLICIES`):
``least_loaded``, ``round_robin``, ``jsq_work``, ``p2c``.
"""

from __future__ import annotations

from typing import Callable, Sequence

from ..core.eventloop import (
    DISPATCH_POLICIES,
    Executor,
    SimResult,
    Worker,
    run_event_loop,
)
from ..core.request import Request

__all__ = ["DISPATCH_POLICIES", "Worker", "run_event_loop", "simulate_cluster"]


def simulate_cluster(
    requests: Sequence[Request],
    schedulers: Sequence,
    executor: Executor,
    policy: str | Callable = "least_loaded",
    seed: int = 0,
    horizon: float | None = None,
    charge_scheduler_overhead: bool = False,
) -> SimResult:
    """Drive N replica schedulers (sharing ``executor``) against one
    arrival stream."""
    return run_event_loop(
        requests,
        [Worker(s, executor) for s in schedulers],
        policy=policy,
        seed=seed,
        horizon=horizon,
        charge_scheduler_overhead=charge_scheduler_overhead,
    )
