"""Coverage of the §3.2 long-term feedback loop (core/profiler.py):
seed/observe ingestion with window eviction, the pickup cadence, and that
a picked-up distribution reflects only in-window samples."""

from __future__ import annotations


from repro.core.profiler import OnlineProfiler, ProfilerConfig


def _profiler(**kw) -> OnlineProfiler:
    cfg = ProfilerConfig(**{"sample_rate": 1.0, "seed": 0, **kw})
    return OnlineProfiler(cfg)


def test_pickup_cadence_none_between_pickups():
    p = _profiler(pickup_interval=100.0)
    p.seed_history("a", [1.0, 2.0, 3.0, 4.0], now=0.0)

    snap = p.maybe_pickup(0.0)
    assert snap is not None and set(snap) == {"a"}

    # Inside the interval: the scheduler keeps its copy.
    assert p.maybe_pickup(50.0) is None
    assert p.maybe_pickup(99.9) is None

    # Past the interval with new data: a fresh snapshot dict.
    p.observe("a", 5.0, now=60.0)
    snap2 = p.maybe_pickup(150.0)
    assert snap2 is not None and set(snap2) == {"a"}
    assert snap2 is not snap and snap2["a"] is not snap["a"]

    # Past the interval but nothing new observed: None (not a stale copy).
    assert p.maybe_pickup(300.0) is None
    # current() still serves the last snapshot.
    assert set(p.current()) == {"a"}


def test_observe_respects_sample_rate_zero():
    p = _profiler(sample_rate=0.0, pickup_interval=0.0)
    p.observe("a", 1.0, now=0.0)
    assert p.maybe_pickup(1.0) is None  # nothing was ingested


def test_pickup_needs_two_samples_per_app():
    p = _profiler(pickup_interval=0.0)
    p.seed_history("solo", [1.0], now=0.0)
    assert p.maybe_pickup(0.0) is None  # one sample cannot make a histogram
    p.observe("solo", 2.0, now=1.0)
    snap = p.maybe_pickup(2.0)
    assert snap is not None and set(snap) == {"solo"}


def test_window_eviction_snapshot_reflects_only_in_window_samples():
    p = _profiler(pickup_interval=0.0, memory_window=100.0)
    p.seed_history("a", [10.0] * 20, now=0.0)
    for _ in range(12):
        p.observe("a", 2.0, now=1_000.0)

    # Pickup at t=1000: the 20 stale samples (t=0 < cutoff 900) are evicted,
    # so the distribution is built from the 12 fresh ones only.
    snap = p.maybe_pickup(1_000.0)
    assert snap is not None
    dist = snap["a"]
    assert abs(dist.mean() - 2.0) < 0.5
    assert dist.hi < 10.0  # no mass anywhere near the stale value


def test_window_eviction_keeps_a_floor_of_samples():
    # All samples stale: eviction must keep >= 8 so the app never loses its
    # distribution entirely (drift reset, not amnesia).
    p = _profiler(pickup_interval=0.0, memory_window=100.0)
    p.seed_history("a", [10.0] * 20, now=0.0)
    p.observe("a", 10.0, now=0.0)  # mark dirty via the observe path too
    snap = p.maybe_pickup(1_000_000.0)
    assert snap is not None
    assert abs(snap["a"].mean() - 10.0) < 0.5
