"""Unit tests for dry-run utilities (no compilation)."""

import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, for_shape, get_config
from repro.launch.dryrun import input_specs, parse_collective_bytes
from repro.models import Model


def test_parse_collective_bytes():
    hlo = """
  %all-gather.17 = bf16[8,128,256]{2,1,0} all-gather(bf16[8,8,256]{2,1,0} %p), dims={1}
  %all-reduce.3 = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%sum
  %ar2 = f32[2,4]{1,0} all-reduce(f32[2,4]{1,0} %y), to_apply=%sum
  %rs = f32[512]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
  %cp = u8[16]{0} collective-permute(u8[16]{0} %w), source_target_pairs={{0,1}}
  %a2a-start.1 = s32[64]{0} all-to-all(s32[64]{0} %v), dimensions={0}
  %not-a-collective = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 256 * 2
    assert got["all-reduce"] == 1024 * 4 + 8 * 4
    assert got["reduce-scatter"] == 512 * 4
    assert got["collective-permute"] == 16
    assert got["all-to-all"] == 64 * 4
    assert "add" not in got


def test_input_specs_cover_all_combinations():
    """Every (arch × shape) yields well-formed ShapeDtypeStruct stand-ins."""
    for arch in ARCHS:
        for shape in SHAPES.values():
            cfg = for_shape(get_config(arch), shape)
            model = Model(cfg)
            ins = input_specs(cfg, shape, model)
            if shape.kind in ("train", "prefill"):
                batch = ins["batch"]
                total = 0
                if "tokens" in batch:
                    assert batch["tokens"].dtype == jnp.int32
                    total += batch["tokens"].shape[1]
                if "frontend_embeds" in batch:
                    fe = batch["frontend_embeds"]
                    assert fe.shape[0] == shape.global_batch
                    if cfg.frontend == "vision":
                        total += fe.shape[1]
                    else:
                        total = fe.shape[1]
                assert total == shape.seq_len, (arch, shape.name)
                if shape.kind == "train":
                    assert batch["labels"].shape == (
                        shape.global_batch,
                        shape.seq_len,
                    )
            else:
                assert ins["pos"].shape == ()
                # decode caches: attention archs carry K/V of the cache len
                leaves = ins["cache"]
                assert leaves is not None


def test_long500k_forces_subquadratic():
    for arch in ("glm4_9b", "nemotron_4_340b", "granite_34b", "dbrx_132b"):
        cfg = for_shape(get_config(arch), SHAPES["long_500k"])
        assert cfg.sliding_window > 0, arch
    # SSM/hybrid archs run natively
    for arch in ("xlstm_1_3b",):
        cfg = for_shape(get_config(arch), SHAPES["long_500k"])
        assert cfg.block_pattern == "xlstm"
    hymba = for_shape(get_config("hymba_1_5b"), SHAPES["long_500k"])
    assert hymba.sliding_window == 1024  # built-in SWA retained


def test_decode_cache_is_bounded_by_window():
    cfg = for_shape(get_config("glm4_9b"), SHAPES["long_500k"])
    model = Model(cfg)
    cache = __import__("jax").eval_shape(
        lambda: model.init_cache(1, cache_len=SHAPES["long_500k"].seq_len)
    )
    import jax

    k_leaves = [
        l for p, l in jax.tree_util.tree_flatten_with_path(cache)[0]
        if any(getattr(k, "key", "") == "k" for k in p)
    ]
    assert all(l.shape[-3] == cfg.sliding_window for l in k_leaves)
