"""Tests for the unified multi-worker discrete-event engine."""

import pytest

from repro.core import (
    BatchLatencyModel,
    ModelExecutor,
    OrlojScheduler,
    Worker,
    run_event_loop,
    simulate,
)
from repro.core.eventloop import DISPATCH_POLICIES
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

LM = BatchLatencyModel(c0=25.0, c1=1.0)
SLOW_LM = BatchLatencyModel(c0=50.0, c1=2.0)

ALL_POLICIES = tuple(DISPATCH_POLICIES)  # every registered dispatch policy


def _rs(util, n=500, seed=11):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=3.0,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=util),
    )


def _orloj(rs, lm=LM):
    return OrlojScheduler(lm, initial_dists=rs.initial_dists())


# ------------------------------------------------- single-worker equivalence
def test_one_worker_reproduces_simulate_bitwise():
    """The unified engine at n_workers=1 is *the* §5 harness: identical
    counts and bit-identical latencies to ``simulate`` on a seeded trace
    (jittered executor included — same seed, same draws)."""
    rs = _rs(util=0.9)
    a = simulate(
        rs.fresh(), _orloj(rs), ModelExecutor(LM, jitter=0.05, seed=3)
    )
    b = run_event_loop(
        rs.fresh(),
        [Worker(_orloj(rs), ModelExecutor(LM, jitter=0.05, seed=3))],
        policy="round_robin",
    )
    for f in (
        "n_total",
        "n_finished_ok",
        "n_finished_late",
        "n_dropped",
        "n_unserved",
        "worker_busy",
        "makespan_ms",
        "n_workers",
        "peak_heap_size",
    ):
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.shape == b.latencies.shape
    assert a.latencies.tobytes() == b.latencies.tobytes()  # bit-for-bit
    assert a.n_workers == 1


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_policy_choice_is_noop_for_one_worker(policy):
    rs = _rs(util=0.8, n=200)
    res = run_event_loop(
        rs.fresh(), [Worker(_orloj(rs), ModelExecutor(LM))], policy=policy
    )
    assert res.n_unserved == 0
    assert res.utilization <= 1.0 + 1e-9


# ------------------------------------------------------------ wake dedup
def test_event_heap_stays_bounded():
    """Per-worker wake dedup: at most one *live* WAKE per worker (plus any
    superseded earlier-re-armed wakes still waiting to fire as no-ops).
    On this seeded light-load trace the high-water mark stays within
    n_requests + 2·n_workers; the pre-unification cluster loop pushed a
    wake on *every* idle dispatch attempt and flooded far past that."""
    n, k = 400, 4
    rs = _rs(util=0.10, n=n)  # light load: workers mostly idle → wake-heavy
    for policy in ALL_POLICIES:
        res = run_event_loop(
            rs.fresh(),
            [Worker(_orloj(rs), ModelExecutor(LM)) for _ in range(k)],
            policy=policy,
        )
        assert res.peak_heap_size <= n + 2 * k, policy
        assert res.n_unserved == 0


# ------------------------------------------------- pool accounting honesty
def test_makespan_and_utilization_are_honest():
    """makespan is the virtual clock of the last event (not multiplied by
    the pool size), n_workers is explicit, and pool utilization is
    worker_busy / (makespan · n_workers) ≤ 1."""
    rs = _rs(util=2.0)
    one = simulate(rs.fresh(), _orloj(rs), ModelExecutor(LM))
    pool = run_event_loop(
        rs.fresh(),
        [Worker(_orloj(rs), ModelExecutor(LM)) for _ in range(3)],
        policy="least_loaded",
    )
    assert pool.n_workers == 3
    # same trace: the pool's clock ends within ~one batch of the
    # single-worker clock, nowhere near 3× (the old makespan=last·n hack)
    assert pool.makespan_ms < 1.5 * one.makespan_ms
    assert pool.worker_busy <= pool.makespan_ms * pool.n_workers + 1e-9
    assert pool.utilization <= 1.0 + 1e-9
    # a 3-replica pool at 2× one-worker load must beat the single worker
    assert pool.finish_rate > one.finish_rate


# ----------------------------------------------- heterogeneous replicas
def test_heterogeneous_pool_all_policies():
    """4 replicas, two fast + two slow (different executors AND different
    latency models per scheduler): completes under every dispatch policy
    with bounded heap and honest utilization."""
    n = 500
    rs = _rs(util=1.8, n=n)
    for policy in ALL_POLICIES:
        workers = []
        for i in range(4):
            lm = LM if i < 2 else SLOW_LM
            workers.append(
                Worker(_orloj(rs, lm=lm), ModelExecutor(lm, seed=i))
            )
        res = run_event_loop(rs.fresh(), workers, policy=policy, seed=7)
        assert res.n_workers == 4
        assert (
            res.n_finished_ok + res.n_finished_late + res.n_dropped
            + res.n_unserved == n
        ), policy
        assert res.utilization <= 1.0 + 1e-9, policy
        assert res.peak_heap_size <= n + 2 * 4, policy
        assert res.finish_rate > 0.4, policy


def test_p2c_tracks_jsq_under_load():
    """Two load probes per arrival should get within striking distance of
    the full-information work-queue balancer."""
    rs = _rs(util=1.6, n=600, seed=23)

    def run(policy):
        return run_event_loop(
            rs.fresh(),
            [Worker(_orloj(rs), ModelExecutor(LM)) for _ in range(4)],
            policy=policy,
            seed=1,
        ).finish_rate

    assert run("p2c") > run("jsq_work") - 0.15


# -------------------------------------------------- horizon & overhead
def test_horizon_truncates_pool_run():
    rs = _rs(util=1.0, n=300)
    res = run_event_loop(
        rs.fresh(),
        [Worker(_orloj(rs), ModelExecutor(LM)) for _ in range(2)],
        horizon=1.0,  # ms: essentially nothing finishes
    )
    assert res.n_unserved > 0
    # honest truncation: the clock reads the horizon, not the first event
    # beyond it, and busy time inside the window keeps utilization ≤ 1
    assert res.makespan_ms == 1.0
    assert 0.0 <= res.utilization <= 1.0 + 1e-9


def test_overhead_charging_completes():
    reqs = _rs(util=0.5, n=100)
    rs = reqs.fresh()
    res = run_event_loop(
        rs,
        [Worker(_orloj(reqs), ModelExecutor(LM))],
        charge_scheduler_overhead=True,
    )
    assert res.n_unserved == 0
    # charged overhead pushes every batch start strictly past its pop time
    assert all(r.started is None or r.started > r.release for r in rs)


# ------------------------------------------------------------- plumbing
def test_unknown_policy_rejected():
    rs = _rs(util=0.5, n=10)
    with pytest.raises(ValueError, match="unknown dispatch policy"):
        run_event_loop(
            rs.fresh(), [Worker(_orloj(rs), ModelExecutor(LM))], policy="nope"
        )
    with pytest.raises(ValueError, match="at least one worker"):
        run_event_loop(rs.fresh(), [])


def test_callable_policy():
    rs = _rs(util=1.0, n=200)
    res = run_event_loop(
        rs.fresh(),
        [Worker(_orloj(rs), ModelExecutor(LM)) for _ in range(2)],
        policy=lambda req, now, pool: req.rid % 2,
    )
    assert res.n_unserved == 0
    assert res.n_workers == 2


# ------------------------------------------------- array-engine contract
# The array engine (RequestStore + EventWheel, DESIGN.md §10) must be
# observably identical to the scalar oracle loop: same counts, same
# bit-level latencies, same per-object bookkeeping.  peak_heap_size is
# the one engine-specific field (both report peak pending events, but
# the scalar heap counts superseded-wake tombstones slightly
# differently), so it is bound-checked, not equality-checked.

_STABLE_FIELDS = (
    "n_total", "n_finished_ok", "n_finished_late", "n_dropped",
    "n_unserved", "worker_busy", "makespan_ms", "n_workers",
    "n_decisions", "n_batches",
)


def _run_both(rs, n_workers=1, policy="round_robin", **kw):
    out = {}
    for engine in ("scalar", "array"):
        reqs = rs.fresh()
        workers = [
            Worker(_orloj(rs), ModelExecutor(LM, seed=i))
            for i in range(n_workers)
        ]
        out[engine] = (
            run_event_loop(reqs, workers, policy=policy, engine=engine, **kw),
            reqs,
        )
    return out


@pytest.mark.parametrize("n_workers,policy", [(1, "round_robin"), (3, "p2c")])
def test_array_engine_bitwise_equivalent(n_workers, policy):
    rs = _rs(util=0.9 * n_workers)
    both = _run_both(rs, n_workers=n_workers, policy=policy)
    a, a_reqs = both["scalar"]
    b, b_reqs = both["array"]
    for f in _STABLE_FIELDS:
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.tobytes() == b.latencies.tobytes()
    # identical per-object bookkeeping (the writeback contract)
    sa = sorted(a_reqs, key=lambda r: r.rid)
    sb = sorted(b_reqs, key=lambda r: r.rid)
    assert [(r.started, r.finished, r.dropped) for r in sa] == [
        (r.started, r.finished, r.dropped) for r in sb
    ]
    assert b.peak_heap_size <= a.peak_heap_size


def test_array_engine_with_quantized_trace():
    """Tick-quantized arrivals (the fleet grids' shape) exercise the
    coalesced same-timestamp bulk paths on both engines."""
    rs = generate_requests(
        bimodal(1.0), LM, slo_scale=3.0,
        cfg=TraceConfig(n_requests=400, seed=7, utilization=0.9, tick_ms=4.0),
    )
    both = _run_both(rs)
    a, _ = both["scalar"]
    b, _ = both["array"]
    for f in _STABLE_FIELDS:
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.tobytes() == b.latencies.tobytes()


def test_array_engine_horizon_and_simulate_entry():
    rs = _rs(util=1.0, n=300)
    res = simulate(
        rs.fresh(), _orloj(rs), ModelExecutor(LM), horizon=1.0, engine="array"
    )
    assert res.makespan_ms == 1.0
    assert res.n_unserved > 0


def test_unknown_engine_rejected():
    rs = _rs(util=0.5, n=10)
    with pytest.raises(ValueError, match="unknown engine"):
        run_event_loop(
            rs.fresh(), [Worker(_orloj(rs), ModelExecutor(LM))], engine="simd"
        )


def test_batch_rows_columnar_scheduler_path():
    """A scheduler speaking the columnar protocol (on_arrival_row /
    on_arrivals_cols, Batch.rows ranges) matches an object-path scheduler
    making the same FIFO decisions — the engine's slice fast paths write
    the same columns the fancy-index fallback does."""
    from benchmarks.queue_micro import (
        _ConstExecutor,
        _eventloop_requests,
        _FifoColsScheduler,
        _FifoObjScheduler,
    )

    master = _eventloop_requests(2_000, tick_ms=4.0, rate_per_ms=64.0)

    def clone():
        return [
            type(r)(app_id=r.app_id, release=r.release, slo=r.slo,
                    true_time=r.true_time)
            for r in master
        ]

    a = run_event_loop(
        clone(), [Worker(_FifoObjScheduler(), _ConstExecutor())], engine="scalar"
    )
    b = run_event_loop(
        clone(), [Worker(_FifoColsScheduler(), _ConstExecutor())], engine="array"
    )
    for f in _STABLE_FIELDS:
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.tobytes() == b.latencies.tobytes()
