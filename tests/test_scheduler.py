"""Tests for the ORLOJ scheduler (Algorithm 1) and the simulator."""

import numpy as np
import pytest

from repro.core import (
    Batch,
    BatchLatencyModel,
    ClipperScheduler,
    ClockworkScheduler,
    EDFScheduler,
    EmpiricalDistribution,
    ModelExecutor,
    NexusScheduler,
    OrlojScheduler,
    Request,
    SchedulerConfig,
    simulate,
)
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal, k_modal, static

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def _dists():
    return {
        "a": EmpiricalDistribution(np.array([10.0, 30.0]), np.array([1.0])),
        "b": EmpiricalDistribution(np.array([80.0, 120.0]), np.array([1.0])),
    }


def _sched(**kw):
    return OrlojScheduler(LM, initial_dists=_dists(), **kw)


def test_single_request_served():
    s = _sched()
    r = Request(app_id="a", release=0.0, slo=500.0, true_time=20.0)
    s.on_arrival(r, 0.0)
    batch, _ = s.next_batch(0.0)
    assert batch is not None and batch.requests == [r]
    assert s.n_pending == 0


def test_batch_formed_from_pending():
    s = _sched()
    reqs = [
        Request(app_id="a", release=0.0, slo=2_000.0, true_time=20.0)
        for _ in range(16)
    ]
    for r in reqs:
        s.on_arrival(r, 0.0)
    batch, _ = s.next_batch(0.0)
    assert batch is not None
    assert len(batch) == batch.batch_size
    assert len(batch) > 1  # plenty of slack: should batch


def test_hopeless_request_dropped():
    s = _sched()
    r = Request(app_id="b", release=0.0, slo=5.0, true_time=100.0)  # impossible
    s.on_arrival(r, 0.0)
    batch, _ = s.next_batch(0.0)
    assert batch is None
    assert r.dropped is not None
    assert s.n_timed_out == 1


def test_deadline_pressure_serves_urgent_in_time():
    """An urgent request among slack ones is served before its deadline.
    (Note: Eq. 2 is *not* strict EDF — a nearly-hopeless request loses
    priority, Fig. 6c — so we assert end-to-end behaviour, not the exact
    batch membership at one instant.)"""
    urgent = Request(app_id="a", release=0.0, slo=180.0, true_time=20.0)
    laters = [
        Request(app_id="a", release=0.0, slo=5_000.0, true_time=20.0)
        for _ in range(6)
    ]
    res = simulate(
        [urgent] + laters, _sched(), ModelExecutor(LM)
    )
    assert urgent.ok
    assert res.n_finished_ok == 7


def test_base_time_reset_keeps_working():
    s = _sched()
    # Drive the clock far enough that b·t would overflow without resets.
    t = 0.0
    served = 0
    for i in range(40):
        t = i * 40_000.0  # 40 s steps → b·t up to 160 ≫ RESET_EXPONENT
        r = Request(app_id="a", release=t, slo=1_000.0, true_time=20.0)
        s.on_arrival(r, t)
        batch, _ = s.next_batch(t)
        if batch:
            served += len(batch)
    assert served == 40  # nothing lost to overflow


def test_milestone_updates_change_selection():
    """As deadlines pass milestones, stale requests decay to zero priority
    and the drop phase removes them."""
    s = _sched()
    r = Request(app_id="a", release=0.0, slo=140.0, true_time=20.0)
    s.on_arrival(r, 0.0)
    # Let its deadline pass without dispatching.
    batch, _ = s.next_batch(139.0)
    # r is infeasible at every batch size by now (est ≥ c0+c1·E[l] > 1ms).
    assert batch is None or r not in batch.requests


def test_paper_desc_ordering_runs():
    s = OrlojScheduler(
        LM,
        cfg=SchedulerConfig(bs_order="paper_desc"),
        initial_dists=_dists(),
    )
    for i in range(8):
        s.on_arrival(
            Request(app_id="a", release=0.0, slo=3_000.0, true_time=20.0), 0.0
        )
    batch, _ = s.next_batch(0.0)
    assert batch is not None


def test_scheduler_end_to_end_finishes_requests():
    rs = generate_requests(
        bimodal(1.0), LM, slo_scale=3.0, cfg=TraceConfig(n_requests=300, seed=0)
    )
    sched = OrlojScheduler(LM, initial_dists=rs.initial_dists())
    res = simulate(rs.fresh(), sched, ModelExecutor(LM))
    assert res.n_total == 300
    assert res.finish_rate > 0.7
    # conservation: every request is accounted for exactly once
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == res.n_total
    )


@pytest.mark.parametrize(
    "mk",
    [
        lambda warm: ClockworkScheduler(LM, init_samples=warm),
        lambda warm: ClockworkScheduler(LM, init_samples=warm, adaptive=True),
        lambda warm: NexusScheduler(LM, init_samples=warm),
        lambda warm: ClipperScheduler(LM, init_samples=warm),
        lambda warm: EDFScheduler(LM, init_samples=warm),
    ],
)
def test_baselines_end_to_end(mk):
    rs = generate_requests(
        bimodal(1.0), LM, slo_scale=3.0, cfg=TraceConfig(n_requests=300, seed=0)
    )
    warm = np.concatenate(list(rs.app_history.values()))
    res = simulate(rs.fresh(), mk(warm), ModelExecutor(LM))
    assert res.n_total == 300
    assert res.finish_rate > 0.2
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == res.n_total
    )


def test_orloj_beats_baselines_on_dynamic():
    """The paper's headline claim, at reduced scale (§5.3)."""
    rs = generate_requests(
        k_modal(3),
        LM,
        slo_scale=4.0,
        cfg=TraceConfig(n_requests=800, seed=2, utilization=0.85),
    )
    warm = np.concatenate(list(rs.app_history.values()))
    orloj = simulate(
        rs.fresh(), OrlojScheduler(LM, initial_dists=rs.initial_dists()),
        ModelExecutor(LM),
    ).finish_rate
    for mk in (NexusScheduler, ClipperScheduler):
        base = simulate(rs.fresh(), mk(LM, init_samples=warm), ModelExecutor(LM))
        assert orloj >= base.finish_rate - 0.02, mk.__name__
    cw = simulate(
        rs.fresh(), ClockworkScheduler(LM, init_samples=warm), ModelExecutor(LM)
    )
    assert orloj >= cw.finish_rate - 0.03


def test_orloj_comparable_on_static():
    """§5.4: no regression on static workloads."""
    rs = generate_requests(
        static(30.0),
        LM,
        slo_scale=4.0,
        cfg=TraceConfig(n_requests=600, seed=3, utilization=0.6),
    )
    warm = np.concatenate(list(rs.app_history.values()))
    orloj = simulate(
        rs.fresh(), OrlojScheduler(LM, initial_dists=rs.initial_dists()),
        ModelExecutor(LM),
    ).finish_rate
    cw = simulate(
        rs.fresh(), ClockworkScheduler(LM, init_samples=warm), ModelExecutor(LM)
    ).finish_rate
    assert orloj >= cw - 0.05


def test_profiler_feedback_loop_adapts():
    """Start with a wrong prior; the online profiler must correct it."""
    wrong = {
        "app0": EmpiricalDistribution(np.array([1.0, 2.0]), np.array([1.0])),
        "app1": EmpiricalDistribution(np.array([1.0, 2.0]), np.array([1.0])),
    }
    rs = generate_requests(
        bimodal(1.0), LM, slo_scale=4.0, cfg=TraceConfig(n_requests=600, seed=4)
    )
    sched = OrlojScheduler(LM, initial_dists=wrong)
    res = simulate(rs.fresh(), sched, ModelExecutor(LM))
    # the learned mixture must end up far from the wrong prior
    assert sched._mix.mean() > 10.0
    assert res.finish_rate > 0.5
