"""Real-engine eval tier (``substrate="engine"``): one ExperimentSpec
drives the actual JAX model through the standard grid-cell lifecycle.

These tests jit and profile a real (toy) model, so they live in the slow
lane with the other engine tests; the engine is cached per process, so
the suite pays model init + XLA compilation once.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.eval import ExperimentSpec, evaluate_claims, run_spec
from repro.eval.run import main as run_main
from repro.eval.runner import read_artifact
from repro.eval.substrate import (
    ENGINE_MODELS,
    _get_engine,
    build_engine_request_set,
    drift_report,
    engine_available,
)

pytestmark = pytest.mark.slow

if not engine_available():  # pragma: no cover - env without jax
    pytest.skip("JAX model stack unavailable", allow_module_level=True)


def _spec(**kw) -> ExperimentSpec:
    base = dict(
        workload="bimodal",
        workload_params={"std": 1.0},
        slo_scale=5.0,
        utilization=0.5,
        n_requests=32,
        seed=3,
        substrate="engine",
        tag="engine/unit",
    )
    base.update(kw)
    return ExperimentSpec(**base)


def test_engine_cell_end_to_end():
    r = run_spec(_spec())
    assert r.spec.substrate == "engine"
    assert r.n_total == 32
    assert (
        r.n_finished_ok + r.n_finished_late + r.n_dropped + r.n_unserved == 32
    )
    m = r.substrate_meta
    assert m["model"] == "orloj_gpt"
    assert m["c0_ms"] > 0 and m["c1_ms_per_token"] > 0
    assert m["n_batches"] > 0
    assert 0.0 <= m["batch_mape"]
    assert len(m["finish_idx"]) == r.n_finished_ok
    # the sim twin replayed the same set under Eq. 3
    assert m["sim_twin"]["n_finished_ok"] + m["sim_twin"]["n_dropped"] <= 32
    # measured latencies flowed into the standard schema
    assert r.latency_p99_ms >= r.latency_p50_ms > 0.0


def test_engine_request_set_is_seed_deterministic():
    """Same spec -> byte-identical request set (lengths, payloads, SLOs);
    the profiled latency curve is cached per process so arrival pacing is
    reproducible too."""
    engine, lm = _get_engine("orloj_gpt")
    spec = _spec()
    a = build_engine_request_set(
        spec, engine.cfg.buckets, engine.cfg.batch_sizes, lm,
        engine.model.cfg.vocab_size,
    )
    b = build_engine_request_set(
        spec, engine.cfg.buckets, engine.cfg.batch_sizes, lm,
        engine.model.cfg.vocab_size,
    )
    assert a.fingerprint() == b.fingerprint()
    assert all(
        np.array_equal(x.payload, y.payload)
        for x, y in zip(a.requests, b.requests)
    )
    # payloads respect the admission contract: at most the largest bucket,
    # token ids within the toy vocab
    assert all(len(r.payload) <= engine.cfg.buckets[-1] for r in a.requests)
    assert all(r.true_time in engine.cfg.buckets for r in a.requests)


def test_engine_cell_determinism_same_seed_same_finish_set():
    """At a generous SLO the finish *set* is timing-robust: two runs of
    the same seeded cell finish exactly the same requests even though the
    measured durations differ run to run.  The SLO must be genuinely
    generous (50x, matching test_engine.py): on a loaded CI runner a
    single OS scheduling hiccup dwarfs a sub-ms toy-model batch, so a
    tight-SLO finish set is *expected* to be noise-sensitive —
    DESIGN.md §8 is explicit that engine outcomes are measurements."""
    r1 = run_spec(_spec(slo_scale=50.0, utilization=0.3))
    r2 = run_spec(_spec(slo_scale=50.0, utilization=0.3))
    assert r1.substrate_meta["finish_idx"] == r2.substrate_meta["finish_idx"]
    assert r1.n_total == r2.n_total
    # measured wall-clock is *not* asserted equal — it never is


def test_engine_results_feed_claims_and_drift_unmodified():
    # Tight-SLO cells so the dominance claim's domain is populated:
    # evaluate_claims states a claim only when the result set carries its
    # cells (static parity and monotonicity need static/multi-SLO series
    # these two cells don't have).
    results = [run_spec(_spec(system=s, slo_scale=1.5, tag=f"engine/unit/{s}"))
               for s in ("orloj", "nexus")]
    claims = evaluate_claims(results)
    assert [c.name for c in claims] == ["tight-slo-dominance"]
    drift = drift_report(results)
    assert drift is not None and drift["n_cells"] == 2
    assert {c["tag"] for c in drift["cells"]} == {
        "engine/unit/orloj",
        "engine/unit/nexus",
    }


def test_engine_hetero_pool_cell():
    """A heterogeneous engine pool: scaled-slow replicas share the one
    measured backend (ServingEngine.executor_for)."""
    r = run_spec(_spec(n_workers=2, hetero=True, policy="jsq_work",
                       utilization=0.8, tag="engine/unit/pool"))
    assert r.n_total == 32
    assert 0.0 <= r.utilization <= 1.0 + 1e-9


def test_cli_engine_smoke_writes_engine_cells(tmp_path):
    out = tmp_path / "BENCH_eval.json"
    rc = run_main(["--grid", "engine-smoke", "--jobs", "1", "--out", str(out)])
    assert rc == 0  # tracked, not gated
    doc, results = read_artifact(str(out))
    assert doc["grid"] == "engine-smoke"
    assert all(r.spec.substrate == "engine" for r in results)
    assert doc["engine_drift"]["n_cells"] == len(results)
    # claims.py consumed the engine cells unmodified
    assert {c["name"] for c in doc["claims"]} >= {"tight-slo-dominance"}


def test_registry_models_resolve_configs():
    """Every registry entry must name a real config module with a serving
    grid; toy entries must stay CPU-sized."""
    import importlib

    for name, entry in ENGINE_MODELS.items():
        mod = importlib.import_module(f"repro.configs.{entry.arch}")
        assert mod.CONFIG.name
        buckets = entry.buckets or mod.SERVE_BUCKETS
        sizes = entry.batch_sizes or mod.SERVE_BATCH_SIZES
        assert tuple(buckets) == tuple(sorted(buckets))
        assert tuple(sizes) == tuple(sorted(sizes))
        if entry.toy:
            cfg = mod.CONFIG.reduced(**dict(entry.config_overrides))
            assert cfg.n_layers <= 2 and cfg.d_model <= 256
