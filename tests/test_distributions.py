"""Tests for empirical distributions and order statistics (paper §4.2)."""

import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.distributions import (
    BatchLatencyModel,
    EmpiricalDistribution,
    hetero_max,
    iid_max,
    mixture,
    ozbey_max_pdf,
    _pdf,
)


def _dist(rng, n_bins=8, lo=1.0, hi=100.0):
    edges = np.sort(rng.uniform(lo, hi, size=n_bins + 1))
    edges += np.arange(n_bins + 1) * 1e-3  # ensure strictly increasing
    probs = rng.random(n_bins) + 1e-3
    return EmpiricalDistribution(edges, probs)


# ---------------------------------------------------------------- basics
def test_normalization_and_mean():
    d = EmpiricalDistribution(np.array([0.0, 1.0, 2.0]), np.array([2.0, 2.0]))
    assert np.isclose(d.probs.sum(), 1.0)
    assert np.isclose(d.mean(), 1.0)


def test_cdf_monotone_and_bounds():
    rng = np.random.default_rng(0)
    d = _dist(rng)
    xs = np.linspace(d.lo - 5, d.hi + 5, 300)
    cdf = d.cdf(xs)
    assert np.all(np.diff(cdf) >= -1e-12)
    assert cdf[0] == 0.0 and cdf[-1] == 1.0


def test_from_samples_and_quantile():
    rng = np.random.default_rng(1)
    samples = rng.lognormal(3.0, 0.5, size=20_000)
    d = EmpiricalDistribution.from_samples(samples, n_bins=64)
    assert np.isclose(d.mean(), samples.mean(), rtol=0.05)
    assert np.isclose(d.quantile(0.5), np.median(samples), rtol=0.1)


def test_delta_distribution():
    d = EmpiricalDistribution.delta(42.0)
    assert np.isclose(d.mean(), 42.0, rtol=1e-2)
    assert d.expected_max(100) <= d.hi


# ------------------------------------------------------- order statistics
def test_iid_max_cdf_is_power():
    """Eq. 6: F_(k) = F^k at the knots."""
    rng = np.random.default_rng(2)
    d = _dist(rng)
    k = 5
    dk = iid_max(d, k)
    assert np.allclose(dk.cdf_at_knots(), d.cdf_at_knots() ** k, atol=1e-12)


def test_expected_max_monte_carlo():
    rng = np.random.default_rng(3)
    d = _dist(rng)
    for k in (1, 2, 4, 16):
        samp = d.sample(rng, size=200_000 // max(k // 4, 1) * k).reshape(-1, k)
        mc = samp.max(axis=1).mean()
        assert np.isclose(d.expected_max(k), mc, rtol=0.02), k


@given(k=st.integers(min_value=1, max_value=64), seed=st.integers(0, 1_000))
@settings(max_examples=30, deadline=None)
def test_expected_max_monotone_in_k(k, seed):
    rng = np.random.default_rng(seed)
    d = _dist(rng)
    e1 = d.expected_max(k)
    e2 = d.expected_max(k + 1)
    assert e2 >= e1 - 1e-9
    assert d.lo - 1e-9 <= e1 <= d.hi + 1e-9


def test_hetero_max_identical_matches_iid():
    rng = np.random.default_rng(4)
    d = _dist(rng)
    hk = hetero_max([d, d, d])
    ik = iid_max(d, 3)
    xs = np.linspace(d.lo, d.hi, 200)
    assert np.allclose(hk.cdf(xs), ik.cdf(xs), atol=5e-3)


def test_hetero_max_mc():
    rng = np.random.default_rng(5)
    ds = [_dist(rng, lo=1, hi=50), _dist(rng, lo=20, hi=120), _dist(rng, lo=5, hi=80)]
    hm = hetero_max(ds)
    samp = np.stack([d.sample(rng, 100_000) for d in ds]).max(axis=0)
    assert np.isclose(hm.mean(), samp.mean(), rtol=0.02)


def test_ozbey_reduces_to_product_cdf():
    """Literal Eq. 8 (k-th order statistic PDF) integrates to the same CDF
    as the product form ``Π F_i`` our implementation uses."""
    rng = np.random.default_rng(6)
    ds = [_dist(rng, n_bins=4, lo=1, hi=40), _dist(rng, n_bins=4, lo=10, hi=60)]
    xs = np.linspace(0.0, 70.0, 4_000)
    pdf = ozbey_max_pdf(ds, xs)
    cdf_from_eq8 = np.cumsum(pdf) * (xs[1] - xs[0])
    cdf_product = ds[0].cdf(xs) * ds[1].cdf(xs)
    assert np.allclose(cdf_from_eq8, cdf_product, atol=2e-2)


def test_ozbey_three_way():
    rng = np.random.default_rng(7)
    ds = [_dist(rng, n_bins=3, lo=1, hi=30) for _ in range(3)]
    xs = np.linspace(0.0, 35.0, 2_000)
    pdf = ozbey_max_pdf(ds, xs)
    cdf_from_eq8 = np.cumsum(pdf) * (xs[1] - xs[0])
    prod = np.ones_like(xs)
    for d in ds:
        prod *= d.cdf(xs)
    assert np.allclose(cdf_from_eq8, prod, atol=3e-2)


# ------------------------------------------------------------- mixtures
def test_mixture_mean():
    rng = np.random.default_rng(8)
    d1, d2 = _dist(rng, lo=1, hi=20), _dist(rng, lo=50, hi=90)
    m = mixture([d1, d2], weights=[0.25, 0.75])
    assert np.isclose(m.mean(), 0.25 * d1.mean() + 0.75 * d2.mean(), rtol=1e-2)


# ------------------------------------------------------- batch latency
def test_batch_latency_model_eq3():
    lm = BatchLatencyModel(c0=5.0, c1=2.0)
    assert lm.batch_time([3.0, 7.0, 1.0]) == 5.0 + 2.0 * 3 * 7.0


def test_batch_dist_affine():
    rng = np.random.default_rng(9)
    d = _dist(rng)
    lm = BatchLatencyModel(c0=5.0, c1=2.0)
    k = 4
    bd = lm.batch_dist(iid_max(d, k), k)
    assert np.isclose(bd.mean(), 5.0 + 2.0 * k * iid_max(d, k).mean(), rtol=1e-9)
    assert np.isclose(lm.expected_batch_time(d, k), 5.0 + 2.0 * k * d.expected_max(k))


def test_bucketed_batch_dist():
    """TPU padded-bucket variant: mass collapses onto bucket boundaries."""
    d = EmpiricalDistribution(np.array([10.0, 90.0]), np.array([1.0]))
    lm = BatchLatencyModel(c0=0.0, c1=1.0, bucket=32.0)
    bd = lm.batch_dist(d, 1)
    # Support must lie (just below) multiples of 32.
    mids = 0.5 * (bd.edges[:-1] + bd.edges[1:])
    mass_bins = mids[bd.probs > 1e-12]
    assert np.all((np.ceil(mass_bins / 32.0) * 32.0 - mass_bins) < 1.0)
    assert lm.batch_time([33.0]) == 64.0


def test_pdf_consistent_with_cdf():
    rng = np.random.default_rng(10)
    d = _dist(rng)
    xs = np.linspace(d.lo, d.hi, 5_000)
    approx_cdf = np.cumsum(_pdf(d, xs)) * (xs[1] - xs[0])
    assert np.allclose(approx_cdf, d.cdf(xs), atol=2e-2)


# -------------------------------------------------------------- fuzzing
@given(
    seed=st.integers(0, 10_000),
    n_bins=st.integers(1, 24),
    k=st.integers(1, 12),
)
@settings(max_examples=40, deadline=None)
def test_property_iid_max_valid_distribution(seed, n_bins, k):
    rng = np.random.default_rng(seed)
    d = _dist(rng, n_bins=n_bins)
    dk = iid_max(d, k)
    assert np.isclose(dk.probs.sum(), 1.0)
    assert np.all(dk.probs >= -1e-12)
    # max stochastically dominates the base distribution
    xs = np.linspace(d.lo, d.hi, 50)
    assert np.all(dk.cdf(xs) <= d.cdf(xs) + 1e-9)
