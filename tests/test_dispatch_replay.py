"""Seed-sweep regression tests for the flat-pool dispatch orderings
(DESIGN.md §7 carry-over): ``p2c-dispatch`` and ``homog-pool-parity``
evaluated over *real* replays of the grid's own pool cells, not synthetic
fixtures (``test_eval.py`` covers the claim arithmetic; this file covers
the orderings the simulator actually produces)."""

import pytest

from repro.eval.claims import (
    HOMOG_BAND,
    P2C_SLACK,
    claim_homog_pool_parity,
    claim_p2c_dispatch,
    claim_scaleout_dispatch,
)
from repro.eval.grid import _scaleout_cells
from repro.eval.runner import run_spec


@pytest.fixture(scope="module")
def pool_results():
    """Replay every `_scaleout_cells` spec the two claims consume: the
    hetero p2c/round_robin pairs plus the full homogeneous policy sweep
    (the exact cells the `small` grid gates in CI, all 3 seeds)."""
    cells = [
        s for s in _scaleout_cells()
        if s.policy in ("p2c", "round_robin") or not s.hetero
    ]
    return [run_spec(s) for s in cells]


def test_p2c_dispatch_on_real_replays(pool_results):
    claim = claim_p2c_dispatch(pool_results)
    assert claim.passed, claim.cells
    # both pool shapes contributed evidence — hetero (where p2c genuinely
    # wins) and homog (where it must at least not lose)
    assert len(claim.cells) == 2
    assert any("hetero" in line for line in claim.cells)
    # the margin is the worst cell's p2c-minus-round_robin plus the slack;
    # a positive raw margin on some seed-mean is what the grid observed
    # (+0.011 hetero) — regression below -slack flips the claim
    assert claim.margin >= 0.0
    assert claim.margin <= 2 * P2C_SLACK  # sanity: slack not silently huge


def test_homog_pool_parity_on_real_replays(pool_results):
    claim = claim_homog_pool_parity(pool_results)
    assert claim.passed, claim.cells
    # every non-best policy on the homogeneous pool produced a gap line
    assert len(claim.cells) >= 2
    assert all("hetero" not in line for line in claim.cells)
    # identical replicas: the observed spread is an order of magnitude
    # inside the band (0.0007 at gate time); half the band means a real
    # behaviour change, not tie-break noise
    assert claim.margin >= HOMOG_BAND / 2


def test_scaleout_jsq_still_ordered_on_homog(pool_results):
    # the original §3.1 ordering stays evaluable on the same replays
    # (homog-only here: jsq_work >= round_robin within its slack)
    claim = claim_scaleout_dispatch(pool_results)
    assert claim.passed, claim.cells
