"""Tests for the flat-npz checkpoint store (``repro.checkpoint.store``):
pytree round-trips, overwrite-in-place, step discovery, loud missing-key /
shape-mismatch restores — and the store acting as the weights source
behind a residency cache (DESIGN.md §13), where the set of restorable
checkpoints and the cache's resident set must stay consistent."""

import numpy as np
import pytest

from repro.checkpoint.store import latest_step, restore_checkpoint, save_checkpoint
from repro.serving.residency import ModelProfile, ResidencyPlan


def _tree(rng, scale=1.0):
    return {
        "embed": {"w": scale * rng.standard_normal((8, 4)).astype(np.float32)},
        "blocks": [
            {"w": scale * rng.standard_normal((4, 4)).astype(np.float32),
             "b": np.zeros((4,), np.float32)}
            for _ in range(2)
        ],
        "head": scale * rng.standard_normal((4, 3)).astype(np.float32),
    }


def _assert_trees_equal(a, b):
    import jax

    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_round_trip(tmp_path):
    tree = _tree(np.random.default_rng(0))
    path = save_checkpoint(tmp_path, 3, tree)
    assert path.name == "step_00000003.npz"
    assert (tmp_path / "treedef.json").exists()
    _assert_trees_equal(restore_checkpoint(tmp_path, 3, tree), tree)


def test_overwrite_same_step_wins(tmp_path):
    rng = np.random.default_rng(1)
    old, new = _tree(rng), _tree(rng, scale=2.0)
    save_checkpoint(tmp_path, 5, old)
    save_checkpoint(tmp_path, 5, new)  # same step: silently replaces
    _assert_trees_equal(restore_checkpoint(tmp_path, 5, old), new)


def test_latest_step(tmp_path):
    assert latest_step(tmp_path) is None  # empty (and nonexistent) dir
    tree = _tree(np.random.default_rng(2))
    for step in (1, 12, 7):
        save_checkpoint(tmp_path, step, tree)
    assert latest_step(tmp_path) == 12
    # stray files that look nothing like checkpoints are ignored
    (tmp_path / "step_notanumber.npz").write_bytes(b"")
    (tmp_path / "notes.txt").write_text("hi")
    assert latest_step(tmp_path) == 12


def test_restore_missing_step_and_missing_key(tmp_path):
    tree = _tree(np.random.default_rng(3))
    save_checkpoint(tmp_path, 1, tree)
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmp_path, 2, tree)
    # a `like` tree with a leaf the checkpoint never saved fails loudly
    wider = dict(tree)
    wider["extra"] = np.zeros((2,), np.float32)
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, 1, wider)


def test_restore_shape_mismatch(tmp_path):
    tree = _tree(np.random.default_rng(4))
    save_checkpoint(tmp_path, 1, tree)
    skewed = dict(tree)
    skewed["head"] = np.zeros((4, 5), np.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(tmp_path, 1, skewed)


def test_store_backs_a_residency_cache(tmp_path):
    """The integration the multi-model tier models: per-model checkpoint
    dirs are the load source, the ResidencyState tracks what's on-device.
    Every model the cache reports resident must be restorable, and the
    cost-aware policy keeps the expensive-to-reload hot model resident."""
    rng = np.random.default_rng(5)
    models = {"hot_big": 3.0, "cold_small": 1.0, "third": 1.0}
    trees = {}
    for name in models:
        trees[name] = _tree(rng, scale=rng.uniform(0.5, 2.0))
        save_checkpoint(tmp_path / name, 0, trees[name])
    # load_ms mirrors checkpoint size: hot_big is the expensive reload
    plan = ResidencyPlan(
        worker_mem=4.0,
        profiles=tuple(
            ModelProfile(model_id=m, nbytes=nb, load_ms=10.0 * nb)
            for m, nb in models.items()
        ),
        policy="cost_aware",
    )
    state = plan.start(1)
    for t in range(4):  # hot_big dominates demand
        state.acquire(0, "hot_big", float(t))
    state.acquire(0, "cold_small", 4.0)
    state.acquire(0, "third", 5.0)  # over budget: cold_small is the victim
    assert state.resident(0, "hot_big") and state.resident(0, "third")
    assert not state.resident(0, "cold_small")
    # the resident set is exactly the loadable, restorable checkpoints
    for name in models:
        if state.resident(0, name):
            assert latest_step(tmp_path / name) == 0
            _assert_trees_equal(
                restore_checkpoint(tmp_path / name, 0, trees[name]),
                trees[name],
            )
