"""Oracle/property tests for the vectorized scheduler hot path.

The invariants (DESIGN.md §Hot-path):

- ``BinScoreModel.score_many`` agrees *bit for bit* with the scalar
  ``score`` (which is a thin wrapper over it) and with the literal-Eq.-2
  ``value_reference`` oracle to float tolerance, across all three regimes
  and for piecewise-step costs;
- ``HullQueue.insert_many`` / ``bulk_load`` produce an envelope identical
  to sequential ``insert``;
- ``OrlojScheduler.on_arrivals`` leaves the scheduler in the same state as
  the equivalent sequence of ``on_arrival`` calls.
"""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core import (
    BatchLatencyModel,
    EmpiricalDistribution,
    OrlojScheduler,
    Request,
)
from repro.core.hull import HullQueue
from repro.core.priority import DEFAULT_B, BinScoreModel, aggregate_steps

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def _model(b=DEFAULT_B, edges=(20.0, 60.0, 120.0, 260.0), probs=(0.5, 0.3, 0.2)):
    d = EmpiricalDistribution(np.array(edges), np.array(probs))
    return BinScoreModel(d, b=b)


def _req(release=0.0, slo=500.0, cost=1.0, **kw):
    return Request(app_id="a", release=release, slo=slo, true_time=10.0,
                   cost=cost, **kw)


# --------------------------------------------------------------- score_many
def test_score_many_matches_scalar_bitwise_all_regimes():
    """One vectorized pass == N scalar scores, bit for bit, with t placed
    before / inside / after every milestone of every request."""
    m = _model()
    reqs = [_req(release=30.0 * i, slo=200.0 + 90.0 * i, cost=1.0 + 0.5 * i)
            for i in range(12)]
    deadlines = np.array([r.release + r.slo for r in reqs])
    costs = np.array([r.cost for r in reqs])
    # every milestone edge ± epsilon, plus far-before and far-after
    probes = [0.0, 5_000.0]
    for d in deadlines:
        for edge in np.concatenate([m.l1, m.l2]):
            for eps in (-1e-3, 0.0, 1e-3):
                probes.append(d - edge + eps)
    for t in probes:
        alpha, beta, miles = m.score_many(deadlines, costs, t, base=0.0)
        for i, r in enumerate(reqs):
            sc = m.score(r, t, base=0.0)
            assert sc.alpha == alpha[i], (t, i)
            assert sc.beta == beta[i], (t, i)
            assert sc.milestone == miles[i], (t, i)


def test_score_many_matches_literal_eq2_oracle():
    m = _model()
    reqs = [_req(release=17.0 * i, slo=150.0 + 123.0 * i) for i in range(8)]
    deadlines = np.array([r.release + r.slo for r in reqs])
    costs = np.array([r.cost for r in reqs])
    for t in np.linspace(0.0, 1_500.0, 61):
        alpha, beta, _ = m.score_many(deadlines, costs, t, base=0.0)
        x = math.exp(m.b * t)
        for i, r in enumerate(reqs):
            want = m.value_reference(r, t, base=0.0)
            got = alpha[i] * x + beta[i]
            assert np.isclose(got, want, rtol=1e-9, atol=1e-12), (t, i)


def test_score_many_piecewise_step_costs():
    """Appendix-B decomposition through the flat-step + aggregate path."""
    m = _model()
    multi = _req(slo=400.0, cost=1.0, extra_deadlines=((600.0, 3.0), (900.0, 4.5)))
    from repro.core.scheduler import _flatten_steps, _score_flat

    for t in (0.0, 150.0, 380.0, 450.0, 640.0, 880.0, 1_000.0):
        d, c, seg = _flatten_steps([multi, _req(slo=500.0)])
        assert seg is not None and list(seg) == [0, 3]
        alpha, beta, miles = _score_flat(m, d, c, seg, t, 0.0)
        sc = m.score(multi, t, 0.0)
        assert sc.alpha == alpha[0] and sc.beta == beta[0]
        assert sc.milestone == miles[0]
        assert np.isclose(
            alpha[0] * math.exp(m.b * t) + beta[0],
            m.value_reference(multi, t, 0.0),
            rtol=1e-9, atol=1e-12,
        )


def test_score_many_milestones_strictly_future():
    """A returned milestone is > t (up to one float rounding step, which the
    scheduler guards); at a milestone the folded (α, β) change."""
    m = _model()
    r = _req(slo=400.0)
    t = 0.0
    seen = 0
    while True:
        sc = m.score(r, t, 0.0)
        if not math.isfinite(sc.milestone):
            break
        assert sc.milestone > t
        nxt = m.score(r, sc.milestone, 0.0)
        assert (nxt.alpha, nxt.beta) != (sc.alpha, sc.beta)
        t = sc.milestone
        seen += 1
    # every distinct regime edge (D − l for each unique bin edge) visited
    assert seen == np.union1d(m.l1, m.l2).size


def test_milestones_never_dropped_with_fullmantissa_edges():
    """Regression: with profiler-derived bin edges (full float mantissas)
    the time-space milestone ``fl(D − l)`` can land exactly ON the wake
    time while the slack-space regime test has not flipped yet; the
    scheduler re-scores at exactly that instant (the WAKE path).  The
    returned next milestone must still be strictly future — a dropped one
    would leave the hull line stale until a base reset.  Walking every
    milestone at its exact float time must terminate with a ~zero score
    past the last regime edge."""
    rng = np.random.default_rng(42)
    for trial in range(50):
        samples = rng.lognormal(mean=3.0, sigma=0.7, size=64)
        d = EmpiricalDistribution.from_samples(samples, n_bins=12)
        m = BinScoreModel(d, b=DEFAULT_B)
        r = _req(release=float(rng.uniform(0, 1e6)),
                 slo=float(rng.uniform(200.0, 4_000.0)))
        t = r.release
        hops = 0
        while True:
            sc = m.score(r, t, base=r.release)
            if not math.isfinite(sc.milestone):
                break
            assert sc.milestone > t, (trial, t)
            t = sc.milestone  # re-score at the exact wake float
            hops += 1
            assert hops <= 2 * (len(m.l1) + len(m.l2)), trial
        # past the last edge the priority has decayed to (numerically) zero
        assert abs(m.value(r, t + 1e-6, r.release)) < 1e-9


@given(
    slo=st.floats(min_value=50.0, max_value=5_000.0),
    t=st.floats(min_value=0.0, max_value=5_000.0),
    base=st.floats(min_value=-1_000.0, max_value=1_000.0),
    cost=st.floats(min_value=0.1, max_value=50.0),
)
@settings(max_examples=80, deadline=None)
def test_property_score_many_equals_scalar(slo, t, base, cost):
    m = _model()
    r = _req(slo=slo, cost=cost)
    alpha, beta, miles = m.score_many(
        np.array([r.deadline]), np.array([cost]), t, base
    )
    sc = m.score(r, t, base)
    assert sc.alpha == alpha[0] and sc.beta == beta[0]
    assert sc.milestone == miles[0]
    assert np.isclose(
        sc.value(t, base, m.b), m.value_reference(r, t, base),
        rtol=1e-9, atol=1e-12,
    )


def test_aggregate_steps_segments():
    alpha = np.array([1.0, 2.0, 4.0, 8.0])
    beta = np.array([0.5, 0.25, 0.125, 0.0625])
    miles = np.array([9.0, 3.0, np.inf, 7.0])
    a, b, m = aggregate_steps(alpha, beta, miles, np.array([0, 2]))
    assert list(a) == [3.0, 12.0]
    assert list(b) == [0.75, 0.1875]
    assert list(m) == [3.0, 7.0]


# ---------------------------------------------------------------- bulk hull
def _envelope(q: HullQueue, xs) -> list:
    return [q.argmax(float(x)) for x in xs]


def test_bulk_load_envelope_matches_sequential_insert():
    rng = np.random.default_rng(5)
    lines = [(i, float(a), float(b))
             for i, (a, b) in enumerate(rng.normal(size=(300, 2)) * 50)]
    xs = np.exp(rng.uniform(0, 10, size=64))
    seq = HullQueue()
    for k, a, b in lines:
        seq.insert(k, a, b)
    bulk = HullQueue()
    bulk.bulk_load(lines)
    assert len(seq) == len(bulk) == 300
    for got, want in zip(_envelope(bulk, xs), _envelope(seq, xs)):
        assert got is not None and want is not None
        assert math.isclose(got[1], want[1], rel_tol=1e-12)


def test_insert_many_then_ops_matches_reference():
    rng = np.random.default_rng(6)
    q = HullQueue()
    ref: dict = {}
    key = 0
    for _ in range(30):  # interleave bulk loads with deletes/updates/queries
        chunk = [(key + j, float(a), float(b))
                 for j, (a, b) in enumerate(rng.normal(size=(17, 2)) * 40)]
        key += len(chunk)
        q.insert_many(chunk)
        ref.update({k: (a, b) for k, a, b in chunk})
        for k in list(ref)[:: 5]:
            if rng.random() < 0.5:
                q.delete(k)
                del ref[k]
            else:
                a, b = rng.normal(size=2) * 40
                q.update(k, float(a), float(b))
                ref[k] = (float(a), float(b))
        x = float(np.exp(rng.uniform(0, 8)))
        got = q.argmax(x)
        want = max(ref.values(), key=lambda ab: ab[0] * x + ab[1])
        assert got is not None
        assert math.isclose(got[1], want[0] * x + want[1],
                            rel_tol=1e-9, abs_tol=1e-9)
    assert len(q) == len(ref)


def test_insert_many_validates_before_mutating():
    q = HullQueue()
    q.insert("a", 1.0, 2.0)
    with pytest.raises(KeyError):
        q.insert_many([("b", 1.0, 1.0), ("a", 2.0, 2.0)])  # dup vs existing
    assert "b" not in q and len(q) == 1  # nothing was half-inserted
    with pytest.raises(KeyError):
        q.insert_many([("c", 1.0, 1.0), ("c", 2.0, 2.0)])  # dup within batch
    assert "c" not in q
    with pytest.raises(ValueError):
        q.insert_many([("d", math.inf, 0.0)])
    assert "d" not in q


# ------------------------------------------------------------- on_arrivals
def _dists():
    return {
        "a": EmpiricalDistribution(np.array([10.0, 30.0]), np.array([1.0])),
        "b": EmpiricalDistribution(np.array([80.0, 120.0]), np.array([1.0])),
    }


def test_on_arrivals_equals_sequential_on_arrival():
    """Bulk delivery leaves the scheduler in the same state as the
    request-at-a-time path: same pending set, same hull envelopes, same
    batch decisions."""
    def mk_reqs():
        return [
            Request(app_id="a" if i % 3 else "b", release=0.0,
                    slo=300.0 + 40.0 * i, true_time=20.0, rid=1_000 + i,
                    cost=1.0 + (i % 2),
                    extra_deadlines=((700.0 + 40.0 * i, 3.0),) if i % 4 == 0
                    else ())
            for i in range(24)
        ]

    bulk = OrlojScheduler(LM, initial_dists=_dists())
    seq = OrlojScheduler(LM, initial_dists=_dists())
    bulk.on_arrivals(mk_reqs(), now=0.0)
    for r in mk_reqs():
        seq.on_arrival(r, now=0.0)

    assert bulk.n_pending == seq.n_pending
    assert set(bulk._pending) == set(seq._pending)
    xs = np.exp(np.linspace(0.0, 0.05, 7))
    for bs in bulk.cfg.batch_sizes:
        hb, hs = bulk._bs_state[bs].hull, seq._bs_state[bs].hull
        assert set(hb.keys()) == set(hs.keys())
        for k in hb.keys():
            for x in xs:
                assert hb.value(k, float(x)) == hs.value(k, float(x))
    assert sorted(bulk._milestones) == sorted(seq._milestones)

    ba, _ = bulk.next_batch(10.0)
    sa, _ = seq.next_batch(10.0)
    assert ba is not None and sa is not None
    assert ba.batch_size == sa.batch_size
    assert {r.rid for r in ba.requests} == {r.rid for r in sa.requests}


def test_on_arrivals_empty_is_noop():
    s = OrlojScheduler(LM, initial_dists=_dists())
    s.on_arrivals([], now=0.0)
    assert s.n_pending == 0
    batch, wake = s.next_batch(0.0)
    assert batch is None


def test_same_timestamp_burst_multiworker_all_policies():
    """Coalesced bursts: same-release arrivals are routed with each idle
    dispatch visible to later picks (a burst over an idle pool spreads
    across workers instead of piling onto one), and everything is
    conserved under every policy."""
    from repro.core import ModelExecutor, Worker, run_event_loop
    from repro.core.eventloop import DISPATCH_POLICIES

    for policy in DISPATCH_POLICIES:
        reqs = [
            Request(app_id="a", release=float(200 * (i // 8)),
                    slo=4_000.0, true_time=20.0)
            for i in range(48)  # bursts of 8 at t = 0, 200, 400, ...
        ]
        dispatch_log: list[tuple[int, float, int]] = []

        def mk_exec(i: int):
            inner = ModelExecutor(LM)

            def run(batch, now):
                dispatch_log.append((i, now, len(batch.requests)))
                return inner(batch, now)

            return run

        workers = [
            Worker(OrlojScheduler(LM, initial_dists=_dists()), mk_exec(i))
            for i in range(3)
        ]
        res = run_event_loop(reqs, workers, policy=policy, seed=3)
        assert res.n_total == 48, policy
        assert (res.n_finished_ok + res.n_finished_late + res.n_dropped
                + res.n_unserved) == 48, policy
        assert res.n_unserved == 0, policy
        # the burst head grabs an idle worker at its release instant …
        assert any(now == 0.0 for _, now, _ in dispatch_log), policy
        # … and load-aware routing sees that dispatch: the 8-deep burst
        # over 3 idle workers starts on at least two of them at t = 0
        if policy in ("least_loaded", "jsq_work", "round_robin"):
            assert len({i for i, now, _ in dispatch_log if now == 0.0}) >= 2, (
                policy
            )


def test_recompute_after_base_reset_uses_bulk_path():
    """Base reset far in the future recomputes every score; values must
    stay base-shift invariant and the scheduler keeps serving."""
    s = OrlojScheduler(LM, initial_dists=_dists())
    reqs = [Request(app_id="a", release=0.0, slo=10_000_000.0, true_time=20.0)
            for _ in range(32)]
    s.on_arrivals(reqs, now=0.0)
    # drive past the reset threshold: b·(t − base) > RESET_EXPONENT
    t = 700_000.0
    batch, _ = s.next_batch(t)
    assert s._base == t  # reset happened
    assert batch is not None and len(batch) >= 1
