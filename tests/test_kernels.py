"""Pallas kernel validation: sweep shapes/dtypes, assert_allclose against
the pure-jnp oracles (interpret=True executes the kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention, flash_attention, moe_gating, rmsnorm
from repro.kernels import ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,h,kv,s,hd",
    [
        (1, 4, 4, 128, 64),     # MHA
        (2, 8, 2, 256, 64),     # GQA 4:1
        (1, 4, 1, 128, 128),    # MQA
        (2, 2, 2, 64, 32),      # small block (block > seq clamps)
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, h, kv, s, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kv, s, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, s, hd)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_lengths_mask_padded_batch():
    """The ORLOJ padded-batch model: short requests padded to the max must
    be numerically identical to running them alone."""
    rng = np.random.default_rng(1)
    b, h, s, hd = 3, 4, 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    lengths = jnp.array([128, 70, 17], jnp.int32)
    out = flash_attention(q, k, v, lengths, block_q=64, block_k=64)
    for i, L in enumerate([128, 70, 17]):
        alone = flash_attention(
            q[i : i + 1, :, :L], k[i : i + 1, :, :L], v[i : i + 1, :, :L],
            block_q=64, block_k=64,
        )
        np.testing.assert_allclose(
            np.asarray(out[i, :, :L], np.float32),
            np.asarray(alone[0], np.float32),
            rtol=2e-5,
            atol=2e-5,
        )


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(2)
    b, h, s, hd = 1, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=2e-5, atol=2e-5
    )


def test_flash_attention_noncausal():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------- decode attention
@pytest.mark.parametrize(
    "b,h,kv,s,hd",
    [(2, 8, 2, 512, 64), (1, 4, 4, 256, 128), (4, 8, 1, 1024, 64)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_shapes(b, h, kv, s, hd, dtype):
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(b, h, hd)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), dtype)
    valid = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = decode_attention(q, kc, vc, valid, block_k=128)
    want = ref.decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "s,block_k",
    [
        (300, 256),   # S % bk != 0: bk rounds down to a divisor (150)
        (96, 64),     # rounds 64 -> 48
        (7, 256),     # S prime and < bk: degenerates to bk=7
        (130, 128),   # 130 = 2*5*13: largest divisor <= 128 is 65
    ],
)
def test_decode_attention_nondivisible_cache_length(s, block_k):
    """Regression: S % block_k != 0 used to trip the divisor assert."""
    rng = np.random.default_rng(9)
    b, h, kv, hd = 2, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
    valid = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = decode_attention(q, kc, vc, valid, block_k=block_k)
    want = ref.decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-5, atol=2e-5,
    )


def test_decode_attention_empty_rows():
    """valid_len == 0 rows (freshly admitted, cache unwritten) must produce
    zeros — not NaN from a 0/0 softmax — and must not disturb live rows."""
    rng = np.random.default_rng(10)
    b, h, kv, s, hd = 3, 4, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, kv, s, hd)), jnp.float32)
    valid = jnp.array([0, 77, 0], jnp.int32)
    out = decode_attention(q, kc, vc, valid, block_k=64)
    want = ref.decode_attention_ref(q, kc, vc, valid)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-5, atol=2e-5,
    )


def test_decode_attention_matches_flash_last_row():
    """Decoding the last position must equal the last row of full flash."""
    rng = np.random.default_rng(5)
    b, h, s, hd = 1, 4, 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    full = flash_attention(q, k, v, block_q=64, block_k=64)
    dec = decode_attention(
        q[:, :, -1], k, v, jnp.array([s], jnp.int32), block_k=64
    )
    np.testing.assert_allclose(
        np.asarray(full[:, :, -1], np.float32),
        np.asarray(dec, np.float32),
        rtol=2e-5,
        atol=2e-5,
    )


# --------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("t,d", [(256, 128), (512, 1024), (64, 896)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(t, d, dtype):
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(t, d)) * 3, dtype)
    scale = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    out = rmsnorm(x, scale)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_rmsnorm_nd_input():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    scale = jnp.ones((64,), jnp.float32)
    out = rmsnorm(x, scale)
    want = ref.rmsnorm_ref(x.reshape(-1, 64), scale).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5)


# ------------------------------------------------------------ moe gating
@pytest.mark.parametrize("t,e,k", [(256, 16, 4), (512, 128, 2), (256, 8, 1)])
def test_moe_gating(t, e, k):
    rng = np.random.default_rng(8)
    logits = jnp.asarray(rng.normal(size=(t, e)) * 2, jnp.float32)
    gates, idx = moe_gating(logits, k)
    wg, wi = ref.moe_gating_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gates), np.asarray(wg), rtol=1e-5, atol=1e-6)
    # gates normalised over the selected experts
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
