"""The ``repro.eval`` subsystem: spec JSON round-trips, runner determinism
(serial == parallel, run-to-run), the substrate field (sim-only here —
real-engine cells are exercised in ``test_eval_engine.py``), the claims
layer, the sched-throughput CI gate, and the CLI artifact."""

from __future__ import annotations

import json

import pytest

from repro.eval import (
    ClaimResult,
    ExperimentResult,
    ExperimentSpec,
    evaluate_claims,
    read_artifact,
    run_spec,
    run_specs,
    write_artifact,
)
from repro.eval.claims import (
    claim_scaleout_dispatch,
    claim_slo_monotonicity,
    claim_static_parity,
    claim_tight_slo_dominance,
)
from repro.eval.grid import (
    GRIDS,
    SYSTEMS,
    _scaleout_cells,
    engine_smoke,
    small,
    tiny,
    tokens,
)


# -- specs -------------------------------------------------------------------


def test_spec_json_round_trip():
    spec = ExperimentSpec(
        workload="bimodal",
        workload_params={"std": [2.0, 0.5]},
        slo_scale=1.5,
        utilization=0.9,
        n_requests=77,
        seed=3,
        system="nexus",
        n_workers=2,
        policy="p2c",
        sched_cfg={"b": 1e-3},
        tag="t",
    )
    blob = json.dumps(spec.to_dict())
    assert ExperimentSpec.from_dict(json.loads(blob)) == spec


def test_result_json_round_trip_and_stable_dict():
    r = run_spec(
        ExperimentSpec(workload="static", slo_scale=3.0, n_requests=60, seed=1)
    )
    blob = json.dumps(r.to_dict())
    r2 = ExperimentResult.from_dict(json.loads(blob))
    assert r2 == r
    stable = r.stable_dict()
    assert "finish_rate" in stable
    for timing in ("sched_time_ms", "sched_us_per_request", "wall_s"):
        assert timing not in stable


def test_unknown_system_and_family_are_rejected():
    with pytest.raises(ValueError, match="unknown system"):
        run_spec(ExperimentSpec(workload="bimodal", slo_scale=2.0, system="nope"))
    with pytest.raises(ValueError, match="unknown workload family"):
        run_spec(ExperimentSpec(workload="nope", slo_scale=2.0))


def test_grids_are_well_formed():
    for name, build in GRIDS.items():
        specs = build()
        assert specs, name
        assert len({s.tag for s in specs}) == len(specs)  # tags are unique
    assert len(small()) == 3 * 3 * 5 * len(SYSTEMS) + len(_scaleout_cells()) + len(
        tokens()
    )


def test_spec_substrate_round_trip_and_default():
    spec = ExperimentSpec(
        workload="bimodal", slo_scale=1.5, substrate="engine", tag="e"
    )
    blob = json.dumps(spec.to_dict())
    assert ExperimentSpec.from_dict(json.loads(blob)) == spec
    # Pre-substrate JSON (PR 3 artifacts) loads with the sim default.
    legacy = spec.to_dict()
    del legacy["substrate"]
    assert ExperimentSpec.from_dict(legacy).substrate == "sim"


def test_parse_substrate():
    from repro.eval import parse_substrate

    assert parse_substrate("sim") == ("sim", "")
    assert parse_substrate("engine") == ("engine", "orloj_gpt")
    assert parse_substrate("engine:orloj_gpt_paper") == (
        "engine",
        "orloj_gpt_paper",
    )
    with pytest.raises(ValueError, match="unknown substrate"):
        parse_substrate("gpu")
    with pytest.raises(ValueError, match="unknown engine model"):
        parse_substrate("engine:nope")


def test_engine_substrate_unavailable_raises(monkeypatch):
    """A bare environment (no JAX model stack) must fail an engine cell
    with an actionable error — and must fail *only* engine cells: sim
    cells never touch the model stack."""
    import repro.eval.substrate as substrate

    monkeypatch.setattr(
        substrate, "_engine_import_error", lambda: "ImportError: no jax"
    )
    monkeypatch.setattr(substrate, "_ENGINE_CACHE", {})
    with pytest.raises(RuntimeError, match="substrate 'engine' needs the JAX"):
        run_spec(
            ExperimentSpec(workload="bimodal", slo_scale=3.0, substrate="engine")
        )
    # sim cells are untouched by the patched availability
    r = run_spec(ExperimentSpec(workload="static", slo_scale=3.0, n_requests=40))
    assert r.n_total == 40


def test_engine_substrate_rejects_time_scale():
    """The Fig.-14 shrink knob is sim-only: on the engine substrate the
    calibration rescale would cancel it bit-for-bit, so it must error
    rather than silently no-op."""
    with pytest.raises(ValueError, match="time_scale"):
        run_spec(
            ExperimentSpec(
                workload="bimodal",
                slo_scale=3.0,
                substrate="engine",
                time_scale=0.5,
            )
        )


def test_engine_smoke_grid_shape():
    specs = engine_smoke()
    assert 2 <= len(specs) <= 4
    assert all(s.substrate == "engine" for s in specs)
    assert len({s.tag for s in specs}) == len(specs)


# -- runner determinism ------------------------------------------------------


def _mini_grid() -> list[ExperimentSpec]:
    return [
        ExperimentSpec(
            workload=fam,
            workload_params=params,
            slo_scale=slo,
            n_requests=100,
            seed=7,
            system=system,
        )
        for fam, params in (("bimodal", {"std": 1.0}), ("static", {"mean": 12.0}))
        for slo in (1.5, 3.0)
        for system in ("orloj", "nexus")
    ]


def test_runner_is_deterministic_serial_and_parallel():
    specs = _mini_grid()
    serial_a = [r.stable_dict() for r in run_specs(specs, jobs=1)]
    serial_b = [r.stable_dict() for r in run_specs(specs, jobs=1)]
    assert serial_a == serial_b

    parallel = [r.stable_dict() for r in run_specs(specs, jobs=2)]
    assert parallel == serial_a  # same cells, same order, same outcomes


def test_multi_worker_spec_runs_and_reports_pool():
    r = run_spec(
        ExperimentSpec(
            workload="bimodal",
            slo_scale=3.0,
            utilization=1.6,
            n_requests=120,
            seed=13,
            n_workers=2,
            policy="p2c",
        )
    )
    assert r.n_total == 120
    assert 0.0 <= r.utilization <= 1.0


# -- claims ------------------------------------------------------------------


def _fake(
    system: str,
    finish_rate: float,
    slo: float = 1.5,
    family: str = "bimodal",
    seed: int = 0,
) -> ExperimentResult:
    spec = ExperimentSpec(
        workload=family,
        workload_params={},
        slo_scale=slo,
        n_requests=100,
        seed=seed,
        system=system,
    )
    return ExperimentResult(
        spec=spec,
        finish_rate=finish_rate,
        n_total=100,
        n_finished_ok=int(100 * finish_rate),
        n_finished_late=0,
        n_dropped=0,
        n_unserved=0,
        utilization=0.5,
        makespan_ms=1.0,
        p99_alone_ms=1.0,
        latency_p50_ms=1.0,
        latency_p99_ms=1.0,
        n_decisions=1,
        sched_time_ms=0.0,
        sched_us_per_request=0.0,
        wall_s=0.0,
    )


def test_dominance_claim_passes_and_fails_on_seed_means():
    # Seed-averaged: orloj mean 0.85 vs nexus mean 0.80 -> pass even though
    # one seed loses.
    results = [
        _fake("orloj", 0.80, seed=0),
        _fake("orloj", 0.90, seed=1),
        _fake("nexus", 0.82, seed=0),
        _fake("nexus", 0.78, seed=1),
    ]
    c = claim_tight_slo_dominance(results)
    assert c.passed and c.margin == pytest.approx(0.05)

    c2 = claim_tight_slo_dominance(results + [_fake("clipper", 0.95)])
    assert not c2.passed and c2.margin == pytest.approx(-0.10)


def test_dominance_claim_ignores_loose_slo_and_static_cells():
    results = [
        _fake("orloj", 0.5, slo=1.5),
        _fake("nexus", 0.4, slo=1.5),
        # Orloj loses at slo 3.0 and on static: neither is in scope.
        _fake("orloj", 0.5, slo=3.0),
        _fake("nexus", 0.9, slo=3.0),
        _fake("orloj", 0.1, family="static"),
        _fake("nexus", 0.9, family="static"),
    ]
    assert claim_tight_slo_dominance(results).passed


def test_dominance_claim_fails_without_cells():
    assert not claim_tight_slo_dominance([_fake("orloj", 0.9)]).passed


def test_static_parity_band():
    base = [_fake("orloj", 0.50, family="static"), _fake("nexus", 0.55, family="static")]
    c = claim_static_parity(base, band=0.08)
    assert c.passed and c.margin == pytest.approx(0.03)
    c2 = claim_static_parity(
        base + [_fake("clipper", 0.60, family="static")], band=0.08
    )
    assert not c2.passed and c2.margin == pytest.approx(-0.02)


def test_monotonicity_slack():
    ok = [_fake("orloj", 0.80, slo=1.5), _fake("orloj", 0.78, slo=3.0)]
    assert claim_slo_monotonicity(ok, slack=0.05).passed
    bad = [_fake("orloj", 0.80, slo=1.5), _fake("orloj", 0.70, slo=3.0)]
    c = claim_slo_monotonicity(bad, slack=0.05)
    assert not c.passed and c.margin == pytest.approx(-0.05)


def _fake_pool(
    policy: str, finish_rate: float, seed: int = 0, hetero: bool = True
) -> ExperimentResult:
    r = _fake("orloj", finish_rate, slo=3.0, seed=seed)
    spec = ExperimentSpec(
        **{
            **r.spec.to_dict(),
            "n_workers": 4,
            "policy": policy,
            "hetero": hetero,
        }
    )
    return ExperimentResult(**{**r.to_dict(), "spec": spec})


def test_scaleout_claim_passes_and_fails_on_seed_means():
    ok = [
        _fake_pool("jsq_work", 0.90, seed=0),
        _fake_pool("jsq_work", 0.94, seed=1),
        _fake_pool("round_robin", 0.88, seed=0),
        _fake_pool("round_robin", 0.90, seed=1),
    ]
    c = claim_scaleout_dispatch(ok, slack=0.02)
    assert c.passed and c.margin == pytest.approx(0.05)

    bad = [_fake_pool("jsq_work", 0.80), _fake_pool("round_robin", 0.90)]
    c2 = claim_scaleout_dispatch(bad, slack=0.02)
    assert not c2.passed and c2.margin == pytest.approx(-0.08)


def test_scaleout_claim_separates_pool_shapes_and_needs_both_policies():
    # hetero and homogeneous pools are distinct cells, not averaged
    mixed = [
        _fake_pool("jsq_work", 0.90, hetero=True),
        _fake_pool("round_robin", 0.95, hetero=False),
    ]
    assert not claim_scaleout_dispatch(mixed).passed  # no cell has both

    # single-worker cells never feed the claim
    assert not claim_scaleout_dispatch([_fake("orloj", 0.9)]).passed


def test_evaluate_claims_states_scaleout_only_with_pool_cells():
    # single-slo dynamic cells: only the tight-slo claim has a domain
    # (no static cells, no multi-slo series — per-domain scoping, §7/§11)
    solo = [_fake("orloj", 0.9), _fake("nexus", 0.8)]
    assert [c.name for c in evaluate_claims(solo)] == ["tight-slo-dominance"]
    with_static = solo + [
        _fake("orloj", 0.9, family="static"),
        _fake("nexus", 0.89, family="static"),
    ]
    assert "static-parity" in {c.name for c in evaluate_claims(with_static)}
    pooled = solo + [
        _fake_pool("jsq_work", 0.9),
        _fake_pool("round_robin", 0.85),
    ]
    names = [c.name for c in evaluate_claims(pooled)]
    assert names[-1] == "scale-out-dispatch"


def test_claim_result_round_trips_via_artifact(tmp_path):
    results = [_fake("orloj", 0.9), _fake("nexus", 0.8)]
    claims = evaluate_claims(results)
    path = tmp_path / "BENCH_eval.json"
    doc = write_artifact(str(path), results, grid="unit", claims=claims)
    assert doc["passed"] == all(c.passed for c in claims)

    loaded, results2 = read_artifact(str(path))
    assert [ExperimentResult.from_dict(d) for d in loaded["results"]] == results2
    assert results2 == results
    assert [ClaimResult.from_dict(d) for d in loaded["claims"]] == claims


def test_write_artifact_merges_extra_sections(tmp_path):
    path = tmp_path / "BENCH_eval.json"
    doc = write_artifact(
        str(path),
        [_fake("orloj", 0.9)],
        grid="unit",
        extra={"engine_drift": {"n_cells": 1}},
    )
    assert doc["engine_drift"] == {"n_cells": 1}
    loaded, _ = read_artifact(str(path))
    assert loaded["engine_drift"] == {"n_cells": 1}
    with pytest.raises(ValueError, match="reserved artifact keys"):
        write_artifact(
            str(path), [_fake("orloj", 0.9)], extra={"results": "clobbered"}
        )


# -- sched-throughput CI gate ------------------------------------------------


def _sched_doc(rate: float, nb_us: float) -> dict:
    return {
        "benchmark": "sched_throughput",
        "sizes": {
            "100": {
                "baseline_arrivals_per_s": 1000.0,
                "vectorized_arrivals_per_s": rate,
                "speedup": 10.0,
                "next_batch_us": nb_us,
            }
        },
    }


def test_sched_gate_ratio_band():
    from repro.eval.sched_gate import check

    base = _sched_doc(30_000.0, 300.0)
    assert check(base, _sched_doc(29_000.0, 310.0)) == []
    # runner noise within the 2.5x band passes
    assert check(base, _sched_doc(13_000.0, 700.0)) == []
    # >2.5x throughput regression fails
    fails = check(base, _sched_doc(11_000.0, 300.0))
    assert len(fails) == 1 and "throughput" in fails[0]
    # >2.5x next_batch latency regression fails
    fails = check(base, _sched_doc(30_000.0, 800.0))
    assert len(fails) == 1 and "next_batch" in fails[0]
    # a size missing from the fresh artifact fails loudly
    assert check(base, {"sizes": {}}) == ["n=100: missing from the fresh artifact"]
    assert check({"sizes": {}}, base) == ["baseline artifact has no 'sizes' section"]


def test_sched_gate_cli_on_committed_artifact(capsys):
    """The committed BENCH_sched.json must pass against itself."""
    import pathlib

    from repro.eval.sched_gate import main

    artifact = str(pathlib.Path(__file__).resolve().parents[1] / "BENCH_sched.json")
    rc = main(["--baseline", artifact, "--fresh", artifact])
    assert rc == 0
    assert "PASS" in capsys.readouterr().out


# -- CLI ---------------------------------------------------------------------


def test_cli_tiny_grid_writes_artifact(tmp_path, monkeypatch):
    from repro.eval.run import main

    out = tmp_path / "BENCH_eval.json"
    rc = main(["--grid", "tiny", "--jobs", "1", "--out", str(out), "--no-gate"])
    assert rc == 0
    doc, results = read_artifact(str(out))
    assert doc["grid"] == "tiny"
    assert len(results) == len(tiny())
    assert "claims" in doc


# -- fleet grids and their claims (DESIGN.md §10) ------------------------------

from dataclasses import replace as _replace  # noqa: E402

from repro.eval.claims import (  # noqa: E402
    claim_array_scalar_equivalence,
    claim_cluster_wall_budget,
    claim_homog_pool_parity,
    claim_p2c_dispatch,
)
from repro.eval.grid import cluster_fleet, cluster_smoke  # noqa: E402
from repro.eval.spec import TIMING_FIELDS  # noqa: E402


def _cell(finish_rate: float = 1.0, *, wall_s: float = 0.0, **spec_kw):
    """An ExperimentResult with an arbitrary spec — fleet-claim fixtures."""
    spec_kw.setdefault("workload", "bimodal")
    spec_kw.setdefault("slo_scale", 3.0)
    spec_kw.setdefault("n_requests", 100)
    spec = ExperimentSpec(**spec_kw)
    n_ok = int(spec.n_requests * finish_rate)
    return ExperimentResult(
        spec=spec,
        finish_rate=finish_rate,
        n_total=spec.n_requests,
        n_finished_ok=n_ok,
        n_finished_late=0,
        n_dropped=0,
        n_unserved=spec.n_requests - n_ok,
        utilization=0.5,
        makespan_ms=1.0,
        p99_alone_ms=1.0,
        latency_p50_ms=1.0,
        latency_p99_ms=1.0,
        n_decisions=1,
        sched_time_ms=0.0,
        sched_us_per_request=0.0,
        wall_s=wall_s,
    )


def test_cluster_grids_are_well_formed():
    for name in ("cluster", "cluster-smoke"):
        assert name in GRIDS
    fleet, smoke = cluster_fleet(), cluster_smoke()
    assert {s.tag for s in smoke} <= {s.tag for s in fleet}
    big = [s for s in fleet if s.n_requests == 100_000]
    assert big and all(s.wall_budget_s > 0 and s.engine == "array" for s in big)
    assert {s.n_workers for s in big} == {100, 1000}
    # every equivalence pair really is paired: same spec up to engine
    pairs = [s for s in fleet if s.n_requests < 100_000]
    keys = {
        json.dumps({**s.to_dict(), "engine": None, "tag": ""}, sort_keys=True)
        for s in pairs
    }
    assert len(pairs) == 2 * len(keys)
    assert {s.engine for s in pairs} == {"scalar", "array"}


def test_p2c_claim_and_homog_parity():
    def pool_cells(policy, rate_hetero, rate_homog):
        return [
            _cell(rate_hetero, n_workers=4, policy=policy, hetero=True),
            _cell(rate_homog, n_workers=4, policy=policy, utilization=0.9),
        ]

    results = (
        pool_cells("round_robin", 0.90, 0.98)
        + pool_cells("p2c", 0.93, 0.98)
        + pool_cells("jsq_work", 0.95, 0.98)
    )
    assert claim_p2c_dispatch(results).passed
    assert claim_homog_pool_parity(results).passed
    # p2c trailing rr beyond the slack flips the ordering claim
    bad = pool_cells("round_robin", 0.95, 0.98) + pool_cells("p2c", 0.90, 0.98)
    assert not claim_p2c_dispatch(bad).passed
    # a policy falling out of the homog band is a broken dispatcher
    spread = pool_cells("p2c", 0.93, 0.98) + pool_cells("jsq_work", 0.95, 0.90)
    assert not claim_homog_pool_parity(spread).passed
    # hetero pools are exempt from the parity band (jsq SHOULD win there)
    assert claim_homog_pool_parity(
        [_cell(0.95, n_workers=4, policy="jsq_work", hetero=True),
         _cell(0.80, n_workers=4, policy="round_robin", hetero=True)]
    ).cells == ("no homogeneous pool cells with >= 2 policies",)


def test_wall_budget_claim():
    ok = _cell(1.0, wall_s=80.0, wall_budget_s=300.0, engine="array")
    over = _cell(1.0, wall_s=301.0, wall_budget_s=300.0, engine="array")
    c = claim_cluster_wall_budget([ok])
    assert c.passed and c.margin == pytest.approx((300 - 80) / 300)
    assert not claim_cluster_wall_budget([ok, over]).passed
    assert not claim_cluster_wall_budget([_cell(1.0)]).passed  # empty domain


def test_array_scalar_equivalence_claim():
    a = _cell(1.0, engine="scalar", seed=3)
    b = _cell(1.0, engine="array", seed=3)
    c = claim_array_scalar_equivalence([a, b])
    assert c.passed and c.margin == 0.0
    # any outcome divergence fails, and the margin scales with the gap
    b_bad = _replace(b, n_finished_ok=95, n_unserved=5, finish_rate=0.95)
    c2 = claim_array_scalar_equivalence([a, b_bad])
    assert not c2.passed and c2.margin == pytest.approx(-0.10)
    # unpaired cells are not an equivalence statement
    assert not claim_array_scalar_equivalence([a]).passed


def test_evaluate_claims_scopes_to_the_grid():
    """A fleet-only result set is gated on budget + equivalence, never on
    the paper claims it has no cells for — and vice versa."""
    fleet = [
        _cell(1.0, wall_s=10.0, wall_budget_s=300.0, engine="array",
              n_workers=100, n_pools=10, policy="p2c", n_requests=1000),
        _cell(1.0, engine="scalar", n_workers=16, n_pools=4, policy="p2c",
              seed=13),
        _cell(1.0, engine="array", n_workers=16, n_pools=4, policy="p2c",
              seed=13),
    ]
    names = {c.name for c in evaluate_claims(fleet)}
    assert names == {"cluster-wall-budget", "array-scalar-equivalence"}

    paper = [
        _fake("orloj", 0.9, slo=1.5),
        _fake("nexus", 0.8, slo=1.5),
        _fake("orloj", 0.95, slo=3.0),
        _fake("nexus", 0.85, slo=3.0),
    ]
    # dynamic-only multi-slo set: tight-slo + monotonicity have domains,
    # static-parity has no static cells and is not stated
    names = {c.name for c in evaluate_claims(paper)}
    assert names == {"tight-slo-dominance", "slo-monotonicity"}


@pytest.mark.slow
def test_small_grid_array_engine_bitwise_equivalent():
    """The ISSUE-level correctness contract: every small-grid cell replayed
    on the array engine reproduces the scalar loop's outcome fields
    exactly (timing fields excluded by definition)."""
    specs = small()
    scalar = run_specs(specs, jobs=0)
    arrayr = run_specs([_replace(s, engine="array") for s in specs], jobs=0)
    for a, b in zip(scalar, arrayr):
        da, db = a.stable_dict(), b.stable_dict()
        da["spec"].pop("engine"), db["spec"].pop("engine")
        for f in TIMING_FIELDS:
            da.pop(f, None), db.pop(f, None)
        assert da == db, a.spec.tag or a.spec
