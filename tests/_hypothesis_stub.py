"""Fallback shims for test modules that use hypothesis property tests.

In an environment without ``hypothesis`` the property-test *modules* must
still collect and run their example-based tests; only the ``@given`` tests
should be skipped.  Test files import via::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st

With the stub, ``@given(...)`` replaces the property test with a skipped
placeholder and strategy constructors are inert.
"""

from __future__ import annotations

import pytest


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


def given(*_args, **_kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis is not installed")
        def _skipped():
            pass  # pragma: no cover

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco


class _InertStrategies:
    """Any ``st.xyz(...)`` call returns None — only ever passed to the
    stubbed ``given``/strategy combinators, never executed."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _InertStrategies()
