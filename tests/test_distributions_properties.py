"""Property tests for the conditional-tail machinery of
``EmpiricalDistribution`` (``repro.core.distributions``): the per-step
remaining-length view token-level scheduling leans on (DESIGN.md §12).

Three contracts, property-tested across random mixtures when hypothesis
is installed (example-based pins always run):

- ``E[X | X > t] = t + expected_remaining(t)`` is nondecreasing in the
  conditioning point ``t`` — true for *any* distribution, even though
  ``expected_remaining`` itself is not monotone for multimodal mixtures;
- ``conditional_tail(t)`` is consistent with direct truncation: its CDF
  is ``(F(x) − F(t)) / (1 − F(t))`` and its mean is
  ``t + expected_remaining(t)`` (both exact under the piecewise-linear
  CDF, so the comparison is tight, not approximate);
- EOS-histogram edge cases: mass in the first bin at 0, a single-knot
  delta, and conditioning at/beyond the end of support stay loud or
  exact rather than silently degenerate.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.distributions import EmpiricalDistribution

RTOL = 1e-9


def _mixture(samples, n_bins=8):
    return EmpiricalDistribution.from_samples(samples, n_bins=n_bins)


def _bimodal():
    # two well-separated peaks: expected_remaining is non-monotone here
    # (it jumps up after the first peak drains), the conditioned mean is not
    return EmpiricalDistribution(
        np.array([1.0, 2.0, 40.0, 50.0]), np.array([0.7, 0.0, 0.3])
    )


# ------------------------------------------------------- example-based pins
def test_conditional_mean_monotone_even_when_remaining_is_not():
    d = _bimodal()
    ts = np.linspace(d.lo, d.hi, 200, endpoint=False)[1:]
    er = np.array([d.expected_remaining(float(t)) for t in ts])
    cond_mean = ts + er
    assert np.all(np.diff(cond_mean) >= -RTOL)
    # sanity: the raw remaining time itself genuinely dips and recovers,
    # so the monotonicity above is not vacuous
    assert np.min(np.diff(er)) < -1e-6 < 1e-6 < np.max(np.diff(er))


def test_conditional_tail_matches_direct_truncation():
    d = _mixture(np.concatenate([
        np.linspace(1.0, 5.0, 40), np.linspace(20.0, 30.0, 20)
    ]))
    for t in (1.5, 4.0, 12.0, 25.0):
        tail = d.conditional_tail(t)
        # support starts exactly at the conditioning point
        assert tail.lo == pytest.approx(t)
        assert tail.hi == pytest.approx(d.hi)
        # CDF identity: F_tail(x) = (F(x) - F(t)) / (1 - F(t))
        xs = np.linspace(t, d.hi, 50)
        ft = float(d.cdf(t))
        np.testing.assert_allclose(
            tail.cdf(xs), (d.cdf(xs) - ft) / (1.0 - ft), atol=1e-12
        )
        # mean identity: E[X | X > t] - t = expected_remaining(t), exact
        assert tail.mean() - t == pytest.approx(
            d.expected_remaining(t), rel=RTOL
        )


def test_mass_at_zero_eos_histogram():
    # an EOS histogram whose first bin starts at 0 with most of the mass:
    # the "already likely done" shape continuous batching produces
    d = EmpiricalDistribution(
        np.array([0.0, 0.5, 4.0]), np.array([0.8, 0.2])
    )
    assert d.lo == 0.0
    assert d.expected_remaining(0.0) > 0.0
    # conditioning inside the zero bin renormalizes, not crashes
    tail = d.conditional_tail(0.25)
    assert tail.lo == pytest.approx(0.25)
    assert tail.mean() - 0.25 == pytest.approx(
        d.expected_remaining(0.25), rel=RTOL
    )
    # conditioning at or below the support start returns the identity
    assert d.conditional_tail(0.0) is d
    assert d.conditional_tail(-1.0) is d


def test_single_knot_delta():
    d = EmpiricalDistribution.delta(5.0)
    assert d.conditional_tail(0.0) is d
    t = d.lo + 0.25 * (d.hi - d.lo)
    tail = d.conditional_tail(t)
    assert tail.lo == pytest.approx(t)
    assert tail.mean() - t == pytest.approx(d.expected_remaining(t), rel=RTOL)
    # a delta's remaining time collapses to ~0 at the scale of its width
    assert d.expected_remaining(t) <= (d.hi - d.lo)


def test_beyond_support_is_loud_or_zero():
    d = _mixture(np.linspace(1.0, 10.0, 30))
    # expected_remaining degrades gracefully: "expected to finish now"
    assert d.expected_remaining(d.hi) == 0.0
    assert d.expected_remaining(d.hi + 5.0) == 0.0
    # conditional_tail cannot represent an empty distribution: loud
    with pytest.raises(ValueError, match="no mass above"):
        d.conditional_tail(d.hi)
    with pytest.raises(ValueError, match="no mass above"):
        d.conditional_tail(d.hi + 5.0)


# ----------------------------------------------------------- property tests
def _dist_and_t(samples, n_bins, frac):
    d = _mixture(samples, n_bins=n_bins)
    t = d.lo + frac * (d.hi - d.lo)
    return d, float(t)


@settings(max_examples=60, deadline=None)
@given(
    samples=st.lists(
        st.floats(min_value=0.0, max_value=500.0,
                  allow_nan=False, allow_infinity=False),
        min_size=2, max_size=60,
    ),
    n_bins=st.integers(min_value=1, max_value=24),
    fa=st.floats(min_value=0.001, max_value=0.999),
    fb=st.floats(min_value=0.001, max_value=0.999),
)
def test_property_conditional_mean_monotone(samples, n_bins, fa, fb):
    d = _mixture(samples, n_bins=n_bins)
    ta, tb = sorted(
        d.lo + f * (d.hi - d.lo) for f in (fa, fb)
    )
    ga = ta + d.expected_remaining(ta)
    gb = tb + d.expected_remaining(tb)
    assert gb >= ga - RTOL * max(1.0, abs(ga))


@settings(max_examples=60, deadline=None)
@given(
    samples=st.lists(
        st.floats(min_value=0.0, max_value=500.0,
                  allow_nan=False, allow_infinity=False),
        min_size=2, max_size=60,
    ),
    n_bins=st.integers(min_value=1, max_value=24),
    frac=st.floats(min_value=0.001, max_value=0.98),
)
def test_property_tail_consistent_with_truncation(samples, n_bins, frac):
    d, t = _dist_and_t(samples, n_bins, frac)
    try:
        tail = d.conditional_tail(t)
    except ValueError:
        # all mass at/below t (histograms can leave empty upper bins):
        # the mean view must agree that nothing remains
        assert d.expected_remaining(t) == 0.0
        return
    if t <= d.lo:
        assert tail is d
        return
    assert tail.lo == pytest.approx(t)
    assert tail.mean() - t == pytest.approx(
        d.expected_remaining(t), rel=1e-7, abs=1e-9
    )
    ft = float(d.cdf(t))
    xs = np.linspace(t, d.hi, 20)
    np.testing.assert_allclose(
        tail.cdf(xs), (d.cdf(xs) - ft) / (1.0 - ft), atol=1e-9
    )
