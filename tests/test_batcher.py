"""Tests for padded-batch construction and bucket selection."""

import numpy as np
import pytest

from repro.core import Request
from repro.serving.batcher import (
    PaddedBatch,
    bucket_for,
    make_padded_batch,
    padded_batch_size,
)

BUCKETS = (16, 32, 64)


def _req(n_tokens: int) -> Request:
    return Request(
        app_id="a",
        release=0.0,
        slo=100.0,
        true_time=1.0,
        payload=np.arange(1, n_tokens + 1, dtype=np.int32),
    )


# ------------------------------------------------------------ bucket_for
def test_bucket_for_edges():
    assert bucket_for(0, BUCKETS) == 16
    assert bucket_for(1, BUCKETS) == 16
    assert bucket_for(16, BUCKETS) == 16  # exact boundary stays in bucket
    assert bucket_for(17, BUCKETS) == 32
    assert bucket_for(64, BUCKETS) == 64


def test_bucket_for_overflow_modes():
    assert bucket_for(65, BUCKETS) == 64  # clamp (default)
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        bucket_for(65, BUCKETS, clamp=False)
    with pytest.raises(ValueError, match="negative"):
        bucket_for(-1, BUCKETS)


# ------------------------------------------------------ make_padded_batch
def test_padded_batch_pads_to_batch_max_bucket():
    pb = make_padded_batch([_req(3), _req(20)], BUCKETS)
    assert pb.tokens.shape == (2, 32)
    assert pb.labels_bucket == 32
    np.testing.assert_array_equal(pb.lengths, [3, 20])
    np.testing.assert_array_equal(pb.tokens[0, :3], [1, 2, 3])
    assert (pb.tokens[0, 3:] == 0).all()  # zero padding, nothing else


def test_padded_batch_rejects_over_bucket_payload_by_default():
    """Payloads longer than the largest bucket used to be truncated
    silently; now they are an explicit error."""
    with pytest.raises(ValueError, match="exceed the largest bucket"):
        make_padded_batch([_req(8), _req(70)], BUCKETS)


def test_padded_batch_explicit_clamp():
    pb = make_padded_batch([_req(8), _req(70)], BUCKETS, overflow="clamp")
    assert pb.tokens.shape == (2, 64)
    # the clamped request keeps its first 64 tokens and an honest length
    np.testing.assert_array_equal(pb.lengths, [8, 64])
    np.testing.assert_array_equal(pb.tokens[1], np.arange(1, 65))


def test_padded_batch_bad_overflow_mode():
    with pytest.raises(ValueError, match="overflow must be"):
        make_padded_batch([_req(4)], BUCKETS, overflow="truncate")


# ------------------------------------------------------- empty inputs
def test_padded_batch_empty_requests_is_explicit_error():
    """Regression: an empty request list used to die inside numpy with an
    opaque 'zero-size array to reduction' ValueError."""
    with pytest.raises(ValueError, match="empty request list"):
        make_padded_batch([], BUCKETS)


def test_padded_batch_empty_buckets_is_explicit_error():
    with pytest.raises(ValueError, match="buckets is empty"):
        make_padded_batch([_req(4)], ())


def test_bucket_for_empty_buckets_is_explicit_error():
    """Regression: used to raise IndexError on buckets[-1]."""
    with pytest.raises(ValueError, match="buckets is empty"):
        bucket_for(5, ())


def test_padded_batch_size_empty_sizes_is_explicit_error():
    """Regression: silently returned k for empty batch_sizes."""
    with pytest.raises(ValueError, match="batch_sizes is empty"):
        padded_batch_size(3, ())


# --------------------------------------------------- batch-dim padding
def test_padded_batch_size_next_supported():
    """Fast-lane coverage of the batch-dimension bucketing the real
    executor uses (the slow test asserts _run reports it)."""
    sizes = (1, 2, 4, 8)
    assert padded_batch_size(1, sizes) == 1
    assert padded_batch_size(3, sizes) == 4
    assert padded_batch_size(8, sizes) == 8
    assert padded_batch_size(9, sizes) == 9  # beyond the largest: as-is
