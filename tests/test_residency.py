"""Tests for the multi-model serving tier (``repro.serving.residency``,
DESIGN.md §13): plan/profile validation, LRU vs cost-aware eviction
semantics, the Zipf model-assignment stream, the shared-queue multi-model
scheduler, engine bit-identity under an active ResidencyPlan, the
residency dispatch policy, and the loud seams at every unsupported
feature crossing (faults, decode, baselines, engine substrate)."""

import pytest

from repro.core import (
    BatchLatencyModel,
    ModelExecutor,
    MultiModelOrlojScheduler,
    Worker,
    run_event_loop,
)
from repro.core.request import Request
from repro.serving import FaultPlan, ResidencyPlan
from repro.serving.cluster import run_fleet
from repro.serving.residency import (
    DEFAULT_ROSTER,
    EVICT_MS,
    LOAD_FIXED_MS,
    PCIE_BYTES_PER_MS,
    ModelProfile,
    latency_scales,
    model_roster,
    zoo_profile,
)
from repro.serving.trace import TraceConfig, generate_requests, generate_token_requests
from repro.serving.workload import bimodal, zipf_weights

LM = BatchLatencyModel(c0=25.0, c1=1.0)

_COUNT_FIELDS = (
    "n_total",
    "n_finished_ok",
    "n_finished_late",
    "n_dropped",
    "n_unserved",
    "n_batches",
    "n_model_loads",
    "n_model_evicts",
)


def _tiny_plan(worker_mem=2.0, policy="lru", load=(10.0, 10.0, 10.0)):
    """Synthetic 1-byte models A/B/C so eviction order is the only variable."""
    profiles = tuple(
        ModelProfile(model_id=m, nbytes=1.0, load_ms=ld)
        for m, ld in zip("ABC", load)
    )
    return ResidencyPlan(worker_mem=worker_mem, profiles=profiles, policy=policy)


def _mm_trace(n_models=2, util=1.2, n=300, seed=11, slo=2.0):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=slo,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=util,
                        n_models=n_models),
    )


def _mm_workers(rs, n_models, k=1):
    scales = latency_scales(n_models)
    base = rs.initial_dists()
    dists = {
        m: {a: d.affine(s, 0.0) for a, d in base.items()}
        for m, s in zip(model_roster(n_models), scales)
    }
    return [
        Worker(MultiModelOrlojScheduler(LM, dists), ModelExecutor(LM))
        for _ in range(k)
    ]


# ------------------------------------------------------------ roster / zoo
def test_model_roster_and_scales():
    assert model_roster(1) == ("olmo_1b",)
    assert model_roster(4) == DEFAULT_ROSTER[:4]
    assert latency_scales(4) == (1.0, 1.25, 1.5, 1.75)
    with pytest.raises(ValueError):
        model_roster(0)
    with pytest.raises(ValueError):
        model_roster(len(DEFAULT_ROSTER) + 1)


def test_zipf_weights_shape():
    w = zipf_weights(4, 1.1)
    assert w.shape == (4,)
    assert w.sum() == pytest.approx(1.0)
    assert all(a > b for a, b in zip(w, w[1:]))  # rank 0 most popular
    # higher skew concentrates more mass on the head
    assert zipf_weights(4, 2.0)[0] > w[0]


def test_zoo_profile_matches_config():
    from repro.configs import get_config

    prof = zoo_profile("olmo_1b")
    nbytes = 2 * get_config("olmo_1b").n_params_estimate  # bf16
    assert prof.nbytes == float(nbytes)
    assert prof.load_ms == pytest.approx(
        nbytes / PCIE_BYTES_PER_MS + LOAD_FIXED_MS
    )
    assert prof.evict_ms == EVICT_MS
    with pytest.raises(ValueError):
        zoo_profile("not_a_model")


# ------------------------------------------------------- plan validation
def test_profile_validation():
    with pytest.raises(ValueError):
        ModelProfile(model_id="x", nbytes=0.0, load_ms=1.0)
    with pytest.raises(ValueError):
        ModelProfile(model_id="x", nbytes=1.0, load_ms=-1.0)


def test_plan_validation():
    with pytest.raises(ValueError, match="eviction policy"):
        _tiny_plan(policy="mru")
    with pytest.raises(ValueError, match="worker_mem"):
        _tiny_plan(worker_mem=0.0)
    with pytest.raises(ValueError, match="at least one model"):
        ResidencyPlan(worker_mem=1.0, profiles=())
    dup = ModelProfile(model_id="A", nbytes=1.0, load_ms=1.0)
    with pytest.raises(ValueError, match="duplicate"):
        ResidencyPlan(worker_mem=1.0, profiles=(dup, dup))
    # a model larger than the budget can never be served — fail at build
    big = ModelProfile(model_id="big", nbytes=4.0, load_ms=1.0)
    with pytest.raises(ValueError, match="can never fit"):
        ResidencyPlan(worker_mem=2.0, profiles=(big,))


def test_plan_dict_round_trip():
    plan = ResidencyPlan.from_zoo(model_roster(3), worker_mem=2**32,
                                  policy="cost_aware")
    assert ResidencyPlan.from_dict(plan.to_dict()) == plan
    # unknown keys (future knobs in old artifacts) are ignored, not fatal
    d = plan.to_dict()
    d["not_a_knob"] = 7
    assert ResidencyPlan.from_dict(d) == plan


# ------------------------------------------------------ acquire semantics
def test_acquire_hit_miss_and_lru_order():
    state = _tiny_plan(worker_mem=2.0).start(1)
    assert state.acquire(0, "A", 0.0) == pytest.approx(10.0)  # cold load
    assert state.acquire(0, "B", 1.0) == pytest.approx(10.0)
    assert state.acquire(0, "A", 2.0) == 0.0  # hit, and A becomes MRU
    # cache full: C evicts the LRU model, which is now B (A was re-touched)
    assert state.acquire(0, "C", 3.0) == pytest.approx(EVICT_MS + 10.0)
    assert state.resident(0, "A") and state.resident(0, "C")
    assert not state.resident(0, "B")
    assert (state.n_loads, state.n_evicts, state.n_hits) == (3, 1, 1)
    assert state.load_ms_total == pytest.approx(30.0 + EVICT_MS)


def test_acquire_evicts_until_model_fits():
    # capacity 3, three 1-byte residents, then a 3-byte arrival: every
    # resident must go, and the stall charges each eviction plus the load
    profiles = tuple(
        ModelProfile(model_id=m, nbytes=1.0, load_ms=5.0) for m in "ABC"
    ) + (ModelProfile(model_id="D", nbytes=3.0, load_ms=20.0),)
    state = ResidencyPlan(worker_mem=3.0, profiles=profiles).start(1)
    for t, m in enumerate("ABC"):
        state.acquire(0, m, float(t))
    stall = state.acquire(0, "D", 3.0)
    assert stall == pytest.approx(3 * EVICT_MS + 20.0)
    assert state.n_evicts == 3
    assert [m for m in "ABC" if state.resident(0, m)] == []


def test_cost_aware_evicts_smallest_reload_risk():
    # A is expensive to reload and hot; B cheap and cold.  LRU would evict
    # A (least recently touched after the B touch below); cost_aware keeps
    # it and sacrifices B.
    for policy, victim in (("lru", "A"), ("cost_aware", "B")):
        state = _tiny_plan(policy=policy, load=(50.0, 1.0, 10.0)).start(1)
        state.acquire(0, "A", 0.0)
        for t in range(1, 5):  # demand signal: A is hot
            state.acquire(0, "A", float(t))
        state.acquire(0, "B", 5.0)  # B now most recent
        state.acquire(0, "C", 6.0)  # full: someone must go
        assert not state.resident(0, victim), policy
        assert state.resident(0, "C")


def test_acquire_is_per_worker_and_deterministic():
    plan = _tiny_plan(worker_mem=1.0)
    state = plan.start(2)
    state.acquire(0, "A", 0.0)
    assert state.resident(0, "A") and not state.resident(1, "A")
    state.acquire(1, "B", 0.0)
    assert state.n_loads == 2 and state.n_evicts == 0  # separate budgets
    # identical call sequences on fresh states replay identically
    seq = [(0, "A"), (1, "B"), (0, "B"), (1, "A"), (0, "A")]
    runs = []
    for _ in range(2):
        s = plan.start(2)
        runs.append([s.acquire(w, m, float(i)) for i, (w, m) in enumerate(seq)])
    assert runs[0] == runs[1]


def test_acquire_unknown_model_and_bad_worker_count():
    plan = _tiny_plan()
    with pytest.raises(ValueError, match="no profile"):
        plan.start(1).acquire(0, "Z", 0.0)
    with pytest.raises(ValueError, match="n_workers"):
        plan.start(0)


# -------------------------------------------------------- trace assignment
def test_assign_models_preserves_base_trace():
    base = _mm_trace(n_models=1, seed=11)
    mm = _mm_trace(n_models=4, seed=11)
    scales = dict(zip(model_roster(4), latency_scales(4)))
    assert all(r.model_id is None for r in base.requests)
    for b, m in zip(base.requests, mm.requests):
        assert m.model_id in scales
        # arrivals, SLOs, app ids are byte-identical; only the per-model
        # execution multiplier touches true_time
        assert (b.app_id, b.release, b.slo) == (m.app_id, m.release, m.slo)
        assert m.true_time == pytest.approx(b.true_time * scales[m.model_id])
    # rank 0 is the Zipf head: strictly the most popular assignment
    counts = {m: 0 for m in scales}
    for r in mm.requests:
        counts[r.model_id] += 1
    head = model_roster(4)[0]
    assert all(counts[head] > c for m, c in counts.items() if m != head)


def test_assign_models_changes_fingerprint_only_when_on():
    base, mm = _mm_trace(n_models=1), _mm_trace(n_models=4)
    inert = generate_requests(
        bimodal(1.0), LM, slo_scale=2.0,
        cfg=TraceConfig(n_requests=300, seed=11, utilization=1.2),
    )
    assert base.fingerprint() == inert.fingerprint()
    assert mm.fingerprint() != base.fingerprint()
    # skew is part of the stream: a different skew reassigns models
    other = generate_requests(
        bimodal(1.0), LM, slo_scale=2.0,
        cfg=TraceConfig(n_requests=300, seed=11, utilization=1.2,
                        n_models=4, model_skew=3.0),
    )
    assert other.fingerprint() != mm.fingerprint()


def test_token_traces_reject_multi_model():
    with pytest.raises(ValueError, match="multi-model"):
        generate_token_requests(
            bimodal(1.0), d0=5.0, d1=0.5, prefill_per_token=0.02,
            ttft_slo_ms=200.0, tpot_slo_ms=20.0,
            cfg=TraceConfig(n_requests=10, n_models=2),
        )


# --------------------------------------------------- multi-model scheduler
def test_multi_model_scheduler_routes_and_stamps():
    rs = _mm_trace(n_models=2, n=100)
    sched = _mm_workers(rs, 2)[0].scheduler
    assert sched.n_pending == 0
    sched.on_arrivals(rs.requests, 0.0)
    assert sched.n_pending == len(rs.requests)
    seen = set()
    now = 10_000.0  # far past every deadline milestone: everything is ripe
    batch, _ = sched.next_batch(now)
    while batch is not None:
        assert batch.model in model_roster(2)
        assert all(r.model_id == batch.model for r in batch.requests)
        seen.add(batch.model)
        sched.on_batch_done(batch, now, [r.true_time for r in batch.requests])
        now += 50.0
        batch, _ = sched.next_batch(now)
    assert seen == set(model_roster(2))
    assert sched.n_pending == 0


def test_multi_model_scheduler_loud_seams():
    rs = _mm_trace(n_models=2, n=10)
    sched = _mm_workers(rs, 2)[0].scheduler
    with pytest.raises(ValueError, match="at least one model"):
        MultiModelOrlojScheduler(LM, {})
    stray = Request(app_id="short", release=0.0, slo=100.0, true_time=1.0,
                    model_id="not_in_roster")
    with pytest.raises(ValueError, match="unknown model"):
        sched.on_arrival(stray, 0.0)
    unset = Request(app_id="short", release=0.0, slo=100.0, true_time=1.0)
    with pytest.raises(ValueError, match="unknown model"):
        sched.on_arrivals([unset], 0.0)


# ------------------------------------------------- event-loop integration
def test_engines_bit_identical_under_residency():
    rs = _mm_trace(n_models=2, n=300, util=1.6)
    plan = ResidencyPlan.from_zoo(model_roster(2),
                                  worker_mem=float(3 * 2**30))
    results = {}
    for engine in ("scalar", "array"):
        results[engine] = run_event_loop(
            rs.fresh(), _mm_workers(rs, 2, k=2), seed=0,
            policy="residency", engine=engine, residency=plan,
        )
    sc, ar = results["scalar"], results["array"]
    for f in _COUNT_FIELDS:
        assert getattr(sc, f) == getattr(ar, f), f
    assert sc.model_load_ms == ar.model_load_ms
    assert sc.latencies.tobytes() == ar.latencies.tobytes()
    assert sc.n_model_loads > 0  # the plan was actually exercised


def test_residency_policy_builds_affinity():
    # two workers, two models, budget fits one model per worker: the
    # residency policy settles into one-model-per-worker and stops
    # loading; round_robin keeps alternating and churns the caches.
    rs = _mm_trace(n_models=2, n=300, util=1.6)
    plan = ResidencyPlan.from_zoo(model_roster(2),
                                  worker_mem=float(3 * 2**30))
    loads = {}
    for policy in ("residency", "round_robin"):
        res = run_event_loop(
            rs.fresh(), _mm_workers(rs, 2, k=2), seed=0,
            policy=policy, engine="array", residency=plan,
        )
        loads[policy] = res.n_model_loads
    assert loads["residency"] <= 4  # ~one cold start per (worker, model)
    assert loads["round_robin"] > 5 * loads["residency"]


def test_fleet_intra_residency():
    rs = _mm_trace(n_models=2, n=300, util=1.6)
    plan = ResidencyPlan.from_zoo(model_roster(2),
                                  worker_mem=float(3 * 2**30))
    loads = {}
    for intra in ("residency", "round_robin"):
        res = run_fleet(
            rs.fresh(), _mm_workers(rs, 2, k=4), n_pools=2,
            inter="round_robin", intra=intra, seed=0, residency=plan,
        )
        loads[intra] = res.n_model_loads
    assert loads["residency"] < loads["round_robin"]


def test_residency_stall_charges_virtual_time():
    # same trace with and without the plan: the managed run's load stalls
    # must show up in the clock (makespan) and the load counters, and
    # disappear again when every model fits resident forever.
    rs = _mm_trace(n_models=2, n=200, util=1.6)
    free = run_event_loop(rs.fresh(), _mm_workers(rs, 2), seed=0)
    tight = run_event_loop(
        rs.fresh(), _mm_workers(rs, 2), seed=0,
        residency=ResidencyPlan.from_zoo(model_roster(2),
                                         worker_mem=float(3 * 2**30)),
    )
    roomy = run_event_loop(
        rs.fresh(), _mm_workers(rs, 2), seed=0,
        residency=ResidencyPlan.from_zoo(model_roster(2),
                                         worker_mem=float(64 * 2**30)),
    )
    assert free.n_model_loads == 0 and free.model_load_ms == 0.0
    assert tight.n_model_evicts > 0
    assert tight.model_load_ms > roomy.model_load_ms > 0.0
    assert roomy.n_model_loads == 2 and roomy.n_model_evicts == 0
    # cold-start churn costs SLO attainment — the §13 claim in miniature
    assert tight.n_finished_ok < roomy.n_finished_ok


# ------------------------------------------------------------- loud seams
def test_residency_rejects_active_fault_plan():
    rs = _mm_trace(n_models=2, n=20)
    plan = ResidencyPlan.from_zoo(model_roster(2), worker_mem=float(3 * 2**30))
    with pytest.raises(ValueError, match="fault"):
        run_event_loop(
            rs.fresh(), _mm_workers(rs, 2), seed=0,
            residency=plan, faults=FaultPlan(mttf_ms=1000.0),
        )


def test_runner_seams_fail_loudly():
    from repro.eval.runner import run_spec
    from repro.eval.spec import ExperimentSpec

    mm = dict(workload="bimodal", slo_scale=2.0, n_requests=20,
              n_models=2, worker_mem=float(3 * 2**30))
    with pytest.raises(ValueError, match="system='orloj' only"):
        run_spec(ExperimentSpec(**mm, system="nexus"))
    with pytest.raises(ValueError, match="sim substrate only"):
        run_spec(ExperimentSpec(**mm, substrate="engine"))
    with pytest.raises(ValueError, match="worker_mem"):
        run_spec(ExperimentSpec(**{**mm, "worker_mem": 0.0}))
    with pytest.raises(ValueError, match="multi-model"):
        run_spec(ExperimentSpec(workload="tokens", slo_scale=2.0,
                                n_requests=20, system="token_fcfs",
                                n_models=2))


def test_decode_cells_reject_fault_plans():
    # DESIGN.md §12 seam, pinned here alongside its §13 sibling: the
    # token-level decode path has no fault story either.
    from repro.eval.runner import run_spec
    from repro.eval.spec import ExperimentSpec

    with pytest.raises(ValueError, match="fault"):
        run_spec(ExperimentSpec(workload="tokens", slo_scale=2.0,
                                n_requests=20, system="token_fcfs",
                                faults={"mttf_ms": 1000.0}))


def test_single_model_run_identical_with_and_without_tier():
    """The inert-knob guarantee behind the single-model-noop claim, at the
    event-loop level: an n_models=1 replay takes zero residency branches."""
    rs = _mm_trace(n_models=1, n=200)

    def once():
        from repro.core import OrlojScheduler

        workers = [
            Worker(OrlojScheduler(LM, initial_dists=rs.initial_dists()),
                   ModelExecutor(LM))
        ]
        return run_event_loop(rs.fresh(), workers, seed=0,
                              residency=None)

    a, b = once(), once()
    assert a.n_model_loads == a.n_model_evicts == 0
    assert a.model_load_ms == 0.0
    for f in _COUNT_FIELDS:
        assert getattr(a, f) == getattr(b, f)
    assert a.latencies.tobytes() == b.latencies.tobytes()
