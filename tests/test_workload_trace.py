"""Tests for the §5.2 workload/trace generation."""

import numpy as np
import pytest

from repro.core.distributions import BatchLatencyModel
from repro.serving.trace import TraceConfig, azure_like_arrivals, generate_requests
from repro.serving.workload import (
    REAL_TASKS,
    bimodal,
    k_modal,
    lognormal_from_mean_p99,
    real_task,
    static,
    unequal_bimodal,
)

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def test_bimodal_two_apps_two_modes():
    apps = bimodal(1.0)
    assert len(apps) == 2
    rng = np.random.default_rng(0)
    s0, s1 = apps[0].sample(rng, 4000), apps[1].sample(rng, 4000)
    assert abs(s0.mean() - 60.0) < 3
    assert abs(s1.mean() - 200.0) < 3


def test_unequal_weights():
    short = unequal_bimodal("short")
    assert short[0].weight > short[1].weight
    long = unequal_bimodal("long")
    assert long[0].weight < long[1].weight


@pytest.mark.parametrize("k", [1, 3, 8])
def test_k_modal_count(k):
    assert len(k_modal(k)) == k


def test_lognormal_fit_matches_published_stats():
    for name, (mean, p99) in list(REAL_TASKS.items())[:4]:
        f = lognormal_from_mean_p99(mean, p99)
        xs = f(np.random.default_rng(0), 200_000)
        assert abs(xs.mean() - mean) / mean < 0.05, name
        # p99 within 25% (lognormal fit of two moments)
        assert abs(np.quantile(xs, 0.99) - p99) / p99 < 0.3, name


def test_real_task_mixture():
    apps = real_task("bart-cnn")
    assert len(apps) == 2


def test_generate_requests_slo_and_replay():
    rs = generate_requests(
        bimodal(1.0), LM, slo_scale=3.0, cfg=TraceConfig(n_requests=400, seed=9)
    )
    assert len(rs.requests) == 400
    # SLO = 3 × P99(alone)
    assert rs.requests[0].slo == pytest.approx(3.0 * rs.p99_alone)
    # releases sorted and non-negative
    rel = [r.release for r in rs.requests]
    assert min(rel) >= 0
    # replay: fresh() preserves everything except bookkeeping
    a, b = rs.fresh(), rs.fresh()
    assert [r.true_time for r in a] == [r.true_time for r in b]
    assert [r.release for r in a] == [r.release for r in b]
    a[0].finished = 1.0
    assert rs.requests[0].finished is None  # no aliasing


def test_utilization_scales_arrival_rate():
    lo = generate_requests(
        bimodal(1.0), LM, cfg=TraceConfig(n_requests=400, seed=1, utilization=0.4)
    )
    hi = generate_requests(
        bimodal(1.0), LM, cfg=TraceConfig(n_requests=400, seed=1, utilization=1.2)
    )
    span = lambda rs: rs.requests[-1].release - rs.requests[0].release
    assert span(lo) > 2.0 * span(hi)


def test_azure_like_arrivals_sorted_within_bucket():
    cfg = TraceConfig()
    rng = np.random.default_rng(2)
    ts = azure_like_arrivals(0.01, 500, cfg, rng)
    assert ts.shape == (500,)
    assert np.all(np.diff(ts) >= 0)
