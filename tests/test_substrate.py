"""Tests for optimizer, data pipeline, checkpointing and the MoE dispatch."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticCorpus
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.models import moe as moe_lib
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------- optim
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    p2, state = adamw_update(cfg, params, g, state)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(cosine_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


# ----------------------------------------------------------------- data
def test_corpus_shapes_and_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=16, batch_size=3, seed=5)
    b1 = SyntheticCorpus(cfg).batch()
    b2 = SyntheticCorpus(cfg).batch()
    assert b1["tokens"].shape == (3, 16)
    assert b1["labels"].shape == (3, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # same seed
    # labels are tokens shifted by one
    row = SyntheticCorpus(cfg)
    full = row.sample_row()
    assert full.shape == (17,)


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        assert latest_step(d) == 7
        like = jax.eval_shape(lambda: tree)
        got = restore_checkpoint(d, 7, like)
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(
            np.asarray(got["b"]["c"]), np.asarray(tree["b"]["c"])
        )


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        bad = {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
        with pytest.raises(ValueError):
            restore_checkpoint(d, 1, bad)


# ------------------------------------------------------------------ moe
@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (4, 1)])
def test_moe_dispatch_matches_dense_oracle(e, k):
    rng = jax.random.PRNGKey(0)
    d, ff = 32, 64
    params = moe_lib.init_moe(rng, d, ff, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    # generous capacity → no drops → must match the dense oracle exactly
    y, aux = moe_lib.moe_apply(params, x, top_k=k, capacity_factor=8.0)
    want = moe_lib.moe_ref(params, x, top_k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    """With capacity factor ≪ 1, most tokens are dropped (zero output) but
    nothing breaks and outputs stay finite."""
    rng = jax.random.PRNGKey(0)
    params = moe_lib.init_moe(rng, 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16), jnp.float32)
    y, _ = moe_lib.moe_apply(params, x, top_k=2, capacity_factor=0.1)
    assert bool(jnp.isfinite(y).all())
    dense = moe_lib.moe_ref(params, x, top_k=2)
    # some tokens lose both expert slots → exactly zero rows
    row_zero = np.asarray(jnp.all(y == 0, axis=-1))[0]
    assert row_zero.sum() > 0
    # overall mass is reduced vs. the no-drop oracle (tokens were dropped;
    # partially-dropped tokens keep only one expert's contribution)
    assert float(jnp.abs(y).mean()) < float(jnp.abs(dense).mean())


def test_moe_load_balance_loss_uniform_router():
    """A uniform router gives aux ≈ 1 (the Switch loss optimum)."""
    rng = jax.random.PRNGKey(0)
    params = moe_lib.init_moe(rng, 16, 32, 4)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 16))
    _, aux = moe_lib.moe_apply(params, x, top_k=1)
    assert 0.9 < float(aux) < 1.1
