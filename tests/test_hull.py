"""Property tests for the dynamic convex-hull priority queue (paper §4.4)."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.hull import HullQueue


def brute_argmax(entries: dict, x: float):
    if not entries:
        return None
    k = max(entries, key=lambda kk: entries[kk][0] * x + entries[kk][1])
    return k, entries[k][0] * x + entries[k][1]


def test_basic_insert_query_delete():
    q = HullQueue()
    q.insert("a", 1.0, 0.0)
    q.insert("b", -1.0, 10.0)
    # at small x, b wins (intercept); at large x, a wins (slope)
    assert q.argmax(0.1)[0] == "b"
    assert q.argmax(100.0)[0] == "a"
    q.delete("a")
    assert q.argmax(100.0)[0] == "b"
    q.delete("b")
    assert q.argmax(1.0) is None


def test_update_changes_line():
    q = HullQueue()
    q.insert(1, 1.0, 0.0)
    q.insert(2, 0.5, 0.0)
    assert q.argmax(1.0)[0] == 1
    q.update(1, 0.1, 0.0)
    assert q.argmax(1.0)[0] == 2


def test_pop_max_sequence():
    q = HullQueue()
    for i in range(10):
        q.insert(i, float(i), 0.0)
    got = [q.pop_max(1.0)[0] for _ in range(10)]
    assert got == list(range(9, -1, -1))
    assert q.pop_max(1.0) is None


def test_duplicate_insert_raises():
    q = HullQueue()
    q.insert("k", 1.0, 2.0)
    with pytest.raises(KeyError):
        q.insert("k", 3.0, 4.0)


def test_equal_slopes_keep_best_intercept():
    q = HullQueue()
    q.insert("lo", 2.0, 1.0)
    q.insert("hi", 2.0, 5.0)
    key, val = q.argmax(3.0)
    assert key == "hi" and val == pytest.approx(11.0)


@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["ins", "del", "query", "update"]),
            st.integers(0, 30),
            st.floats(-100, 100, allow_nan=False),
            st.floats(-100, 100, allow_nan=False),
        ),
        min_size=1,
        max_size=200,
    ),
    xs=st.lists(st.floats(0.01, 1e6), min_size=1, max_size=5),
)
@settings(max_examples=120, deadline=None)
def test_property_matches_bruteforce(ops, xs):
    q = HullQueue()
    ref: dict = {}
    for op, key, a, b in ops:
        if op == "ins" and key not in ref:
            q.insert(key, a, b)
            ref[key] = (a, b)
        elif op == "del" and key in ref:
            q.delete(key)
            del ref[key]
        elif op == "update" and key in ref:
            q.update(key, a, b)
            ref[key] = (a, b)
        elif op == "query":
            for x in xs:
                got = q.argmax(x)
                want = brute_argmax(ref, x)
                if want is None:
                    assert got is None
                else:
                    assert got is not None
                    # value must match the true max (keys may tie)
                    assert math.isclose(got[1], want[1], rel_tol=1e-9, abs_tol=1e-9)
    assert len(q) == len(ref)
    for x in xs:
        got, want = q.argmax(x), brute_argmax(ref, x)
        if want is None:
            assert got is None
        else:
            assert math.isclose(got[1], want[1], rel_tol=1e-9, abs_tol=1e-9)


def test_many_interleaved_ops_random():
    rng = np.random.default_rng(0)
    q = HullQueue()
    ref: dict = {}
    next_key = 0
    for step in range(5_000):
        r = rng.random()
        if r < 0.5 or not ref:
            a, b = rng.normal(size=2) * 50
            q.insert(next_key, a, b)
            ref[next_key] = (a, b)
            next_key += 1
        elif r < 0.8:
            k = int(rng.choice(list(ref)))
            q.delete(k)
            del ref[k]
        else:
            x = float(np.exp(rng.uniform(0, 10)))
            got, want = q.argmax(x), brute_argmax(ref, x)
            assert got is not None
            assert math.isclose(got[1], want[1], rel_tol=1e-9, abs_tol=1e-7)
