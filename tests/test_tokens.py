"""Token-level continuous batching: schedulers, resumable decode through
both event engines, the length-distribution tail view, the eval tier's
token cells, and the gated length-awareness claim (DESIGN.md §12)."""

import numpy as np
import pytest

from repro.core.distributions import EmpiricalDistribution
from repro.core.eventloop import (
    DecodeModelExecutor,
    ModelExecutor,
    SimResult,
    Worker,
    run_event_loop,
)
from repro.core.request import Request
from repro.core.scheduler import Batch
from repro.core.tokensched import (
    FcfsTokenScheduler,
    LengthAwareTokenScheduler,
    TokenSchedConfig,
    token_deadline,
)
from repro.eval.claims import (
    TOKEN_TIGHT_SLO_MAX,
    claim_token_length_awareness,
)
from repro.eval.runner import (
    generate_token_set,
    run_spec,
    token_sched_config,
)
from repro.eval.spec import ExperimentResult, ExperimentSpec


def _token_reqs(n=60, seed=0, mean_out=12.0, rate=0.05, ttft=200.0, tpot=10.0):
    rng = np.random.default_rng(seed)
    out = np.maximum(rng.geometric(1.0 / mean_out, size=n), 1)
    at = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [
        Request(
            app_id="a",
            release=float(t),
            slo=ttft + tpot * (float(o) - 1.0),
            true_time=float(o),
            prompt_tokens=int(rng.integers(8, 64)),
            out_tokens=int(o),
        )
        for t, o in zip(at, out)
    ]


# --------------------------------------------------------------------------
# conditional length tail (the per-step remaining-work view)
# --------------------------------------------------------------------------


def test_expected_remaining_matches_bruteforce_tail():
    rng = np.random.default_rng(3)
    xs = rng.uniform(10.0, 50.0, size=4000)
    d = EmpiricalDistribution.from_samples(xs, n_bins=16)
    # Uniform(10, 50): E[X - t | X > t] = (50 - t) / 2 exactly.
    for t in (10.0, 20.0, 35.0, 49.0):
        assert d.expected_remaining(t) == pytest.approx((50.0 - t) / 2, rel=0.08)
    # Tail exhausted -> "finishes immediately", not an error.
    assert d.expected_remaining(60.0) == 0.0
    # Below the support the conditioning is vacuous.
    assert d.expected_remaining(0.0) == pytest.approx(d.mean() - 0.0, rel=0.05)


def test_conditional_tail_renormalizes():
    d = EmpiricalDistribution.from_samples(
        np.linspace(0.0, 100.0, 2000), n_bins=10
    )
    tail = d.conditional_tail(50.0)
    assert tail.mean() == pytest.approx(75.0, rel=0.05)
    with pytest.raises(ValueError):
        d.conditional_tail(150.0)


def test_token_deadline_shape():
    cfg = TokenSchedConfig(ttft_slo_ms=100.0, tpot_slo_ms=10.0)
    assert token_deadline(cfg, 5.0, 1.0) == pytest.approx(105.0)
    assert token_deadline(cfg, 5.0, 11.0) == pytest.approx(205.0)
    # Degenerate zero-token request never gets a negative horizon.
    assert token_deadline(cfg, 5.0, 0.0) == pytest.approx(105.0)


# --------------------------------------------------------------------------
# scheduler unit behaviour
# --------------------------------------------------------------------------


def test_fcfs_admits_in_arrival_order_and_fills_free_slots():
    cfg = TokenSchedConfig(max_batch=2)
    s = FcfsTokenScheduler(cfg)
    reqs = _token_reqs(5)
    s.on_arrivals(reqs, 0.0)
    batch, _ = s.next_batch(0.0)
    assert batch.decode and [r.rid for r in batch.requests] == [
        reqs[0].rid, reqs[1].rid
    ]
    # one slot frees -> exactly the next waiter joins, in order
    joined = s.on_decode_step([reqs[0]], n_active=1, now=10.0)
    assert [r.rid for r in joined] == [reqs[2].rid]
    assert s.on_decode_step([], n_active=2, now=11.0) == []
    assert s.n_pending == 2


def test_token_schedulers_reject_atomic_batch_hook():
    for s in (FcfsTokenScheduler(), LengthAwareTokenScheduler()):
        with pytest.raises(TypeError):
            s.on_batch_done(Batch([], 0), 0.0, [])


def test_length_aware_drops_hopeless_and_admits_shortest_first():
    cfg = TokenSchedConfig(
        max_batch=4, ttft_slo_ms=50.0, tpot_slo_ms=5.0, d0=2.0, d1=0.5,
        default_len=10.0, prefill_per_token=0.0,
    )
    dists = {
        "short": EmpiricalDistribution.delta(4.0),
        "long": EmpiricalDistribution.delta(100.0),
    }
    s = LengthAwareTokenScheduler(cfg, initial_len_dists=dists)
    # long app: even alone, 100 tokens * 2.5ms = 250ms > 50 + 5*99 = 545...
    # make it hopeless via a late 'now' instead: deadline is anchored at
    # release, so a stale waiter becomes hopeless as the clock advances.
    late = Request(app_id="long", release=0.0, slo=1.0, true_time=1.0,
                   prompt_tokens=1, out_tokens=100)
    short_b = Request(app_id="short", release=400.0, slo=1.0, true_time=1.0,
                      prompt_tokens=1, out_tokens=4)
    short_a = Request(app_id="short", release=400.0, slo=1.0, true_time=1.0,
                      prompt_tokens=1, out_tokens=4)
    s.on_arrivals([late, short_b, short_a], 400.0)
    batch, _ = s.next_batch(400.0)
    # late is hopeless at now=400 (finish 400+250=650 > 0+50+5*99=545)
    assert late.dropped == 400.0 and s.n_timed_out == 1
    # both shorts admitted, rid tiebreak keeps arrival order
    assert [r.rid for r in batch.requests] == [short_b.rid, short_a.rid]


@pytest.mark.parametrize("d1,expect_join", [(0.0, True), (2.0, False)],
                         ids=["flat_step", "steep_step"])
def test_length_aware_protects_active_budget(d1, expect_join):
    """A short candidate that is feasible on its own joins under a flat
    step-time curve, but is refused when the post-join step time would
    blow the *active* request's remaining token budget (it stays queued,
    not dropped).

    Numbers: active app 'a' (delta length 10) released at 0 has decoded 2
    tokens by now=20, so its implied deadline is 0+40+3.05·9 = 67.45 and
    its remaining 8 tokens need 8 steps.  At d1=0 a step is 3 ms →
    20+24 = 44 fits; at d1=2 the k=2 step is 7 ms → 20+56 = 76 blows it.
    The candidate (delta length 2, released at 20) fits either way:
    20 + 7·2 = 34 ≤ 20+40+3.05."""
    cfg = TokenSchedConfig(
        max_batch=8, ttft_slo_ms=40.0, tpot_slo_ms=3.05, d0=3.0, d1=d1,
        prefill_per_token=0.0,
    )
    dists = {
        "a": EmpiricalDistribution.delta(10.0),
        "s": EmpiricalDistribution.delta(2.0),
    }
    s = LengthAwareTokenScheduler(cfg, initial_len_dists=dists)
    active = Request(app_id="a", release=0.0, slo=1.0, true_time=1.0,
                     prompt_tokens=1, out_tokens=10)
    active.tokens_done = 2
    s._active = [active]
    cand = Request(app_id="s", release=20.0, slo=1.0, true_time=1.0,
                   prompt_tokens=1, out_tokens=2)
    s.on_arrival(cand, 20.0)
    joined = s.on_decode_step([], n_active=1, now=20.0)
    if expect_join:
        assert [r.rid for r in joined] == [cand.rid]
    else:
        assert joined == []
        assert s.n_pending == 1 and cand.dropped is None


def test_length_aware_learns_from_eos_observations():
    cfg = TokenSchedConfig(default_len=50.0, rebuild_every=4)
    s = LengthAwareTokenScheduler(cfg)
    probe = Request(app_id="a", release=0.0, slo=1.0, true_time=1.0)
    assert s._expected_len(probe) == pytest.approx(50.0)  # default prior
    for _ in range(4):
        done = Request(app_id="a", release=0.0, slo=1.0, true_time=1.0)
        done.tokens_done = 8
        s._observe(done)
    assert s._expected_len(probe) == pytest.approx(8.0, abs=1.0)


# --------------------------------------------------------------------------
# resumable decode through the event loop
# --------------------------------------------------------------------------


def _clone(reqs):
    return [
        Request(app_id=r.app_id, release=r.release, slo=r.slo,
                true_time=r.true_time, prompt_tokens=r.prompt_tokens,
                out_tokens=r.out_tokens)
        for r in reqs
    ]


def _run(reqs, mk_sched, engine):
    return run_event_loop(
        reqs,
        [Worker(mk_sched(), DecodeModelExecutor(2.0, 0.25, 0.02))],
        engine=engine,
    )


@pytest.mark.parametrize("mk_sched", [
    lambda: FcfsTokenScheduler(TokenSchedConfig(max_batch=4)),
    lambda: LengthAwareTokenScheduler(
        TokenSchedConfig(max_batch=4, ttft_slo_ms=80.0, tpot_slo_ms=8.0)
    ),
], ids=["fcfs", "length_aware"])
def test_decode_scalar_array_bit_identical(mk_sched):
    master = _token_reqs(120, seed=5)
    runs, clones = {}, {}
    for engine in ("scalar", "array"):
        reqs = _clone(master)
        runs[engine] = _run(reqs, mk_sched, engine)
        clones[engine] = reqs
    sc, ar = runs["scalar"], runs["array"]
    for f in (
        "n_total", "n_finished_ok", "n_finished_late", "n_dropped",
        "n_unserved", "n_batches", "n_decisions", "makespan_ms",
        "worker_busy",
    ):
        assert getattr(sc, f) == getattr(ar, f), f
    for a, b in zip(clones["scalar"], clones["array"]):
        assert (a.tokens_done, a.first_token, a.started, a.finished,
                a.dropped) == (
            b.tokens_done, b.first_token, b.started, b.finished, b.dropped)
    assert sc.conserved and ar.conserved


@pytest.mark.parametrize("engine", ["scalar", "array"])
def test_decode_serves_every_token_and_stamps_first_token(engine):
    reqs = _token_reqs(40, seed=2)
    res = _run(reqs, lambda: FcfsTokenScheduler(TokenSchedConfig(max_batch=4)),
               engine)
    assert res.n_finished_ok + res.n_finished_late == 40
    for r in reqs:
        assert r.tokens_done == r.out_tokens
        assert r.first_token is not None and r.first_token <= r.finished
        # TPOT accounting needs finish strictly after the first token for
        # multi-token outputs
        if r.out_tokens > 1:
            assert r.finished > r.first_token


def test_decode_rejects_fault_plans():
    from repro.serving.faults import FaultPlan

    reqs = _token_reqs(8)
    with pytest.raises(ValueError, match="fault"):
        run_event_loop(
            reqs,
            [Worker(FcfsTokenScheduler(), DecodeModelExecutor())],
            faults=FaultPlan(mttf_ms=50.0),
        )


def test_decode_batch_requires_step_time_executor():
    """An atomic executor (no step_time) meeting a decode batch is a
    contract violation reported as an actionable TypeError."""
    from repro.core.distributions import BatchLatencyModel

    reqs = _token_reqs(8)
    atomic = ModelExecutor(BatchLatencyModel(c0=2.0, c1=0.5))
    with pytest.raises(TypeError, match="step_time"):
        run_event_loop(reqs, [Worker(FcfsTokenScheduler(), atomic)])
    # and the decode executor refuses the atomic path symmetrically
    with pytest.raises(TypeError):
        DecodeModelExecutor()(Batch(reqs[:2], 2), 0.0)


# --------------------------------------------------------------------------
# eval tier: token cells, metrics, claim
# --------------------------------------------------------------------------


def _token_spec(**kw):
    base = dict(
        workload="tokens",
        slo_scale=1.5,
        workload_params={"short_mean": 6.0, "long_mean": 24.0},
        n_requests=80,
        seed=3,
        system="token_fcfs",
        lm_c0=2.0,
        lm_c1=0.25,
        utilization=0.8,
    )
    base.update(kw)
    return ExperimentSpec(**base)


def test_token_sched_config_slo_axis():
    cfg = token_sched_config(_token_spec(slo_scale=2.0))
    # tpot = slo_scale * (d0 + d1 * reference_batch) = 2 * (2 + 0.25*8)
    assert cfg.tpot_slo_ms == pytest.approx(8.0)
    assert cfg.ttft_slo_ms == pytest.approx(64.0)
    assert cfg.d0 == 2.0 and cfg.d1 == 0.25


def test_token_set_regenerates_bit_identical():
    spec = _token_spec()
    a, b = generate_token_set(spec), generate_token_set(spec)
    assert a.fingerprint() == b.fingerprint()
    assert all(r.out_tokens >= 1 and r.prompt_tokens >= 1 for r in a.requests)


def test_run_token_spec_produces_token_metrics_and_is_deterministic():
    spec = _token_spec()
    r1, r2 = run_spec(spec), run_spec(spec)
    assert r1.n_tokens_out > 0
    assert r1.ttft_p50_ms > 0.0 and r1.tpot_p50_ms > 0.0
    assert r1.tpot_p99_ms >= r1.tpot_p50_ms
    assert r1.stable_dict() == r2.stable_dict()
    # and the aware system runs through the same entry point
    r3 = run_spec(_token_spec(system="token_orloj"))
    assert r3.n_tokens_out > 0


@pytest.mark.parametrize("kw,match", [
    (dict(substrate="engine"), "sim substrate"),
    (dict(n_workers=2), "single-worker"),
    (dict(faults={"mttf_ms": 10.0}), "fault"),
    (dict(sched_cfg={"b": 4}), "sched_cfg"),
    (dict(system="orloj"), "unknown token system"),
])
def test_run_token_spec_guards(kw, match):
    with pytest.raises(ValueError, match=match):
        run_spec(_token_spec(**kw))


def _fake_token_result(system, finish_rate, slo, seed=0):
    spec = _token_spec(system=system, slo_scale=slo, seed=seed)
    return ExperimentResult(
        spec=spec, finish_rate=finish_rate, n_total=80,
        n_finished_ok=int(80 * finish_rate), n_finished_late=0, n_dropped=0,
        n_unserved=0, utilization=0.5, makespan_ms=1.0, p99_alone_ms=1.0,
        latency_p50_ms=1.0, latency_p99_ms=1.0, n_decisions=1,
        sched_time_ms=0.0, sched_us_per_request=0.0, wall_s=0.0,
    )


def test_token_length_awareness_claim():
    tight, loose = 1.25, TOKEN_TIGHT_SLO_MAX + 1.0
    results = [
        _fake_token_result("token_orloj", 0.9, tight, seed=0),
        _fake_token_result("token_fcfs", 0.6, tight, seed=0),
        # loose-SLO cells are out of the claim's domain even when blind wins
        _fake_token_result("token_orloj", 0.5, loose, seed=0),
        _fake_token_result("token_fcfs", 0.9, loose, seed=0),
    ]
    c = claim_token_length_awareness(results)
    assert c.passed and c.margin == pytest.approx(0.3)
    # strict: a single tight-SLO loss fails the claim
    worse = [
        _fake_token_result("token_orloj", 0.59, tight, seed=0),
        _fake_token_result("token_fcfs", 0.6, tight, seed=0),
    ]
    assert not claim_token_length_awareness(worse).passed
    # no eligible cells -> explicit failure, not a vacuous pass
    assert not claim_token_length_awareness([]).passed


def test_token_grids_registered():
    from repro.eval.grid import GRIDS

    for name in ("tokens", "tokens-smoke"):
        specs = GRIDS[name]()
        assert all(s.workload == "tokens" for s in specs)
        systems = {s.system for s in specs}
        assert systems == {"token_orloj", "token_fcfs"}
        # the equivalence pairing needs both engines present
        assert {s.engine for s in specs} == {"scalar", "array"}
