"""Tests for the fault-injection tier (``repro.serving.faults``,
DESIGN.md §11): plan validation and determinism, engine bit-identity
under active plans, the fault-free no-op guarantee, admission control,
the deadline-aware retry gate, wall-budget truncation, fleet re-dispatch
and the hard conservation invariant — property-tested across engines."""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core import (
    BatchLatencyModel,
    ModelExecutor,
    OrlojScheduler,
    Worker,
    run_event_loop,
)
from repro.serving import FaultPlan, finish_probability
from repro.serving.cluster import run_fleet
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

LM = BatchLatencyModel(c0=25.0, c1=1.0)

_COUNT_FIELDS = (
    "n_total",
    "n_finished_ok",
    "n_finished_late",
    "n_dropped",
    "n_unserved",
    "n_rejected",
    "n_failed",
    "n_retried",
    "n_batches",
    "n_workers",
    "truncated",
)


def _rs(util=1.2, n=400, seed=11, slo=2.0):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=slo,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=util),
    )


def _workers(rs, k=1):
    return [
        Worker(OrlojScheduler(LM, initial_dists=rs.initial_dists()),
               ModelExecutor(LM))
        for _ in range(k)
    ]


def _assert_identical(a, b):
    for f in _COUNT_FIELDS:
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.tobytes() == b.latencies.tobytes()


CHAOS = FaultPlan(
    seed=5, mttf_ms=3_000.0, restart_delay_ms=100.0, max_retries=3,
    retry_backoff_ms=10.0, retry_threshold=0.05, straggler_prob=0.1,
    straggler_factor=2.5, admission_floor=0.05,
)


# --------------------------------------------------------------- FaultPlan
def test_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(mttf_ms=-1.0)
    with pytest.raises(ValueError):
        FaultPlan(straggler_prob=1.5)
    with pytest.raises(ValueError):
        FaultPlan(straggler_prob=0.5, straggler_factor=0.5)
    with pytest.raises(ValueError):
        FaultPlan(admission_floor=2.0)
    with pytest.raises(ValueError):
        FaultPlan(max_retries=-1)
    with pytest.raises(ValueError):
        FaultPlan(batch_timeout_ms=-0.5)


def test_plan_enabled_and_dict_round_trip():
    assert not FaultPlan().enabled()
    assert not FaultPlan(seed=9, max_retries=5, retry_backoff_ms=3.0).enabled()
    for kw in (
        {"mttf_ms": 1.0},
        {"straggler_prob": 0.1, "straggler_factor": 2.0},
        {"admission_floor": 0.1},
        {"batch_timeout_ms": 50.0},
    ):
        assert FaultPlan(**kw).enabled(), kw
    assert FaultPlan.from_dict(CHAOS.to_dict()) == CHAOS
    # unknown keys (future knobs in old artifacts) are ignored, not fatal
    assert FaultPlan.from_dict({"mttf_ms": 2.0, "not_a_knob": 1}) == FaultPlan(
        mttf_ms=2.0
    )


def test_same_seed_same_draws():
    """Two FaultStates from one plan replay identical crash renewals and
    straggler draws; a different seed diverges."""
    a, b = CHAOS.start(4), CHAOS.start(4)
    for w in range(4):
        assert [a.next_crash(w, 0.0) for _ in range(20)] == [
            b.next_crash(w, 0.0) for _ in range(20)
        ]
    durs = np.linspace(10.0, 200.0, 50)
    assert [a.straggle(d) for d in durs] == [b.straggle(d) for d in durs]
    c = dataclasses.replace(CHAOS, seed=6).start(4)
    assert [a.next_crash(0, 0.0) for _ in range(20)] != [
        c.next_crash(0, 0.0) for _ in range(20)
    ]


def test_crash_streams_are_per_worker():
    """Worker w's renewal sequence does not depend on how often other
    workers' streams are consumed — the engine-invariance keystone."""
    a = CHAOS.start(3)
    b = CHAOS.start(3)
    for _ in range(10):
        b.next_crash(0, 0.0)  # burn worker 0's stream only
    assert [a.next_crash(2, 0.0) for _ in range(5)] == [
        b.next_crash(2, 0.0) for _ in range(5)
    ]


# --------------------------------------------------- finish prob / retry
def test_finish_probability_edges():
    rs = _rs(n=50)
    sched = OrlojScheduler(LM, initial_dists=rs.initial_dists())
    req = rs.fresh()[0]
    assert finish_probability(sched, req, req.deadline + 1.0) == 0.0
    p = finish_probability(sched, req, req.release)
    assert 0.0 <= p <= 1.0

    class _Blind:  # no latency knowledge at all: optimistic no-op gate
        pass

    assert finish_probability(_Blind(), req, req.release) == 1.0


def test_retry_gate_exhaustion_and_deadline():
    rs = _rs(n=50)
    sched = OrlojScheduler(LM, initial_dists=rs.initial_dists())
    state = FaultPlan(seed=1, max_retries=1, retry_backoff_ms=5.0).start(1)
    req = rs.fresh()[0]
    req.retries = 0
    ok, t_retry = state.retry_decision(sched, req, req.release)
    assert ok and t_retry >= req.release
    req.retries = 1  # budget exhausted
    assert state.retry_decision(sched, req, req.release)[0] is False
    req.retries = 0  # past the deadline: probability floor kills it
    assert state.retry_decision(sched, req, req.deadline + 1.0)[0] is False


# ------------------------------------------------------ fault-free no-op
@pytest.mark.parametrize("engine", ["scalar", "array"])
def test_disabled_plan_is_bitwise_noop(engine):
    """faults=None, faults={} at the spec level and a populated-but-
    disabled plan all produce bit-identical results: threading the hook
    points costs nothing observable."""
    rs = _rs()
    bare = run_event_loop(rs.fresh(), _workers(rs, 2), seed=3, engine=engine)
    disabled = run_event_loop(
        rs.fresh(), _workers(rs, 2), seed=3, engine=engine,
        faults=FaultPlan(seed=99, max_retries=7, retry_backoff_ms=50.0),
    )
    _assert_identical(bare, disabled)
    assert disabled.n_rejected == disabled.n_failed == disabled.n_retried == 0


# ------------------------------------------------- engine equivalence
@pytest.mark.parametrize("k", [1, 4])
def test_scalar_array_identical_under_chaos(k):
    """The bit-identity equivalence claim extends to every FaultPlan:
    crashes + stragglers + admission + retries, one and many workers."""
    rs = _rs(n=500)
    a = run_event_loop(
        rs.fresh(), _workers(rs, k), policy="least_loaded", seed=7,
        engine="scalar", faults=CHAOS,
    )
    b = run_event_loop(
        rs.fresh(), _workers(rs, k), policy="least_loaded", seed=7,
        engine="array", faults=CHAOS,
    )
    _assert_identical(a, b)
    assert a.conserved
    assert a.n_retried > 0  # the plan actually fired


def test_batch_timeout_abort_path():
    """batch_timeout_ms aborts slow batches on both engines identically;
    timed-out requests end as retried-then-resolved or failed, never
    lost."""
    rs = _rs(n=300)
    plan = FaultPlan(seed=2, batch_timeout_ms=60.0, max_retries=1,
                     retry_backoff_ms=5.0)
    a = run_event_loop(rs.fresh(), _workers(rs, 2), seed=5,
                       engine="scalar", faults=plan)
    b = run_event_loop(rs.fresh(), _workers(rs, 2), seed=5,
                       engine="array", faults=plan)
    _assert_identical(a, b)
    assert a.conserved
    assert a.n_retried + a.n_failed > 0


# ------------------------------------------------------ admission control
def test_admission_floor_rejects_under_overload():
    rs = _rs(util=3.0, n=400)
    plan = FaultPlan(seed=3, admission_floor=0.4)
    res = {
        e: run_event_loop(rs.fresh(), _workers(rs), seed=9, engine=e,
                          faults=plan)
        for e in ("scalar", "array")
    }
    _assert_identical(res["scalar"], res["array"])
    r = res["scalar"]
    assert r.n_rejected > 0
    assert r.conserved
    # rejected requests never execute: no latency sample for them
    assert len(r.latencies) == r.n_finished_ok + r.n_finished_late


# ----------------------------------------------------------- truncation
@pytest.mark.parametrize("engine", ["scalar", "array"])
def test_wall_budget_truncates_gracefully(engine):
    rs = _rs(n=2_000)
    res = run_event_loop(
        rs.fresh(), _workers(rs, 2), seed=1, engine=engine,
        faults=CHAOS, wall_budget_s=1e-9,
    )
    assert res.truncated
    assert res.conserved
    assert res.n_unserved > 0  # cut off early: unresolved work is visible
    assert res.worker_busy <= res.makespan_ms * res.n_workers + 1e-9


# ----------------------------------------------------------- fleet mode
def test_fleet_chaos_equivalence_and_conservation():
    rs = _rs(n=600, util=1.5)
    kw = dict(n_pools=2, inter="p2c", intra="round_robin", seed=7,
              faults=CHAOS)
    a = run_fleet(rs.fresh(), _workers(rs, 6), engine="scalar", **kw)
    b = run_fleet(rs.fresh(), _workers(rs, 6), engine="array", **kw)
    _assert_identical(a, b)
    assert a.conserved
    assert a.n_retried > 0


@pytest.mark.parametrize("engine", ["scalar", "array"])
def test_dead_target_retries_drain_to_sibling(engine):
    """Requeued work targeted at a dead worker re-routes to a live
    sibling (the fleet drain path).  All arrivals pin to worker 0, which
    crashes early and stays down for the rest of the run; with a sibling
    present the aborted requests finish on it, alone they stall until
    the far restart and die late."""
    # seed 161: worker 0's first crash lands at ~1.9s (mid-batch under
    # 2x overload), worker 1's not before ~9.3s — a live sibling window
    plan = FaultPlan(
        seed=161, mttf_ms=1_500.0, restart_delay_ms=1e6,  # die, stay dead
        max_retries=3, retry_backoff_ms=1.0,
    )
    pin0 = lambda req, now, pool: 0  # noqa: E731

    def run(k):
        rs = _rs(n=200, util=2.0, seed=17)
        return run_event_loop(
            rs.fresh(), _workers(rs, k), policy=pin0, seed=3,
            engine=engine, faults=plan,
        )

    alone, paired = run(1), run(2)
    assert alone.conserved and paired.conserved
    assert paired.n_retried > 0  # the crash aborted in-flight work
    # worker 0's crash stream is seeded identically in both runs; only
    # the sibling explains the recovered finishes
    assert paired.n_finished_ok > alone.n_finished_ok


# ------------------------------------------------- conservation property
def _conservation_case(seed, util, k, level, engine):
    rs = _rs(util=util, n=200, seed=seed)
    plan = FaultPlan(
        seed=seed, mttf_ms=800.0 * level, restart_delay_ms=50.0,
        max_retries=2, retry_backoff_ms=5.0, retry_threshold=0.05,
        straggler_prob=0.1, straggler_factor=2.0, admission_floor=0.05,
    )
    res = run_event_loop(
        rs.fresh(), _workers(rs, k), policy="least_loaded", seed=seed,
        engine=engine, faults=plan,
    )
    assert res.conserved, (seed, util, k, level, engine)
    assert res.n_finished_ok + res.n_finished_late == len(res.latencies)


@pytest.mark.parametrize("engine", ["scalar", "array"])
@pytest.mark.parametrize("seed,util,k,level", [
    (0, 0.5, 1, 1.0),
    (1, 1.5, 2, 0.25),
    (2, 3.0, 3, 4.0),
    (3, 1.0, 4, 0.5),
])
def test_conservation_examples(engine, seed, util, k, level):
    """Seeded example grid of the conservation invariant — always runs,
    hypothesis or not."""
    _conservation_case(seed, util, k, level, engine)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    util=st.floats(min_value=0.2, max_value=4.0),
    k=st.integers(min_value=1, max_value=4),
    level=st.floats(min_value=0.1, max_value=8.0),
    engine=st.sampled_from(["scalar", "array"]),
)
def test_conservation_property(seed, util, k, level, engine):
    """Every request reaches exactly one terminal state (or none —
    unserved) under arbitrary seeded fault plans, on both engines."""
    _conservation_case(seed, util, k, level, engine)
