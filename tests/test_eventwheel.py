"""Property tests for the array engine's event sourcing: the calendar-queue
:class:`EventWheel` (total order ≡ heapq, bucket-boundary and overflow
edges) and the columnar :class:`RequestStore` (sorting, groups, row
mapping, stats folding) — DESIGN.md §10."""

import heapq
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.eventwheel import MAX_BUCKET_SPAN, EventWheel
from repro.core.request import Request
from repro.core.requeststore import RequestStore


def _random_events(rng, n, *, tick=None, t_max=1_000.0):
    times = rng.uniform(0.0, t_max, size=n)
    if tick:
        times = np.floor(times / tick) * tick  # force heavy timestamp ties
    return [(float(t), i, 0, None) for i, t in enumerate(times)]


def _heapq_order(events):
    h = list(events)
    heapq.heapify(h)
    return [heapq.heappop(h) for _ in range(len(h))]


# ------------------------------------------------------------ EventWheel
@pytest.mark.parametrize("bucket_ms", [None, 0.5, 4.0, 1_000.0, 1e9])
@pytest.mark.parametrize("tick", [None, 4.0])
def test_drain_matches_heapq(bucket_ms, tick):
    """Total order across buckets/overflow ≡ a heapq over (time, seq),
    for bucket widths from far-finer to far-coarser than the spread and
    for continuous as well as heavily tied (tick-quantized) timestamps."""
    rng = np.random.default_rng(0)
    events = _random_events(rng, 500, tick=tick)
    w = EventWheel(bucket_ms)
    for ev in events:
        w.push(*ev)
    assert len(w) == len(events)
    assert list(w.drain()) == _heapq_order(events)
    assert len(w) == 0 and not w


def test_same_timestamp_coalesce_one_batch():
    """Equal-time events land in one bucket and drain as one seq-sorted
    batch — the coalescing window the bulk arrival path feeds on."""
    w = EventWheel(4.0)
    for seq in (5, 1, 3):
        w.push(7.5, seq, 0, f"p{seq}")
    batch = w.pop_bucket()
    assert [(t, s) for t, s, _, _ in batch] == [(7.5, 1), (7.5, 3), (7.5, 5)]


def test_bucket_boundary_edges():
    """t exactly on a bucket edge belongs to the *upper* bucket
    (floor(t / width)); just-below stays in the lower one."""
    w = EventWheel(4.0)
    eps = 1e-9
    w.push(8.0, 1, 0, None)        # bucket 2
    w.push(8.0 - eps, 0, 0, None)  # bucket 1
    first = w.pop_bucket()
    assert [s for _, s, _, _ in first] == [0]
    assert [s for _, s, _, _ in w.pop_bucket()] == [1]


def test_overflow_nonfinite_and_far_future():
    """Non-finite and pathologically far timestamps take the heapq
    fallback but still merge back in global (time, seq) order."""
    w = EventWheel(1.0)
    far = (MAX_BUCKET_SPAN + 10) * 1.0  # beyond the bucket-span window
    w.push(math.inf, 3, 0, "inf")
    w.push(far, 2, 0, "far")
    w.push(5.0, 1, 0, "near")
    assert w.peek_key() == (5.0, 1)
    got = [(t, s) for t, s, _, _ in w.drain()]
    assert got == [(5.0, 1), (far, 2), (math.inf, 3)]


def test_overflow_merges_into_bucket_window():
    """An event pushed while outside the bucket-span window (→ overflow
    heap) still surfaces inside the right bucket's batch, sorted into
    place, once the cursor catches up and that bucket goes live."""
    bm = 2.0
    w = EventWheel(bm)
    near = MAX_BUCKET_SPAN * bm        # bucket idx = span: inside window
    far = 2 * MAX_BUCKET_SPAN * bm     # idx = 2*span: outside -> overflow
    w.push(near, 0, 0, None)
    w.push(far, 1, 0, None)
    assert [s for _, s, _, _ in w.pop_bucket()] == [0]  # cursor -> span
    w.push(far + 0.5, 2, 0, None)      # same bucket, now inside the window
    batch = w.pop_bucket()
    assert [(t, s) for t, s, _, _ in batch] == [(far, 1), (far + 0.5, 2)]


def _fault_tail_events(rng, n_near, n_far, bucket_ms):
    """Mixed near/far/non-finite stream shaped like a faulted run: normal
    DONE/WAKE traffic plus CRASH(kind 3)/RESTART(kind 4) events whose
    timestamps land far outside the bucket window (huge restart delays)
    or at +inf (a next-crash renewal past everything)."""
    _CRASH, _RESTART = 3, 4
    events = []
    seq = 0
    for t in rng.uniform(0.0, 500.0, size=n_near):
        events.append((float(t), seq, int(rng.integers(0, 3)), None))
        seq += 1
    far_base = (MAX_BUCKET_SPAN + 1) * bucket_ms
    for t in rng.uniform(far_base, far_base * 50, size=n_far):
        kind = _CRASH if seq % 2 else _RESTART
        events.append((float(t), seq, kind, seq % 4))
        seq += 1
    events.append((math.inf, seq, _CRASH, 0))
    return events


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("bucket_ms", [0.5, 4.0, 64.0])
def test_overflow_fault_events_keep_heapq_order(seed, bucket_ms):
    """Crash/restart events at far-future and non-finite timestamps (the
    shapes huge ``restart_delay_ms``/``mttf_ms`` plans produce) ride the
    overflow heap yet drain in exact (time, seq) heapq order, mixed
    pop/pop_bucket included."""
    rng = np.random.default_rng(seed)
    events = _fault_tail_events(rng, n_near=300, n_far=40, bucket_ms=bucket_ms)
    w = EventWheel(bucket_ms)
    for ev in events:
        w.push(*ev)
    got = []
    while w:
        if rng.random() < 0.5:
            got.append(w.pop())
        else:
            got.extend(w.pop_bucket())
    assert got == _heapq_order(events)


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    bucket_ms=st.floats(min_value=1e-3, max_value=1e6),
    n_near=st.integers(min_value=0, max_value=200),
    n_far=st.integers(min_value=0, max_value=50),
)
def test_overflow_fault_order_property(seed, bucket_ms, n_near, n_far):
    """Property form of the above: arbitrary bucket widths and near/far
    mixes, total drain order ≡ heapq."""
    rng = np.random.default_rng(seed)
    events = _fault_tail_events(rng, n_near, n_far, bucket_ms)
    w = EventWheel(bucket_ms)
    for ev in events:
        w.push(*ev)
    assert list(w.drain()) == _heapq_order(events)


def test_push_before_last_pop_raises():
    w = EventWheel(4.0)
    w.push(10.0, 0, 0, None)
    w.pop_bucket()
    with pytest.raises(ValueError, match="pushed before"):
        w.push(9.0, 1, 0, None)
    # at the last-pop time is fine (same-instant follow-up events)
    w.push(10.0, 2, 0, None)


def test_push_during_drain_keeps_global_order():
    """Handlers may push fresh events between the remaining entries of a
    popped batch (DONE arming a WAKE); peek_key exposes them so the
    caller's merge preserves (time, seq) order."""
    w = EventWheel(10.0)
    w.push(1.0, 0, 0, None)
    w.push(9.0, 1, 0, None)
    batch = w.pop_bucket()
    assert [s for _, s, _, _ in batch] == [0, 1]
    w.push(5.0, 2, 0, None)  # between the two popped entries' times
    assert w.peek_key() == (5.0, 2)
    assert [s for _, s, _, _ in w.pop_bucket()] == [2]


def test_pop_single_matches_heapq_and_mixes_with_pop_bucket():
    rng = np.random.default_rng(3)
    events = _random_events(rng, 200, tick=2.0, t_max=100.0)
    w = EventWheel(4.0)
    for ev in events:
        w.push(*ev)
    got = []
    while w:
        if rng.random() < 0.5:
            got.append(w.pop())
        else:
            got.extend(w.pop_bucket())
    assert got == _heapq_order(events)


def test_empty_and_invalid():
    w = EventWheel(4.0)
    assert w.peek_key() == (math.inf, -1)
    assert w.peek_time() == math.inf
    with pytest.raises(IndexError):
        w.pop_bucket()
    with pytest.raises(IndexError):
        w.pop()
    with pytest.raises(ValueError, match="bucket_ms"):
        EventWheel(0.0)
    with pytest.raises(ValueError, match="bucket_ms"):
        EventWheel(-1.0)


# ---------------------------------------------------------- RequestStore
def _reqs(releases, slo=50.0):
    return [
        Request(app_id="a", release=float(t), slo=slo, true_time=1.0)
        for t in releases
    ]


def test_store_sorts_stably_and_groups():
    reqs = _reqs([5.0, 1.0, 5.0, 3.0, 1.0])
    store = RequestStore(reqs)
    assert [r.release for r in store.requests] == [1.0, 1.0, 3.0, 5.0, 5.0]
    # stable: equal-release requests keep input order
    assert store.requests == sorted(reqs, key=lambda r: r.release)
    assert store.group_times == [1.0, 3.0, 5.0]
    assert store.group_starts == [0, 2, 3, 5]
    assert store.group(0) == store.requests[0:2]
    assert store.n_groups == 3


def test_store_sorted_input_fast_path():
    reqs = _reqs([1.0, 2.0, 2.0, 7.0])
    store = RequestStore(reqs)
    assert store.requests == reqs  # no reorder
    assert store.release.tolist() == [1.0, 2.0, 2.0, 7.0]
    assert (store.deadline == store.release + 50.0).all()
    assert len(store) == 4
    assert len(RequestStore([])) == 0


def test_rows_for_contiguous_and_sparse_rids():
    reqs = _reqs([3.0, 1.0, 2.0])  # contiguous rids from the global counter
    store = RequestStore(reqs)
    assert store.rows_for([reqs[0], reqs[1]]) == [2, 0]
    assert isinstance(store._row, list)
    # sparse rids (hand-built subset) fall back to the dict map
    sparse = _reqs([4.0, 5.0, 6.0])[::2]
    store2 = RequestStore(sparse)
    assert store2.rows_for(list(reversed(sparse))) == [1, 0]
    assert isinstance(store2._row, dict)


def test_fold_stats_matches_scalar_accounting():
    reqs = _reqs([0.0, 1.0, 2.0, 3.0], slo=10.0)
    store = RequestStore(reqs)
    store.started[:] = [0.0, 1.0, np.nan, np.nan]
    store.finished[:] = [5.0, 20.0, np.nan, np.nan]  # ok, late, -, -
    store.requests[2].dropped = 2.5
    ok, late, dropped, unserved, lat = store.fold_stats()
    assert (ok, late, dropped, unserved) == (1, 1, 1, 1)
    assert lat.tolist() == [5.0, 19.0]
    # no_drops fast path: the proven-drop-free accounting
    store.requests[2].dropped = None
    ok, late, dropped, unserved, _ = store.fold_stats(no_drops=True)
    assert (ok, late, dropped, unserved) == (1, 1, 0, 2)


def test_writeback_flushes_only_written_rows():
    reqs = _reqs([0.0, 1.0])
    store = RequestStore(reqs)
    store.started[0] = 4.0
    store.finished[0] = 9.0
    store.writeback()
    assert (reqs[0].started, reqs[0].finished) == (4.0, 9.0)
    assert reqs[1].started is None and reqs[1].finished is None
