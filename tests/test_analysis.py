"""Tests for the ``repro.analysis`` static-analysis pass (DESIGN.md §9).

Every rule gets a positive fixture (must flag) and a negative fixture
(must stay silent) fed through :func:`analyze_source` with a *virtual*
path inside the rule's zone — no files on disk, no jax import: the pass
is AST-only, so this file runs in the bare-env CI job too.
"""

import json
import textwrap

from repro.analysis import (
    ALL_RULES,
    Baseline,
    analyze_source,
    diff_against_baseline,
    fingerprint,
    get_rules,
)
from repro.analysis.cli import main

CORE = "src/repro/core/fake.py"


def run(source, path=CORE, rules=None):
    return analyze_source(textwrap.dedent(source), path, rules or ALL_RULES)


def rule_ids(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------- R1
def test_r1_flags_wallclock_and_global_rng():
    active, _ = run(
        """
        import time, random
        import numpy as np

        def f():
            t = time.time()
            random.shuffle([1, 2])
            rng = np.random.default_rng()
            return t, rng
        """
    )
    assert rule_ids(active) == ["R1", "R1", "R1"]
    assert "time.time" in active[0].message


def test_r1_silent_on_seeded_rng_and_outside_zone():
    src = """
    import numpy as np

    def f(seed):
        return np.random.default_rng(seed).normal()
    """
    active, _ = run(src)
    assert active == []
    # the same wall-clock code outside the determinism zones is fine
    active, _ = run("import time\nt = time.time()\n", path="src/repro/models/x.py")
    assert active == []


def test_r1_resolves_import_aliases():
    active, _ = run(
        """
        import time as _time

        def f():
            return _time.perf_counter()
        """
    )
    assert rule_ids(active) == ["R1"]


# ---------------------------------------------------------------- R2
def test_r2_flags_fold_after_split():
    # the models/ssm.py probe shape this rule was built around
    active, _ = run(
        """
        import jax

        def init(rng):
            ks = jax.random.split(rng, 6)
            return jax.random.fold_in(rng, 7)
        """,
        path="src/repro/models/fake.py",
    )
    assert rule_ids(active) == ["R2"]
    assert "split" in active[0].message


def test_r2_flags_sampler_then_split():
    active, _ = run(
        """
        import jax

        def f(key):
            x = jax.random.normal(key, (4,))
            a, b = jax.random.split(key)
            return x, a, b
        """,
        path="src/repro/models/fake.py",
    )
    assert rule_ids(active) == ["R2"]


def test_r2_approves_fold_in_fanout_and_carry_rebind():
    active, _ = run(
        """
        import jax

        def fanout(rng, n):
            return [jax.random.fold_in(rng, i) for i in range(n)]

        def carry(rng):
            for _ in range(3):
                rng, sub = jax.random.split(rng)
                x = jax.random.normal(sub, ())
            return x
        """,
        path="src/repro/models/fake.py",
    )
    assert active == []


def test_r2_catches_loop_carried_reuse():
    active, _ = run(
        """
        import jax

        def f(rng):
            out = []
            for i in range(3):
                out.append(jax.random.normal(rng, ()))
            return out
        """,
        path="src/repro/models/fake.py",
    )
    assert rule_ids(active) == ["R2"]


def test_r2_repo_probe_is_fixed():
    # regression for the init_mlstm fold_in-after-split collision: the real
    # file must stay clean under R2
    source = open("src/repro/models/ssm.py", encoding="utf-8").read()
    active, _ = analyze_source(source, "src/repro/models/ssm.py", get_rules(["R2"]))
    assert active == []


# ---------------------------------------------------------------- R3
def test_r3_flags_bare_time_names_at_boundaries():
    active, _ = run(
        """
        class Cfg:
            deadline: float

        def schedule(batch, timeout):
            return batch, timeout
        """
    )
    assert rule_ids(active) == ["R3", "R3"]
    assert "Cfg.deadline" in active[0].message


def test_r3_silent_on_suffixed_and_private():
    active, _ = run(
        """
        class Cfg:
            deadline_ms: float

        def schedule(batch, timeout_s):
            return batch, timeout_s

        def _helper(deadline):
            return deadline
        """
    )
    assert active == []


def test_r3_flags_mixed_unit_arithmetic():
    active, _ = run(
        """
        def f(a_ms, b_s):
            bad = a_ms + b_s
            also_bad = a_ms < b_s
            fine = a_ms + b_s * 1e3
            return bad, also_bad, fine
        """
    )
    assert rule_ids(active) == ["R3", "R3"]


# ---------------------------------------------------------------- R4
def test_r4_flags_set_iteration_and_conversion():
    active, _ = run(
        """
        def f(pending):
            ready = set(pending)
            for rid in ready:
                emit(rid)
            return list({1, 2} | ready)
        """
    )
    assert rule_ids(active) == ["R4", "R4"]


def test_r4_flags_defaulting_pop_pattern():
    active, _ = run(
        """
        def remove(table, rid):
            for bs in table.pop(rid, set()):
                drop(bs)
        """
    )
    assert rule_ids(active) == ["R4"]


def test_r4_approves_sorted_and_dict_iteration():
    active, _ = run(
        """
        def f(pending, d):
            for rid in sorted(set(pending)):
                emit(rid)
            for k, v in d.items():
                emit(k, v)
            return max({1, 2}), len({3})
        """
    )
    assert active == []


def test_r4_rebinding_to_list_clears_set_mark():
    active, _ = run(
        """
        def f(pending):
            xs = set(pending)
            xs = sorted(xs)
            for x in xs:
                emit(x)
        """
    )
    assert active == []


# ---------------------------------------------------------------- R5
SCHED = "src/repro/core/scheduler.py"


def test_r5_flags_alloc_in_hot_loop():
    active, _ = run(
        """
        class OrlojScheduler:
            def on_arrivals(self, reqs, now):
                for r in reqs:
                    self.feasible[r.rid] = set(self.sizes)
        """,
        path=SCHED,
    )
    assert rule_ids(active) == ["R5"]


def test_r5_silent_outside_hot_functions_and_loops():
    active, _ = run(
        """
        class OrlojScheduler:
            def on_arrivals(self, reqs, now):
                bulk = [r.rid for r in reqs]  # outside a loop body: bulk
                self.hull.insert_many(bulk)

            def cold_helper(self, reqs):
                for r in reqs:
                    box = [r]
        """,
        path=SCHED,
    )
    assert active == []


def test_r5_only_applies_to_listed_files():
    active, _ = run(
        """
        class OrlojScheduler:
            def on_arrivals(self, reqs, now):
                for r in reqs:
                    box = [r]
        """,
        path="src/repro/core/other.py",
    )
    assert active == []


# ---------------------------------------------------------------- R6
KERN = "src/repro/kernels/fake.py"


def test_r6_flags_python_branch_on_traced_value():
    active, _ = run(
        """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """,
        path=KERN,
    )
    assert rule_ids(active) == ["R6"]


def test_r6_flags_host_calls_in_pallas_kernel():
    active, _ = run(
        """
        import functools
        import jax.experimental.pallas as pl

        def _kernel(x_ref, o_ref, *, block: int):
            print(x_ref)
            o_ref[...] = x_ref[...]

        def op(x, block):
            return pl.pallas_call(
                functools.partial(_kernel, block=block),
                out_shape=x,
            )(x)
        """,
        path=KERN,
    )
    assert rule_ids(active) == ["R6"]


def test_r6_approves_static_idioms():
    active, _ = run(
        """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, lengths=None, *, mode="a"):
            if lengths is None:
                lengths = x
            if x.shape[0] > 4:
                return lengths
            if mode == "b":
                return x
            return x + lengths
        """,
        path=KERN,
    )
    assert active == []


# ---------------------------------------------------------------- R7
def test_r7_flags_bare_except_and_silent_swallow():
    active, _ = run(
        """
        def probe(x):
            try:
                return x()
            except:
                pass

        def fallback(leaf):
            try:
                return transform(leaf)
            except Exception:
                return leaf

        def empty():
            try:
                return load()
            except Exception:
                return {}
        """
    )
    assert rule_ids(active) == ["R7", "R7", "R7"]
    assert "bare `except:`" in active[0].message
    assert "swallows" in active[1].message


def test_r7_silent_on_observed_recovered_or_narrow():
    active, _ = run(
        """
        import traceback

        def bound_and_used(x):
            try:
                return x()
            except Exception as e:
                return f"{type(e).__name__}: {e}"

        def recorded(res, x):
            try:
                res.value = x()
            except Exception:
                res.error = traceback.format_exc()

        def reraised(x):
            try:
                return x()
            except Exception:
                raise

        def narrow(d):
            try:
                return d["k"]
            except KeyError:
                return None
        """
    )
    assert active == []
    # outside the src/repro zone the rule does not apply
    active, _ = run(
        "try:\n    f()\nexcept:\n    pass\n", path="tests/fake.py"
    )
    assert "R7" not in rule_ids(active)


def test_r7_repo_swallow_sites_are_baselined():
    # the three triaged boundary swallows stay in the committed baseline
    base = Baseline.load("ANALYSIS_baseline.json")
    r7 = [m for m in base.meta.values() if m["rule"] == "R7"]
    assert {m["path"] for m in r7} == {
        "src/repro/launch/dryrun.py",
        "src/repro/models/blocks.py",
    }


# ------------------------------------------------------- suppressions
def test_suppression_same_line_and_line_above():
    src = """
    import time

    def f():
        a = time.time()  # simlint: ignore[R1] -- measured wall time
        # simlint: ignore[R1] -- measured wall time
        b = time.time()
        return a, b
    """
    active, silenced = run(src)
    assert active == []
    assert len(silenced) == 2
    assert all(sup.justified for _, sup in silenced)


def test_suppression_without_justification_is_tracked():
    active, silenced = run(
        """
        import time

        def f():
            return time.time()  # simlint: ignore[R1]
        """
    )
    assert active == []
    assert [sup.justified for _, sup in silenced] == [False]


def test_suppression_wrong_rule_id_does_not_silence():
    active, silenced = run(
        """
        import time

        def f():
            return time.time()  # simlint: ignore[R4] -- wrong id
        """
    )
    assert rule_ids(active) == ["R1"]
    assert silenced == []


def test_skip_file_directive():
    active, silenced = run(
        """
        # simlint: skip-file
        import time
        t = time.time()
        """
    )
    assert active == [] and silenced == []


# ------------------------------------------------------------ baseline
def test_baseline_round_trip(tmp_path):
    active, _ = run(
        """
        import time

        def f():
            return time.time()
        """
    )
    base = Baseline.from_findings(active)
    path = tmp_path / "base.json"
    base.save(path)
    loaded = Baseline.load(path)
    assert loaded.counts == base.counts

    new, stale = diff_against_baseline(active, loaded)
    assert new == [] and stale == []
    # a fresh finding not in the baseline is new
    new, _ = diff_against_baseline(active + active, loaded)
    assert len(new) == 1
    # a fixed finding leaves a stale entry behind
    _, stale = diff_against_baseline([], loaded)
    assert stale == [fingerprint(active[0])]


def test_baseline_fingerprint_ignores_line_numbers():
    a1, _ = run("import time\n\ndef f():\n    return time.time()\n")
    a2, _ = run("import time\n\n\n\ndef f():\n    return time.time()\n")
    assert a1[0].line != a2[0].line
    assert fingerprint(a1[0]) == fingerprint(a2[0])


def test_missing_baseline_loads_empty(tmp_path):
    assert Baseline.load(tmp_path / "absent.json").counts == {}


# ----------------------------------------------------------------- CLI
def _write(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(text), encoding="utf-8")
    return p


def test_cli_clean_tree_exits_zero(tmp_path, monkeypatch, capsys):
    _write(tmp_path, "src/repro/core/ok.py", "def f(x_ms):\n    return x_ms\n")
    monkeypatch.chdir(tmp_path)
    assert main(["--check", "--no-baseline", "src"]) == 0


def test_cli_injected_positive_exits_one(tmp_path, monkeypatch, capsys):
    _write(
        tmp_path,
        "src/repro/core/bad.py",
        "import time\n\ndef f():\n    return time.time()\n",
    )
    monkeypatch.chdir(tmp_path)
    assert main(["--check", "--no-baseline", "src"]) == 1
    out = capsys.readouterr().out
    assert "[R1/determinism-wallclock]" in out


def test_cli_check_rejects_unjustified_suppression(tmp_path, monkeypatch, capsys):
    _write(
        tmp_path,
        "src/repro/core/bad.py",
        "import time\n\ndef f():\n    return time.time()  # simlint: ignore[R1]\n",
    )
    monkeypatch.chdir(tmp_path)
    assert main(["--check", "--no-baseline", "src"]) == 1
    assert "justification" in capsys.readouterr().err


def test_cli_baseline_ratchet(tmp_path, monkeypatch, capsys):
    _write(
        tmp_path,
        "src/repro/core/old.py",
        "import time\n\ndef f():\n    return time.time()\n",
    )
    monkeypatch.chdir(tmp_path)
    assert main(["--write-baseline", "src"]) == 0
    # grandfathered finding passes the gate
    assert main(["--check", "src"]) == 0
    # a second, new finding fails it
    _write(
        tmp_path,
        "src/repro/core/new.py",
        "import time\n\ndef g():\n    return time.time()\n",
    )
    assert main(["--check", "src"]) == 1


def test_cli_json_report(tmp_path, monkeypatch, capsys):
    _write(
        tmp_path,
        "src/repro/core/bad.py",
        "import time\n\ndef f():\n    return time.time()\n",
    )
    monkeypatch.chdir(tmp_path)
    assert main(["--json", "--no-baseline", "src"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["summary"]["total"] == 1
    assert doc["findings"][0]["rule"] == "R1"
    assert doc["findings"][0]["new"] is True


def test_cli_unknown_rule_exits_two(capsys):
    assert main(["--rules", "R99", "src"]) == 2


def test_cli_syntax_error_exits_two(tmp_path, monkeypatch, capsys):
    _write(tmp_path, "src/repro/core/broken.py", "def f(:\n")
    monkeypatch.chdir(tmp_path)
    assert main(["--check", "--no-baseline", "src"]) == 2


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("R1", "R2", "R3", "R4", "R5", "R6"):
        assert rid in out


# --------------------------------------------------------- repo gate
def test_repo_head_passes_the_gate(monkeypatch, capsys):
    """`python -m repro.analysis --check src tests` must be green at HEAD."""
    import pathlib

    repo_root = pathlib.Path(__file__).resolve().parent.parent
    monkeypatch.chdir(repo_root)
    assert main(["--check", "src", "tests"]) == 0


def test_get_rules_selectors():
    assert [r.rule_id for r in get_rules(["R1", "prng-key-reuse"])] == ["R1", "R2"]
    assert len(get_rules(None)) == 7
