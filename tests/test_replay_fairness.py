"""The §5.2 same-request-set fairness premise behind every table:

- the same ``TraceConfig`` seed regenerates a bitwise-identical
  ``RequestSet`` (so independent grid cells can regenerate instead of
  sharing state);
- ``fresh()`` copies are isolated — one system's run mutating its
  ``Request`` s (bookkeeping, deadlines) cannot leak into the next
  system's replay.
"""

from __future__ import annotations

import numpy as np

from repro.core import BatchLatencyModel, ModelExecutor, OrlojScheduler, simulate
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def _gen(seed: int = 5):
    return generate_requests(
        bimodal(1.0),
        LM,
        slo_scale=2.0,
        cfg=TraceConfig(n_requests=150, utilization=0.85, seed=seed),
    )


def test_same_seed_regenerates_bitwise_identical_set():
    a, b = _gen(), _gen()
    assert a.fingerprint() == b.fingerprint()
    # ... and the fingerprint actually discriminates.
    assert a.fingerprint() != _gen(seed=6).fingerprint()


def test_fingerprint_ignores_run_bookkeeping():
    rs = _gen()
    before = rs.fingerprint()
    reqs = rs.fresh()
    res = simulate(
        reqs, OrlojScheduler(LM, initial_dists=rs.initial_dists()), ModelExecutor(LM)
    )
    assert res.n_total == 150
    assert rs.fingerprint() == before


def test_fresh_copies_are_isolated_between_systems():
    rs = _gen()
    first = rs.fresh()
    res = simulate(
        first, OrlojScheduler(LM, initial_dists=rs.initial_dists()), ModelExecutor(LM)
    )
    # The first system's replay left its marks on its own copy...
    assert res.n_finished_ok > 0
    assert any(r.finished is not None or r.dropped is not None for r in first)

    # ...but the template and a second fresh copy are untouched.
    for template in rs.requests:
        assert template.started is None
        assert template.finished is None
        assert template.dropped is None
    second = rs.fresh()
    assert all(r.started is None and r.finished is None and r.dropped is None
               for r in second)

    # Core fields match pairwise (same arrivals, SLOs, hidden times)...
    for x, y in zip(first, second):
        assert (x.app_id, x.release, x.slo, x.true_time) == (
            y.app_id, y.release, y.slo, y.true_time)
    # ...through distinct objects: mutating one copy cannot leak.
    second[0].slo = -1.0
    assert first[0].slo != -1.0
    assert rs.requests[0].slo != -1.0


def test_fresh_assigns_distinct_rids_per_copy():
    # Two replays must not alias each other's requests in scheduler maps
    # keyed by rid.
    rs = _gen()
    rids_a = {r.rid for r in rs.fresh()}
    rids_b = {r.rid for r in rs.fresh()}
    assert rids_a.isdisjoint(rids_b)


def test_warm_samples_matches_app_history():
    rs = _gen()
    warm = rs.warm_samples()
    assert warm.shape == (sum(len(v) for v in rs.app_history.values()),)
    assert np.array_equal(
        warm, np.concatenate(list(rs.app_history.values()))
    )
