import os

# The dry-run launcher forces 512 placeholder devices when imported as a
# program; tests import its pure helpers and must keep the real 1-device
# CPU backend (see src/repro/launch/dryrun.py header).
os.environ.setdefault("REPRO_DRYRUN_DEVICES", "0")
