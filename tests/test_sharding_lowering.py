"""Sharding/lowering tests on an 8-device debug mesh (subprocess so the
placeholder-device XLA flag never leaks into other tests)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, for_shape, InputShape
    from repro.models import Model
    from repro.models.sharding import (
        param_specs, input_batch_specs, cache_specs, to_named)

    arch, kind = sys.argv[1], sys.argv[2]
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    model = Model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = to_named(mesh, param_specs(cfg, params_shape, mesh))

    b, s = 4, 64
    if kind == "train":
        batch = {}
        if cfg.frontend == "audio":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct((b, s, model.frontend_dim), jnp.float32)
        elif cfg.frontend == "vision":
            f = cfg.n_frontend_tokens
            batch["frontend_embeds"] = jax.ShapeDtypeStruct((b, f, model.frontend_dim), jnp.float32)
            batch["tokens"] = jax.ShapeDtypeStruct((b, s - f), jnp.int32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        bspecs = to_named(mesh, input_batch_specs(cfg, mesh, batch, b))
        fn = jax.jit(model.loss, in_shardings=(pspecs, bspecs),
                     out_shardings=NamedSharding(mesh, P()))
        compiled = fn.lower(params_shape, batch).compile()
    else:
        tok = (jax.ShapeDtypeStruct((b, 1, model.frontend_dim), jnp.float32)
               if cfg.frontend == "audio" else jax.ShapeDtypeStruct((b, 1), jnp.int32))
        cache = jax.eval_shape(lambda: model.init_cache(b, cache_len=s, dtype=jnp.bfloat16))
        cspecs = to_named(mesh, cache_specs(cfg, mesh, cache, b, kind == "seqshard"))
        tspec = to_named(mesh, input_batch_specs(cfg, mesh, tok, b))
        fn = jax.jit(model.decode_step,
                     in_shardings=(pspecs, tspec, cspecs, NamedSharding(mesh, P())),
                     out_shardings=(NamedSharding(mesh, P()), cspecs))
        compiled = fn.lower(params_shape, tok, cache,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print(json.dumps({"flops": float(ca.get("flops", 0))}))
    """
)


def _run(arch: str, kind: str):
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch, kind],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize(
    "arch", ["glm4_9b", "dbrx_132b", "hymba_1_5b", "xlstm_1_3b", "internvl2_1b"]
)
def test_train_lowering_on_mesh(arch):
    got = _run(arch, "train")
    assert got["flops"] > 0


@pytest.mark.parametrize("arch", ["glm4_9b", "arctic_480b", "musicgen_large"])
def test_decode_lowering_on_mesh(arch):
    got = _run(arch, "decode")
    assert got["flops"] > 0
