"""Tests for the Eq.-2 time-varying priority score (paper §4.1, §4.4, App. B)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # bare env: property tests skip, the rest run
    from _hypothesis_stub import given, settings, st

from repro.core.distributions import EmpiricalDistribution
from repro.core.priority import DEFAULT_B, BinScoreModel
from repro.core.request import Request


def _model(b=DEFAULT_B, edges=(20.0, 60.0, 120.0, 260.0), probs=(0.5, 0.3, 0.2)):
    d = EmpiricalDistribution(np.array(edges), np.array(probs))
    return BinScoreModel(d, b=b)


def _req(release=0.0, slo=500.0, cost=1.0, **kw):
    return Request(app_id="a", release=release, slo=slo, true_time=10.0, cost=cost, **kw)


def test_alpha_beta_matches_literal_eq2():
    m = _model()
    r = _req()
    for t in np.linspace(0.0, 600.0, 97):
        assert np.isclose(
            m.value(r, t, base=0.0), m.value_reference(r, t, base=0.0), rtol=1e-9
        ), t


def test_regimes_and_zero_after_hopeless():
    m = _model()
    r = _req(slo=500.0)
    # After D − l1_min (= 500 − 20) every bin is in regime C: score 0.
    assert m.value(r, 490.0, 0.0) == pytest.approx(0.0, abs=1e-12)
    # Well before the deadline the score is positive and *increasing*.
    v1, v2 = m.value(r, 0.0, 0.0), m.value(r, 100.0, 0.0)
    assert 0 < v1 < v2


def test_continuity_at_milestones():
    """p(t) is continuous across the D−l2 / D−l1 regime changes."""
    m = _model()
    r = _req(slo=400.0)
    for edge in np.concatenate([m.l1, m.l2]):
        t = r.deadline - edge
        lo, hi = m.value(r, t - 1e-6, 0.0), m.value(r, t + 1e-6, 0.0)
        assert np.isclose(lo, hi, rtol=1e-6, atol=1e-7)


def test_milestone_is_next_regime_change():
    m = _model()
    r = _req(slo=400.0)
    sc = m.score(r, 0.0, 0.0)
    # milestone = min over future D−l2, D−l1
    expected = min(
        min(r.deadline - m.l2), min(r.deadline - m.l1)
    )
    assert np.isclose(sc.milestone, expected)
    # just after the milestone the (α, β) must change
    sc2 = m.score(r, sc.milestone + 1e-9, 0.0)
    assert (sc.alpha, sc.beta) != (sc2.alpha, sc2.beta)


def test_base_shift_invariance():
    """Scores are invariant to the overflow-handling base shift (§4.4)."""
    m = _model()
    r = _req(release=1_000.0, slo=400.0)
    t = 1_100.0
    assert np.isclose(m.value(r, t, base=0.0), m.value(r, t, base=900.0), rtol=1e-9)


def test_earlier_deadline_scores_higher():
    m = _model()
    t = 0.0
    r1 = _req(release=0.0, slo=400.0)
    r2 = _req(release=0.0, slo=800.0)
    assert m.value(r1, t, 0.0) > m.value(r2, t, 0.0)


def test_cost_scales_score():
    m = _model()
    r1 = _req(cost=1.0)
    r5 = _req(cost=5.0)
    assert np.isclose(5 * m.value(r1, 10.0, 0.0), m.value(r5, 10.0, 0.0), rtol=1e-9)


def test_piecewise_step_cost_decomposition():
    """Appendix B: a multi-step cost is the sum of single-step scores."""
    m = _model()
    # deadlines at slo and slo+200 with cumulative costs 1 and 3.
    multi = _req(slo=400.0, cost=1.0, extra_deadlines=((600.0, 3.0),))
    s1 = _req(slo=400.0, cost=1.0)
    s2 = _req(slo=600.0, cost=2.0)
    for t in (0.0, 150.0, 350.0, 450.0, 590.0):
        assert np.isclose(
            m.value(multi, t, 0.0),
            m.value(s1, t, 0.0) + m.value(s2, t, 0.0),
            rtol=1e-9,
        ), t


def test_b_does_not_change_ordering():
    """§5.6: the relative ordering of requests is insensitive to b."""
    reqs = [_req(release=float(i * 30), slo=400.0 + 50 * i) for i in range(6)]
    orders = []
    for b in (1e-5, 1e-4, 1e-3):
        m = _model(b=b)
        vals = [m.value(r, 100.0, 0.0) for r in reqs]
        orders.append(tuple(np.argsort(vals)))
    assert orders[0] == orders[1] == orders[2]


@given(
    slo=st.floats(min_value=300.0, max_value=5_000.0),
    t=st.floats(min_value=0.0, max_value=5_000.0),
    base=st.floats(min_value=-1_000.0, max_value=1_000.0),
)
@settings(max_examples=60, deadline=None)
def test_property_score_nonnegative_finite(slo, t, base):
    m = _model()
    r = _req(slo=slo)
    v = m.value(r, t, base)
    assert np.isfinite(v)
    assert v >= -1e-9
