"""Tests for multi-replica scale-out (§3.1) via the ``simulate_cluster``
compatibility wrapper over the unified event engine."""

import numpy as np
import pytest

from repro.core import (
    BatchLatencyModel,
    ClockworkScheduler,
    ModelExecutor,
    OrlojScheduler,
    simulate,
)
from repro.serving.cluster import DISPATCH_POLICIES, simulate_cluster
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def _rs(util, n=600, seed=5):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=3.0,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=util),
    )


@pytest.mark.parametrize("policy", sorted(DISPATCH_POLICIES))
def test_cluster_conservation(policy):
    rs = _rs(util=1.5)  # offered at ~1.5× one worker → needs the pool
    scheds = [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(3)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), policy=policy)
    assert res.n_total == 600
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == res.n_total
    )
    assert res.finish_rate > 0.5, policy
    # honest accounting: explicit pool size, util over makespan·n_workers
    assert res.n_workers == 3
    assert res.utilization <= 1.0 + 1e-9, policy


def test_more_replicas_help_under_overload():
    rs = _rs(util=2.2)
    one = simulate(
        rs.fresh(),
        OrlojScheduler(LM, initial_dists=rs.initial_dists()),
        ModelExecutor(LM),
    ).finish_rate
    four = simulate_cluster(
        rs.fresh(),
        [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(4)],
        ModelExecutor(LM),
    ).finish_rate
    assert four > one + 0.15


def test_cluster_works_with_baseline_schedulers():
    rs = _rs(util=1.5)
    warm = np.concatenate(list(rs.app_history.values()))
    scheds = [ClockworkScheduler(LM, init_samples=warm) for _ in range(2)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM))
    assert res.finish_rate > 0.3


def test_cluster_supports_horizon():
    rs = _rs(util=1.0, n=200)
    scheds = [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(2)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), horizon=1.0)
    assert res.n_unserved > 0


# ---------------------------------------------------- fleet mode (§10)
from repro.core import Worker  # noqa: E402
from repro.serving.cluster import (  # noqa: E402
    INTER_POOL_POLICIES,
    hierarchical_policy,
    pool_bounds,
    run_fleet,
)


def _orloj(rs):
    return OrlojScheduler(LM, initial_dists=rs.initial_dists())


def test_pool_bounds_even_partition():
    assert pool_bounds(10, 2) == [(0, 5), (5, 10)]
    assert pool_bounds(7, 3) == [(0, 3), (3, 5), (5, 7)]  # first pools +1
    assert pool_bounds(4, 4) == [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert pool_bounds(5, 1) == [(0, 5)]
    for bad in ((4, 0), (4, 5), (0, 1)):
        with pytest.raises(ValueError, match="n_pools"):
            pool_bounds(*bad)


def test_hierarchical_policy_validation():
    with pytest.raises(ValueError, match="unknown inter-pool policy"):
        hierarchical_policy(8, 2, inter="least_loaded")  # intra-only name
    with pytest.raises(ValueError, match="unknown intra-pool policy"):
        hierarchical_policy(8, 2, intra="nope")


@pytest.mark.parametrize("inter", INTER_POOL_POLICIES)
@pytest.mark.parametrize("intra", sorted(DISPATCH_POLICIES))
def test_fleet_conservation_every_policy_pair(inter, intra):
    """Every inter x intra combination resolves all requests and routes
    only within pool bounds (conservation through two dispatch levels)."""
    rs = _rs(util=0.9 * 4, n=200)
    workers = [
        Worker(_orloj(rs), ModelExecutor(LM, seed=i)) for i in range(4)
    ]
    res = run_fleet(
        rs.fresh(), workers, n_pools=2, inter=inter, intra=intra, seed=3
    )
    assert res.n_total == 200
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped
        + res.n_unserved == 200
    )
    assert res.n_unserved == 0


def test_fleet_deterministic_and_engine_equivalent():
    """Same seed -> identical fleet run; scalar and array engines agree
    bit-for-bit through hierarchical dispatch (the policy owns its rng,
    so dispatch sequences are engine-independent)."""
    rs = _rs(util=0.9 * 6, n=300)

    def run(engine):
        workers = [
            Worker(_orloj(rs), ModelExecutor(LM, seed=i)) for i in range(6)
        ]
        return run_fleet(
            rs.fresh(), workers, n_pools=3, inter="p2c", intra="round_robin",
            seed=5, engine=engine,
        )

    a, a2, b = run("scalar"), run("scalar"), run("array")
    for f in ("n_finished_ok", "n_finished_late", "n_dropped", "n_unserved",
              "makespan_ms", "n_decisions", "n_batches"):
        assert getattr(a, f) == getattr(a2, f), f
        assert getattr(a, f) == getattr(b, f), f
    assert a.latencies.tobytes() == b.latencies.tobytes()
