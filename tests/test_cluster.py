"""Tests for multi-replica scale-out (§3.1) via the ``simulate_cluster``
compatibility wrapper over the unified event engine."""

import numpy as np
import pytest

from repro.core import (
    BatchLatencyModel,
    ClockworkScheduler,
    ModelExecutor,
    OrlojScheduler,
    simulate,
)
from repro.serving.cluster import DISPATCH_POLICIES, simulate_cluster
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

LM = BatchLatencyModel(c0=25.0, c1=1.0)


def _rs(util, n=600, seed=5):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=3.0,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=util),
    )


@pytest.mark.parametrize("policy", sorted(DISPATCH_POLICIES))
def test_cluster_conservation(policy):
    rs = _rs(util=1.5)  # offered at ~1.5× one worker → needs the pool
    scheds = [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(3)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), policy=policy)
    assert res.n_total == 600
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == res.n_total
    )
    assert res.finish_rate > 0.5, policy
    # honest accounting: explicit pool size, util over makespan·n_workers
    assert res.n_workers == 3
    assert res.utilization <= 1.0 + 1e-9, policy


def test_more_replicas_help_under_overload():
    rs = _rs(util=2.2)
    one = simulate(
        rs.fresh(),
        OrlojScheduler(LM, initial_dists=rs.initial_dists()),
        ModelExecutor(LM),
    ).finish_rate
    four = simulate_cluster(
        rs.fresh(),
        [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(4)],
        ModelExecutor(LM),
    ).finish_rate
    assert four > one + 0.15


def test_cluster_works_with_baseline_schedulers():
    rs = _rs(util=1.5)
    warm = np.concatenate(list(rs.app_history.values()))
    scheds = [ClockworkScheduler(LM, init_samples=warm) for _ in range(2)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM))
    assert res.finish_rate > 0.3


def test_cluster_supports_horizon():
    rs = _rs(util=1.0, n=200)
    scheds = [OrlojScheduler(LM, initial_dists=rs.initial_dists()) for _ in range(2)]
    res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), horizon=1.0)
    assert res.n_unserved > 0
