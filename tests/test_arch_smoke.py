"""Per-architecture smoke tests: reduced variants (≤2 layers, d_model ≤ 512,
≤4 experts) run a real forward + train-gradient step and a decode step on
CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model

BATCH, SEQ = 2, 32


def _batch(model: Model, rng):
    cfg = model.cfg
    front = cfg.n_frontend_tokens
    k_in, k_tok, k_lab = jax.random.split(rng, 3)
    b: dict = {}
    if cfg.frontend == "audio":
        b["frontend_embeds"] = jax.random.normal(
            k_in, (BATCH, SEQ, model.frontend_dim), jnp.float32
        )
        b["labels"] = jax.random.randint(k_lab, (BATCH, SEQ), 0, cfg.vocab_size)
    elif cfg.frontend == "vision":
        b["frontend_embeds"] = jax.random.normal(
            k_in, (BATCH, front, model.frontend_dim), jnp.float32
        )
        b["tokens"] = jax.random.randint(k_tok, (BATCH, SEQ - front), 0, cfg.vocab_size)
        labels = jax.random.randint(k_lab, (BATCH, SEQ), 0, cfg.vocab_size)
        b["labels"] = labels.at[:, :front].set(-100)  # mask image positions
    else:
        b["tokens"] = jax.random.randint(k_in, (BATCH, SEQ), 0, cfg.vocab_size)
        b["labels"] = jax.random.randint(k_lab, (BATCH, SEQ), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(model, jax.random.PRNGKey(1))

    logits = jax.jit(model.logits)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)), f"{arch}: non-finite grads"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(BATCH, cache_len=64, dtype=jnp.float32)
    if cfg.frontend == "audio":
        tok = jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, 1, model.frontend_dim), jnp.float32
        )
    else:
        tok = jnp.array([[1]] * BATCH, jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache2 = step(params, tok, cache, jnp.int32(0))
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    # a second step at pos 1 must also be finite and change the cache
    logits2, cache3 = step(params, tok, cache2, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all()), arch
    leaves2 = jax.tree.leaves(cache2)
    leaves3 = jax.tree.leaves(cache3)
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(leaves2, leaves3)
    ), f"{arch}: cache not updated"


def test_mlstm_init_key_discipline():
    """Regression for the fold_in-after-split collision in ``init_mlstm``:
    every weight must come from a distinct split child, deterministically."""
    from repro.models.ssm import init_mlstm

    p1 = init_mlstm(jax.random.PRNGKey(0), 64, 4)
    p2 = init_mlstm(jax.random.PRNGKey(0), 64, 4)
    for k in p1:
        assert np.array_equal(np.asarray(p1[k]), np.asarray(p2[k])), k
    # a different seed must move every weight, including "out" (previously
    # derived from the already-split parent key)
    p3 = init_mlstm(jax.random.PRNGKey(1), 64, 4)
    assert not np.array_equal(np.asarray(p1["out"]), np.asarray(p3["out"]))
    # same-shape weights within one init must not coincide (distinct keys)
    assert not np.array_equal(np.asarray(p1["out"]), np.asarray(p1["w_o"]))


@pytest.mark.parametrize("arch", ["glm4_9b", "hymba_1_5b", "xlstm_1_3b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    full = model.logits(params, {"tokens": toks})
    cache = model.init_cache(1, cache_len=16, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(8):
        lg, cache = step(params, toks[:, i : i + 1], cache, jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32), rtol=5e-2, atol=5e-2
    )
