"""End-to-end test of the real-execution serving engine (tiny model)."""

import numpy as np
import pytest

from repro.core import EmpiricalDistribution, OrlojScheduler, SchedulerConfig
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, ServingEngine

# Real jitted-model execution: excluded from the quick CI lane.
pytestmark = pytest.mark.slow

TINY = ModelConfig(
    name="tiny",
    arch_type="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
    scan_layers=False,
)


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(
        TINY, EngineConfig(buckets=(16, 32), batch_sizes=(1, 2, 4), profile_reps=2)
    )


def test_profile_fits_eq3(engine):
    lm = engine.profile_latency_model()
    assert lm.c0 >= 0 and lm.c1 > 0
    # bigger work → bigger predicted latency
    assert lm.batch_time([32.0] * 4) > lm.batch_time([16.0])


def test_executor_reports_padded_batch_size(engine):
    """A k=3 batch pads up to the next supported size (4) and the executor
    reports that executed size — the quantity the profiler must fit
    against for estimates to match measurements."""
    assert engine.executor.padded_batch_size(3) == 4
    assert engine.executor.padded_batch_size(4) == 4
    assert engine.executor.padded_batch_size(9) == 9  # beyond the largest
    ms, k_pad = engine.executor._run(np.ones((3, 16), np.int32))
    assert k_pad == 4
    assert ms > 0.0


def test_pool_serving_real_execution(engine):
    """Two ORLOJ replicas sharing the measured JAX executor finish a light
    trace through the unified multi-worker loop."""
    lm = engine.profile_latency_model()
    reqs, hist = engine.make_requests(
        24,
        lm,
        length_sampler=lambda rng: int(rng.integers(4, 32)),
        slo_scale=50.0,
        utilization=0.4,
        seed=2,
    )
    dists = {
        a: EmpiricalDistribution.from_samples(x)
        for a, x in hist.items()
        if len(x) >= 2
    }
    scheds = [
        OrlojScheduler(
            lm, cfg=SchedulerConfig(batch_sizes=(1, 2, 4)), initial_dists=dists
        )
        for _ in range(2)
    ]
    res = engine.serve_pool(reqs, scheds)
    assert res.n_workers == 2
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == 24
    )
    assert res.utilization <= 1.0 + 1e-9


def test_serve_real_requests_end_to_end(engine):
    lm = engine.profile_latency_model()
    reqs, hist = engine.make_requests(
        30,
        lm,
        length_sampler=lambda rng: int(rng.integers(4, 32)),
        slo_scale=50.0,  # generous: CPU timing jitter is large
        utilization=0.3,
        seed=1,
    )
    dists = {
        a: EmpiricalDistribution.from_samples(x)
        for a, x in hist.items()
        if len(x) >= 2
    }
    sched = OrlojScheduler(
        lm, cfg=SchedulerConfig(batch_sizes=(1, 2, 4)), initial_dists=dists
    )
    res = engine.serve(reqs, sched)
    assert res.n_total == 30
    assert res.n_finished_ok + res.n_finished_late + res.n_dropped == 30
    assert res.finish_rate > 0.5


# ---------------------------------------------------------------- decode path


def test_decode_executor_serves_token_requests(engine):
    """Continuous batching against the real decode-attention step: every
    request's tokens are served, slots recycle, and a second run on the
    same executor reuses the compiled step (slot reconciliation by rid)."""
    from repro.core.tokensched import FcfsTokenScheduler, TokenSchedConfig

    dec = engine.decode_executor(max_batch=4, max_cache=64)
    step_ms = dec.calibrate()
    assert step_ms > 0.0
    reqs = engine.make_token_requests(
        24, dec, mean_out=8.0, utilization=0.5, seed=2
    )
    cfg = TokenSchedConfig(
        max_batch=4,
        ttft_slo_ms=reqs[0].slo,  # generous: CPU timing jitter is large
        tpot_slo_ms=4.0 * step_ms,
        d0=step_ms,
        d1=0.0,
    )
    res = engine.serve_tokens(reqs, FcfsTokenScheduler(cfg), dec)
    assert res.n_total == 24 and res.conserved
    assert all(r.tokens_done == r.out_tokens for r in reqs)
    assert all(r.first_token is not None for r in reqs)
    # slots of the final step's finishers are reclaimed lazily on the next
    # run's first step — a fresh serve must start from full capacity
    reqs2 = engine.make_token_requests(
        8, dec, mean_out=4.0, utilization=0.5, seed=3
    )
    res2 = engine.serve_tokens(reqs2, FcfsTokenScheduler(cfg), dec)
    assert res2.n_total == 8
    assert all(r.tokens_done == r.out_tokens for r in reqs2)


def test_serve_tokens_rejects_oversized_scheduler(engine):
    from repro.core.tokensched import FcfsTokenScheduler, TokenSchedConfig

    dec = engine.decode_executor(max_batch=2, max_cache=32)
    with pytest.raises(ValueError, match="cache slots"):
        engine.serve_tokens(
            [], FcfsTokenScheduler(TokenSchedConfig(max_batch=8)), dec
        )


def test_decode_executor_pallas_interpreter_agrees(engine):
    """One measured step under the Pallas interpreter matches the jnp
    reference numerics bit-for-bit from identical seeded state — the
    kernel-integration check (auto-detect picks the reference on CPU;
    forcing use_pallas=True exercises the interpreter)."""
    import jax.numpy as jnp

    outs = {}
    for use_pallas in (False, True):
        dec = engine.decode_executor(
            max_batch=2, max_cache=32, use_pallas=use_pallas, seed=7
        )
        dec._valid = jnp.array([5, 0], jnp.int32)  # one occupied, one empty
        dec._decode_once()
        outs[use_pallas] = (np.asarray(dec.last_out), np.asarray(dec._valid))
    np.testing.assert_array_equal(outs[False][1], outs[True][1])
    # occupied slot: same attention numerics through either path
    np.testing.assert_allclose(
        outs[True][0][0], outs[False][0][0], rtol=2e-5, atol=1e-6
    )
    # empty slot (valid_len == 0) must come back all-zero, not NaN — the
    # fully-masked-row regression both kernel paths now share
    np.testing.assert_array_equal(outs[True][0][1], np.zeros_like(outs[True][0][1]))
