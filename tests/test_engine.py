"""End-to-end test of the real-execution serving engine (tiny model)."""

import numpy as np
import pytest

from repro.core import EmpiricalDistribution, OrlojScheduler, SchedulerConfig
from repro.models.config import ModelConfig
from repro.serving.engine import EngineConfig, ServingEngine

# Real jitted-model execution: excluded from the quick CI lane.
pytestmark = pytest.mark.slow

TINY = ModelConfig(
    name="tiny",
    arch_type="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
    scan_layers=False,
)


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(
        TINY, EngineConfig(buckets=(16, 32), batch_sizes=(1, 2, 4), profile_reps=2)
    )


def test_profile_fits_eq3(engine):
    lm = engine.profile_latency_model()
    assert lm.c0 >= 0 and lm.c1 > 0
    # bigger work → bigger predicted latency
    assert lm.batch_time([32.0] * 4) > lm.batch_time([16.0])


def test_executor_reports_padded_batch_size(engine):
    """A k=3 batch pads up to the next supported size (4) and the executor
    reports that executed size — the quantity the profiler must fit
    against for estimates to match measurements."""
    assert engine.executor.padded_batch_size(3) == 4
    assert engine.executor.padded_batch_size(4) == 4
    assert engine.executor.padded_batch_size(9) == 9  # beyond the largest
    ms, k_pad = engine.executor._run(np.ones((3, 16), np.int32))
    assert k_pad == 4
    assert ms > 0.0


def test_pool_serving_real_execution(engine):
    """Two ORLOJ replicas sharing the measured JAX executor finish a light
    trace through the unified multi-worker loop."""
    lm = engine.profile_latency_model()
    reqs, hist = engine.make_requests(
        24,
        lm,
        length_sampler=lambda rng: int(rng.integers(4, 32)),
        slo_scale=50.0,
        utilization=0.4,
        seed=2,
    )
    dists = {
        a: EmpiricalDistribution.from_samples(x)
        for a, x in hist.items()
        if len(x) >= 2
    }
    scheds = [
        OrlojScheduler(
            lm, cfg=SchedulerConfig(batch_sizes=(1, 2, 4)), initial_dists=dists
        )
        for _ in range(2)
    ]
    res = engine.serve_pool(reqs, scheds)
    assert res.n_workers == 2
    assert (
        res.n_finished_ok + res.n_finished_late + res.n_dropped + res.n_unserved
        == 24
    )
    assert res.utilization <= 1.0 + 1e-9


def test_serve_real_requests_end_to_end(engine):
    lm = engine.profile_latency_model()
    reqs, hist = engine.make_requests(
        30,
        lm,
        length_sampler=lambda rng: int(rng.integers(4, 32)),
        slo_scale=50.0,  # generous: CPU timing jitter is large
        utilization=0.3,
        seed=1,
    )
    dists = {
        a: EmpiricalDistribution.from_samples(x)
        for a, x in hist.items()
        if len(x) >= 2
    }
    sched = OrlojScheduler(
        lm, cfg=SchedulerConfig(batch_sizes=(1, 2, 4)), initial_dists=dists
    )
    res = engine.serve(reqs, sched)
    assert res.n_total == 30
    assert res.n_finished_ok + res.n_finished_late + res.n_dropped == 30
    assert res.finish_rate > 0.5
