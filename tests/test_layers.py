"""Layer-level equivalence tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_apply, init_attention, norm_apply, init_norm


@pytest.mark.parametrize("h,kv", [(8, 2), (4, 4), (6, 1)])
def test_repeat_kv_equals_grouped_gqa(h, kv):
    """The §Perf repeat-KV formulation is numerically identical to the
    baseline grouped formulation."""
    rng = jax.random.PRNGKey(0)
    d, hd = 64, 16
    params = init_attention(rng, d, h, kv, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d), jnp.float32)
    base = attention_apply(params, x, n_kv=kv, rope_theta=1e4)
    rep = attention_apply(params, x, n_kv=kv, rope_theta=1e4, repeat_kv=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(rep), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [4, 16])
def test_repeat_kv_sliding_window(window):
    rng = jax.random.PRNGKey(2)
    params = init_attention(rng, 32, 4, 2, 8)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32), jnp.float32)
    base = attention_apply(
        params, x, n_kv=2, rope_theta=1e4, sliding_window=window
    )
    rep = attention_apply(
        params, x, n_kv=2, rope_theta=1e4, sliding_window=window, repeat_kv=True
    )
    np.testing.assert_allclose(np.asarray(base), np.asarray(rep), rtol=2e-5, atol=2e-5)


def test_nonparam_ln_has_no_params():
    p = init_norm(jax.random.PRNGKey(0), 16, "nonparam_ln")
    assert p == {}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16)) * 7 + 3
    y = norm_apply(p, x, "nonparam_ln")
    np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y.std(-1)), 1.0, atol=1e-2)
