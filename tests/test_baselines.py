"""First direct coverage of the baseline schedulers (core/baselines.py).

Each test drives a baseline through its public simulator protocol only —
``on_arrivals`` / ``next_batch`` / ``on_batch_done`` — and checks the
behavior the paper characterises for that system (§2.3, §5):

- Clockwork: a batch overrunning the predicted completion by more than the
  action window makes the pre-committed next action fail (its requests are
  dropped);
- Nexus: the fixed batch size is replanned from the observed *mean* only
  every ``replan_interval``;
- Clipper: AIMD — an SLO-violating batch halves the cap, a compliant one
  regrows it additively;
- EDF: earliest-deadline-first service order, expired heads dropped.
"""

from __future__ import annotations

from repro.core import BatchLatencyModel, Request
from repro.core.baselines import (
    BASELINES,
    ClipperScheduler,
    ClockworkScheduler,
    EDFScheduler,
    NexusScheduler,
)

LM = BatchLatencyModel(c0=10.0, c1=1.0)
WARM = [10.0] * 8  # point estimators start at mean 10 -> est_batch(bs) = 10 + 10*bs


def _req(release: float, slo: float, app: str = "a") -> Request:
    return Request(app_id=app, release=release, slo=slo, true_time=10.0)


def test_registry_covers_all_baselines():
    assert set(BASELINES) == {"clockwork", "nexus", "clipper", "edf"}
    for name, cls in BASELINES.items():
        assert cls.name == name


# -- Clockwork ---------------------------------------------------------------


def test_clockwork_action_window_miss_drops_precommitted_batch():
    sched = ClockworkScheduler(LM, init_samples=WARM, window_slack=10.0)
    reqs = [_req(0.0, 100.0) for _ in range(6)]
    sched.on_arrivals(reqs, 0.0)

    batch, _ = sched.next_batch(0.0)
    # est_batch(4) = 50 <= earliest deadline 100; 8 > 6 pending -> bs 4.
    assert batch is not None and len(batch.requests) == 4
    assert sched.n_pending == 2

    # The worker finished far past the predicted completion (50) plus the
    # action window (10): the pre-planned action is rejected and the batch
    # it would have run fails (§2.3 "subsequent batch to fail").
    batch2, _ = sched.next_batch(70.0)
    assert batch2 is None
    assert sched.n_pending == 0
    assert sched.n_timed_out == 2
    dropped = [r for r in reqs if r.dropped is not None]
    assert len(dropped) == 2 and all(r.dropped == 70.0 for r in dropped)


def test_clockwork_on_time_action_keeps_batch():
    sched = ClockworkScheduler(LM, init_samples=WARM, window_slack=10.0)
    reqs = [_req(0.0, 100.0) for _ in range(6)]
    sched.on_arrivals(reqs, 0.0)
    sched.next_batch(0.0)

    # Within the window (predicted 50 + slack 10): the next action runs.
    batch2, _ = sched.next_batch(55.0)
    assert batch2 is not None and len(batch2.requests) == 2
    assert sched.n_timed_out == 0
    assert all(r.dropped is None for r in reqs)


# -- Nexus -------------------------------------------------------------------


def test_nexus_replans_fixed_batch_from_mean_at_interval():
    sched = NexusScheduler(LM, init_samples=WARM, replan_interval=5_000.0)
    slo = 100.0

    # Plan from mean 10: squishy-bin rule 2*(10 + 10*bs) <= 100 -> bs=4.
    sched.on_arrivals([_req(0.0, slo) for _ in range(4)], 0.0)
    b1, _ = sched.next_batch(0.0)
    assert b1 is not None and len(b1.requests) == 4

    # Observations drop the mean to (8*10 + 32*2)/40 = 3.6, but the next
    # arrival is inside the replan interval: the fixed plan must NOT move.
    sched.on_batch_done(b1, 10.0, [2.0] * 32)
    sched.on_arrivals([_req(1_000.0, slo) for _ in range(8)], 1_000.0)
    b2, _ = sched.next_batch(1_000.0)
    assert b2 is not None and len(b2.requests) == 4

    # Past the interval the arrival triggers a replan from the new mean:
    # 2*(10 + 8*3.6) = 77.6 <= 100 fits, 2*(10 + 16*3.6) doesn't -> bs=8.
    sched.on_arrivals([_req(6_000.0, slo) for _ in range(8)], 6_000.0)
    b3, _ = sched.next_batch(6_000.0)
    assert b3 is not None and len(b3.requests) == 8


def test_nexus_tight_slo_plans_smaller_batches():
    # slo=100: 2*(10+10*bs) <= 100 -> bs <= 4; with mean 10 the plan is 4.
    sched = NexusScheduler(LM, init_samples=WARM)
    sched.on_arrivals([_req(0.0, 100.0) for _ in range(16)], 0.0)
    batch, _ = sched.next_batch(0.0)
    assert batch is not None and len(batch.requests) == 4


# -- Clipper -----------------------------------------------------------------


def test_clipper_aimd_shrinks_then_regrows_additively():
    sched = ClipperScheduler(LM, init_samples=WARM)
    slo = 200.0
    sched.on_arrivals([_req(0.0, slo) for _ in range(40)], 0.0)

    b1, _ = sched.next_batch(0.0)
    assert b1 is not None and len(b1.requests) == 16  # cap starts at max bs

    # SLO-violating batch execution latency -> multiplicative decrease.
    b1.requests[0].started = 0.0
    b1.requests[0].finished = 300.0  # duration 300 > slo 200
    sched.on_batch_done(b1, 1.0, [10.0] * len(b1.requests))
    b2, _ = sched.next_batch(1.0)
    assert b2 is not None and len(b2.requests) == 8

    # Compliant batch -> additive increase by one.
    b2.requests[0].started = 1.0
    b2.requests[0].finished = 101.0  # duration 100 < slo
    sched.on_batch_done(b2, 2.0, [10.0] * len(b2.requests))
    b3, _ = sched.next_batch(2.0)
    assert b3 is not None and len(b3.requests) == 9


# -- EDF ---------------------------------------------------------------------


def test_edf_serves_earliest_deadline_first():
    sched = EDFScheduler(LM, init_samples=WARM)
    r_late = _req(0.0, 300.0)
    r_soon = _req(0.0, 50.0)
    r_mid = _req(0.0, 100.0)
    sched.on_arrivals([r_late, r_soon, r_mid], 0.0)

    # Earliest deadline 50 bounds the batch: est_batch(2)=30 fits, 4 > 3
    # pending anyway -> the two earliest-deadline requests, in order.
    batch, _ = sched.next_batch(0.0)
    assert batch is not None
    assert [r.rid for r in batch.requests] == [r_soon.rid, r_mid.rid]
    assert sched.n_pending == 1


def test_edf_drops_expired_head_and_counts_it():
    sched = EDFScheduler(LM, init_samples=WARM)
    r_dead = _req(0.0, 15.0)  # now + est_batch(1)=20 > 15 -> hopeless
    r_live = _req(0.0, 200.0)
    sched.on_arrivals([r_dead, r_live], 0.0)

    batch, _ = sched.next_batch(0.0)
    assert batch is not None and [r.rid for r in batch.requests] == [r_live.rid]
    assert r_dead.dropped == 0.0
    assert sched.n_timed_out == 1
    assert sched.n_pending == 0
