"""Fig. 13 (sensitivity to the anticipated-delay parameter b) and
Fig. 14 (scheduling overhead: shrinking minimum execution times) — thin
wrappers over the :mod:`repro.eval.grid` spec constructors."""

from __future__ import annotations

from repro.eval import grid
from repro.eval.runner import run_specs

from .common import emit, run_and_emit


def fig13_b_sweep(full: bool = False) -> None:
    """Finish rate as b varies 1e-6..1e-1 on the three-modal workload."""
    run_and_emit(grid.fig13(full))


def fig14_min_exec(full: bool = False) -> None:
    """Scale the whole execution-time distribution down until ORLOJ's
    scheduling overhead (estimates, milestones) bites.  These specs run
    with ``charge_overhead=True``: the measured scheduler decision time is
    billed to the virtual clock — the point of the Fig.-14 study.  The row
    name carries the scaled set's measured P99, so it is formatted from
    the result, not the spec tag."""
    for r in run_specs(grid.fig14(full)):
        emit(
            [
                f"fig14/p99-{r.p99_alone_ms:.1f}ms/slo{r.spec.slo_scale:g},"
                f"{r.sched_us_per_request:.1f},finish_rate={r.finish_rate:.3f}"
            ]
        )
