"""Fig. 13 (sensitivity to the anticipated-delay parameter b) and
Fig. 14 (scheduling overhead: shrinking minimum execution times)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    BatchLatencyModel,
    ModelExecutor,
    OrlojScheduler,
    SchedulerConfig,
    simulate,
)
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import k_modal

from .common import LM


def fig13_b_sweep(full: bool = False) -> None:
    """Finish rate as b varies 1e-6..1e-1 on the three-modal workload."""
    bs = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1)
    slos = (2.0, 3.0, 5.0) if not full else (1.5, 2.0, 3.0, 4.0, 5.0)
    apps = k_modal(3)
    for slo in slos:
        rs = generate_requests(
            apps, LM, slo_scale=slo, cfg=TraceConfig(n_requests=1_000, seed=3)
        )
        for b in bs:
            sched = OrlojScheduler(
                LM,
                cfg=SchedulerConfig(b=b),
                initial_dists=rs.initial_dists(),
            )
            res = simulate(rs.fresh(), sched, ModelExecutor(LM))
            print(
                f"fig13/slo{slo:g}/b{b:g},0,finish_rate={res.finish_rate:.3f}",
                flush=True,
            )


def fig14_min_exec(full: bool = False) -> None:
    """Scale the whole execution-time distribution down until ORLOJ's
    scheduling overhead (estimates, milestones) bites."""
    scales = (1.0, 0.5, 0.25, 0.1, 0.05) if not full else (1.0, 0.5, 0.25, 0.1, 0.075, 0.05, 0.025)
    for scale in scales:
        lm = BatchLatencyModel(c0=25.0 * scale, c1=1.0)
        apps = [
            type(a)(a.app_id, _scaled(a.sampler, scale), a.weight)
            for a in k_modal(3)
        ]
        for slo in (1.5, 3.0, 5.0):
            rs = generate_requests(
                apps, lm, slo_scale=slo, cfg=TraceConfig(n_requests=800, seed=4)
            )
            sched = OrlojScheduler(lm, initial_dists=rs.initial_dists())
            # charge the *measured* scheduler decision time to the virtual
            # clock — the whole point of the Fig.-14 overhead study
            res = simulate(
                rs.fresh(), sched, ModelExecutor(lm), charge_scheduler_overhead=True
            )
            p99 = rs.p99_alone
            print(
                f"fig14/p99-{p99:.1f}ms/slo{slo:g},0,finish_rate={res.finish_rate:.3f}",
                flush=True,
            )


def _scaled(sampler, scale):
    def f(rng, n):
        return sampler(rng, n) * scale

    return f
