"""Fig. 12: priority-queue insertion / query microbenchmark, plus the
end-to-end scheduler- and event-loop-throughput benchmarks behind
``BENCH_sched.json``.

Reproduces the O(log² n) scaling study for our Bentley–Saxe hull queue
(the paper's Overmars–van Leeuwen replacement; DESIGN.md §Substitutions)
and tracks the §4.4 claim that per-request decisions stay cheap: the
``sched`` benchmark measures the arrival path (requests/second into a
scheduler with n pending) and ``next_batch`` latency at n ∈ {1e2, 1e3,
1e4}, against the pre-PR scalar baseline *recorded in the same run*.

The ``eventloop`` benchmark (DESIGN.md §10) measures the event *engine*
itself — events/second through ``run_event_loop`` on the scalar oracle
loop vs the array engine at 10⁴/10⁵ requests — and feeds the ≥5× floor
gated by ``repro.eval.sched_gate``.  The ``token_decode`` benchmark
(DESIGN.md §12) prices the decode-step hook on the continuous-batching
path: per-``on_decode_step``-call µs for both token schedulers, which
the gate budgets absolutely (a hook that fires every token step must
stay strictly cheap).  All benchmarks merge their section into
``BENCH_sched.json`` without clobbering the others'.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (
    BatchLatencyModel,
    EmpiricalDistribution,
    HullQueue,
    OrlojScheduler,
    Request,
    Worker,
    run_event_loop,
)
from repro.core.scheduler import Batch


def _merge_sched_artifact(json_path: str, update: dict) -> None:
    """Read-modify-write ``BENCH_sched.json``: each benchmark owns its
    keys, and regenerating one section never clobbers the other."""
    try:
        with open(json_path) as f:
            doc = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        doc = {}
    doc.update(update)
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def fig12_queue(full: bool = False) -> None:
    sizes = (10, 100, 1_000, 10_000) if not full else (10, 32, 100, 316, 1_000, 3_162, 10_000)
    rng = np.random.default_rng(0)
    for n in sizes:
        # --- insertion: average per-request time filling to n
        reps = 3
        ins_total = 0.0
        for _ in range(reps):
            q = HullQueue()
            coeffs = rng.normal(size=(n, 2)) * 50
            t0 = time.perf_counter()
            for i in range(n):
                q.insert(i, float(coeffs[i, 0]), float(coeffs[i, 1]))
            ins_total += time.perf_counter() - t0
        ins_us = ins_total / (reps * n) * 1e6

        # --- query with a line of random slope
        q = HullQueue()
        coeffs = rng.normal(size=(n, 2)) * 50
        for i in range(n):
            q.insert(i, float(coeffs[i, 0]), float(coeffs[i, 1]))
        xs = np.exp(rng.uniform(0, 10, size=100))
        t0 = time.perf_counter()
        for x in xs:
            q.argmax(float(x))
        qry_us = (time.perf_counter() - t0) / xs.size * 1e6

        log2n = np.log2(max(n, 2)) ** 2
        print(f"fig12/insert/n{n},{ins_us:.2f},log2sq={log2n:.1f}", flush=True)
        print(f"fig12/query/n{n},{qry_us:.2f},log2sq={log2n:.1f}", flush=True)


def fig12_mixed_ops(full: bool = False) -> None:
    """Sustained scheduler-like mix: insert + milestone updates + pops."""
    rng = np.random.default_rng(1)
    n = 5_000 if full else 2_000
    q = HullQueue()
    t0 = time.perf_counter()
    alive = []
    ops = 0
    for i in range(n):
        q.insert(i, float(rng.normal() * 50), float(rng.normal() * 50))
        alive.append(i)
        ops += 1
        if i % 3 == 0 and len(alive) > 4:
            k = alive.pop(rng.integers(0, len(alive)))
            q.update(k, float(rng.normal() * 50), float(rng.normal() * 50))
            alive.append(k)
            ops += 1
        if i % 5 == 0 and len(alive) > 8:
            got = q.pop_max(float(np.exp(rng.uniform(0, 8))))
            alive.remove(got[0])
            ops += 1
    us = (time.perf_counter() - t0) / ops * 1e6
    print(f"fig12/mixed/n{n},{us:.2f},ops={ops}", flush=True)


# =====================================================================
# End-to-end scheduler throughput (BENCH_sched.json)
# =====================================================================

class _LegacyScorer:
    """The pre-PR scalar scoring path, kept verbatim from the seed
    (``np.where`` + ``np.sum`` over every bin, per request, per batch
    size) so the speedup of the vectorized hot path is measured against
    the real historical baseline in the same run."""

    def __init__(self, model) -> None:  # model: BinScoreModel
        # rebuilt from the model's public histogram fields only, so this
        # CI-gated baseline cannot break when internal caches are reshaped
        self.b = model.b
        self.l1, self.l2, self.h = model.l1, model.l2, model.h
        self._ebl1 = np.exp(self.b * self.l1)
        self._ebl2 = np.exp(self.b * self.l2)
        self._k = 1.0 / (model.e_l * self.b)

    def score(self, req, t: float, base: float):
        deadline, cost = req.release + req.slo, req.cost
        d_rel = deadline - base
        ebD = np.exp(-self.b * d_rel)
        coef = self._k * cost * self.h
        m_hi = deadline - self.l2
        m_lo = deadline - self.l1
        in_a = t < m_hi
        in_b = (~in_a) & (t < m_lo)
        alpha = float(
            np.sum(np.where(in_a, coef * (self._ebl2 - self._ebl1) * ebD, 0.0))
            + np.sum(np.where(in_b, -coef * self._ebl1 * ebD, 0.0))
        )
        beta = float(np.sum(np.where(in_b, coef, 0.0)))
        future = np.concatenate([m_hi[m_hi > t], m_lo[m_lo > t]])
        milestone = float(future.min()) if future.size else np.inf
        return alpha, beta, milestone


def _legacy_arrivals(sched: OrlojScheduler, reqs, now: float) -> None:
    """Pre-PR arrival path: one scalar score + one cascading hull insert
    per (request, batch size), same heap bookkeeping as ``on_arrivals``."""
    import heapq
    import math

    scorers = {bs: _LegacyScorer(st.score_model)
               for bs, st in sched._bs_state.items()}
    for req in reqs:
        sched._pending[req.rid] = req
        feas = set()
        for bs, st in sched._bs_state.items():
            feas.add(bs)
            alpha, beta, milestone = scorers[bs].score(req, now, sched._base)
            st.hull.insert(req.rid, alpha, beta)
            heapq.heappush(st.deadline_heap, (req.release + req.slo, req.rid))
            if math.isfinite(milestone):
                heapq.heappush(sched._milestones, (milestone, req.rid, bs))
        sched._feasible[req.rid] = feas


def _sched_fixture(n: int, seed: int = 0):
    from repro.core import Request

    rng = np.random.default_rng(seed)
    dists = {
        "a": EmpiricalDistribution(np.array([8.0, 14.0, 30.0]),
                                   np.array([0.6, 0.4])),
        "b": EmpiricalDistribution(np.array([70.0, 100.0, 130.0]),
                                   np.array([0.5, 0.5])),
        "c": EmpiricalDistribution(np.array([20.0, 45.0, 90.0]),
                                   np.array([0.3, 0.7])),
    }
    lm = BatchLatencyModel(c0=25.0, c1=1.0)
    # generous SLOs: every request stays feasible at every batch size, so
    # the hulls really hold n pending lines when next_batch is probed
    reqs = [
        Request(
            app_id="abc"[int(rng.integers(0, 3))],
            release=0.0,
            slo=float(rng.uniform(5_000.0, 50_000.0)),
            true_time=20.0,
        )
        for _ in range(n)
    ]
    return lambda: OrlojScheduler(lm, initial_dists=dists), reqs


def sched_throughput(full: bool = False,
                     json_path: str = "BENCH_sched.json") -> None:
    """Arrival-path throughput and ``next_batch`` latency vs pending count,
    new vectorized path and pre-PR scalar baseline in the same run; emits
    the machine-readable ``BENCH_sched.json`` trajectory artifact."""
    sizes = (100, 1_000, 10_000)
    out: dict[str, dict[str, float]] = {}
    for n in sizes:
        mk, reqs = _sched_fixture(n)
        reps = 3 if (full or n <= 1_000) else 1

        base_dt = vec_dt = 0.0
        for _ in range(reps):
            s0 = mk()
            t0 = time.perf_counter()
            _legacy_arrivals(s0, reqs, 0.0)
            base_dt += time.perf_counter() - t0

            s1 = mk()
            t0 = time.perf_counter()
            s1.on_arrivals(reqs, 0.0)
            vec_dt += time.perf_counter() - t0

        base_rate = reps * n / base_dt
        vec_rate = reps * n / vec_dt
        speedup = vec_rate / base_rate

        # next_batch latency with n pending (first decision after the bulk
        # load: milestone drain + drop phase + candidate scan + PopBatch)
        s1 = mk()
        s1.on_arrivals(reqs, 0.0)
        t0 = time.perf_counter()
        batch, _ = s1.next_batch(0.0)
        nb_us = (time.perf_counter() - t0) * 1e6
        assert batch is not None

        print(f"sched/arrivals/n{n},{1e6 / vec_rate:.2f},"
              f"base_us={1e6 / base_rate:.2f} speedup={speedup:.1f}x",
              flush=True)
        print(f"sched/next_batch/n{n},{nb_us:.2f},bs={batch.batch_size}",
              flush=True)
        out[str(n)] = {
            "baseline_arrivals_per_s": round(base_rate, 1),
            "vectorized_arrivals_per_s": round(vec_rate, 1),
            "speedup": round(speedup, 2),
            "next_batch_us": round(nb_us, 2),
        }

    _merge_sched_artifact(json_path, {
        "benchmark": "sched_throughput",
        "unit_note": "arrival path = full bookkeeping for one request "
                     "across all batch sizes (score + hull + heaps); "
                     "baseline = pre-PR scalar path recorded in this run",
        "sizes": out,
    })


# =====================================================================
# End-to-end event-loop throughput (BENCH_sched.json, "eventloop" section)
# =====================================================================

class _FifoObjScheduler:
    """Minimal object-path FIFO scheduler: append on arrival, pop up to
    ``max_batch`` in order.  The benchmark isolates the event *engine*
    (arrival delivery, completion processing, stats folding), so the
    scheduler must be as close to free as possible — Orloj's scoring
    would dominate and mask the engine difference being measured."""

    reads_request_state = False

    def __init__(self, max_batch: int = 256) -> None:
        self.q: list[Request] = []
        self.head = 0
        self.max_batch = max_batch
        self.n_timed_out = 0

    def on_arrival(self, req: Request, now: float) -> None:
        self.q.append(req)

    def on_arrivals(self, reqs, now: float) -> None:
        self.q.extend(reqs)

    def next_batch(self, now: float):
        k = min(self.max_batch, len(self.q) - self.head)
        if k <= 0:
            return None, None
        picked = self.q[self.head:self.head + k]
        self.head += k
        if self.head > 1 << 16:
            del self.q[:self.head]
            self.head = 0
        return Batch(picked, k), None

    def on_batch_done(self, batch, now, alone) -> None:
        pass

    @property
    def n_pending(self) -> int:
        return len(self.q) - self.head


class _FifoColsScheduler:
    """Columnar twin of :class:`_FifoObjScheduler` for the array engine:
    with a single worker, arrivals land in store order, so the pending
    set is one contiguous ``[lo, hi)`` row window — batches carry
    ``Batch.rows`` ranges and the engine's O(1) slice paths run.  Makes
    the *same batching decisions* as the object FIFO on the same trace
    (asserted by the benchmark), so the two engines do identical
    scheduling work and the delta is pure engine overhead."""

    reads_request_state = False

    def __init__(self, max_batch: int = 256) -> None:
        self.lo = 0
        self.hi = 0
        self.max_batch = max_batch
        self.n_timed_out = 0
        self.store = None

    def on_arrival(self, req: Request, now: float) -> None:
        raise RuntimeError("cols scheduler must be driven through the store")

    def on_arrival_row(self, store, row: int, now: float) -> None:
        self.store = store
        self.hi = row + 1

    def on_arrivals_cols(self, store, lo: int, hi: int, now: float) -> None:
        self.store = store
        self.hi = hi

    def next_batch(self, now: float):
        lo = self.lo
        k = self.hi - lo
        if k <= 0:
            return None, None
        if k > self.max_batch:
            k = self.max_batch
        self.lo = lo + k
        return Batch(self.store.requests[lo:lo + k], k, rows=range(lo, lo + k)), None

    def on_batch_done(self, batch, now, alone) -> None:
        pass

    @property
    def n_pending(self) -> int:
        return self.hi - self.lo


class _ConstExecutor:
    """Cheap deterministic Eq.-3-shaped batch time (no rng, no model)."""

    def __call__(self, batch, now: float) -> float:
        return 2.0 + 0.05 * len(batch.requests)


def _eventloop_requests(
    n: int, tick_ms: float, rate_per_ms: float, seed: int = 0
) -> list[Request]:
    """Poisson arrivals quantized to ``tick_ms`` (the front-end-drain
    arrival shape the fleet grids replay; TraceConfig.tick_ms) with
    generous SLOs, so the run measures engine throughput, not drops."""
    rng = np.random.default_rng(seed)
    at = np.cumsum(rng.exponential(1.0 / rate_per_ms, size=n))
    if tick_ms > 0:
        at = np.floor(at / tick_ms) * tick_ms
    return [
        Request(app_id="a", release=float(t), slo=100.0, true_time=1.0)
        for t in at
    ]


def eventloop_throughput(full: bool = False,
                         json_path: str = "BENCH_sched.json") -> None:
    """Events/second through ``run_event_loop``, scalar oracle loop vs the
    array engine, at 10⁴ and 10⁵ requests (an *event* is one arrival or
    one batch completion).  Both engines replay the identical trace with
    FIFO schedulers that make identical batching decisions (asserted), so
    the ratio is pure engine speedup — the number the ≥5× sched_gate
    floor tracks."""
    tick_ms, rate_per_ms = 4.0, 64.0
    sizes = (10_000, 100_000)
    reps = 3
    out: dict[str, dict[str, float]] = {}
    for n in sizes:
        master = _eventloop_requests(n, tick_ms, rate_per_ms)
        results, rates = {}, {}
        for engine, mk in (("scalar", _FifoObjScheduler),
                           ("array", _FifoColsScheduler)):
            best = float("inf")
            for _ in range(reps):
                reqs = [
                    Request(app_id=r.app_id, release=r.release, slo=r.slo,
                            true_time=r.true_time)
                    for r in master
                ]
                workers = [Worker(mk(), _ConstExecutor())]
                t0 = time.perf_counter()
                res = run_event_loop(reqs, workers, engine=engine)
                best = min(best, time.perf_counter() - t0)
            results[engine] = res
            rates[engine] = (res.n_total + res.n_batches) / best
        sc, ar = results["scalar"], results["array"]
        assert (sc.n_finished_ok, sc.n_finished_late, sc.n_batches) == (
            ar.n_finished_ok, ar.n_finished_late, ar.n_batches
        ), "engines diverged on the benchmark trace"
        speedup = rates["array"] / rates["scalar"]
        print(f"eventloop/array/n{n},{1e6 / rates['array']:.3f},"
              f"scalar_us={1e6 / rates['scalar']:.3f} speedup={speedup:.1f}x",
              flush=True)
        out[str(n)] = {
            "scalar_events_per_s": round(rates["scalar"], 1),
            "array_events_per_s": round(rates["array"], 1),
            "speedup": round(speedup, 2),
            "n_events": sc.n_total + sc.n_batches,
        }

    _merge_sched_artifact(json_path, {
        "eventloop": {
            "unit_note": "events/s through run_event_loop (1 event = "
                         "arrival or batch completion); identical "
                         "tick-quantized trace and FIFO batching decisions "
                         "on both engines, so speedup = engine overhead "
                         "ratio; best of 3 reps",
            "tick_ms": tick_ms,
            "rate_per_ms": rate_per_ms,
            "sizes": out,
        },
    })


def eventloop_faults(full: bool = False,
                     json_path: str = "BENCH_sched.json") -> None:
    """Fault-path overhead through ``run_event_loop``: the same FIFO
    trace as :func:`eventloop_throughput`, replayed fault-free and under
    an *active* :class:`~repro.serving.faults.FaultPlan` (crashes +
    stragglers + retries), on both engines.  ``fault_slowdown`` =
    fault-free events/s over faulted events/s per engine — it prices the
    crash/abort/retry machinery including the extra events it schedules,
    and the gate (``repro.eval.sched_gate``) caps it so the retry hooks
    can never quietly regress the event loop.  Both engines must agree
    exactly on the faulted outcome (asserted), the same bit-identity
    contract the chaos grid gates."""
    from repro.serving.faults import FaultPlan

    tick_ms, rate_per_ms = 4.0, 64.0
    sizes = (10_000, 100_000) if full else (10_000,)
    reps = 3
    # ~4 crashes over the 1e4-request trace's ~160 ms span; each abort
    # re-queues a full FIFO batch through the retry gate.
    plan = FaultPlan(
        seed=0,
        mttf_ms=40.0,
        restart_delay_ms=5.0,
        max_retries=2,
        retry_backoff_ms=1.0,
        straggler_prob=0.05,
        straggler_factor=3.0,
    )
    out: dict[str, dict[str, float]] = {}
    for n in sizes:
        master = _eventloop_requests(n, tick_ms, rate_per_ms)
        row: dict[str, float] = {}
        results = {}
        for engine in ("scalar", "array"):
            per_mode = {}
            for mode, faults in (("free", None), ("faulted", plan)):
                best = float("inf")
                for _ in range(reps):
                    reqs = [
                        Request(app_id=r.app_id, release=r.release, slo=r.slo,
                                true_time=r.true_time)
                        for r in master
                    ]
                    # object FIFO on BOTH engines: retries re-enter through
                    # the object on_arrival path, which the columnar FIFO
                    # deliberately refuses
                    workers = [Worker(_FifoObjScheduler(), _ConstExecutor())]
                    t0 = time.perf_counter()
                    res = run_event_loop(
                        reqs, workers, engine=engine, faults=faults
                    )
                    best = min(best, time.perf_counter() - t0)
                per_mode[mode] = (res.n_total + res.n_batches) / best
                if mode == "faulted":
                    results[engine] = res
            slowdown = per_mode["free"] / per_mode["faulted"]
            row[f"{engine}_faulted_events_per_s"] = round(per_mode["faulted"], 1)
            row[f"{engine}_fault_slowdown"] = round(slowdown, 3)
        sc, ar = results["scalar"], results["array"]
        assert (
            sc.n_finished_ok, sc.n_finished_late, sc.n_failed,
            sc.n_retried, sc.n_batches,
        ) == (
            ar.n_finished_ok, ar.n_finished_late, ar.n_failed,
            ar.n_retried, ar.n_batches,
        ), "engines diverged under the fault plan"
        row["n_retried"] = sc.n_retried
        row["n_failed"] = sc.n_failed
        print(f"eventloop_faults/array/n{n},"
              f"{1e6 / row['array_faulted_events_per_s']:.3f},"
              f"slowdown={row['array_fault_slowdown']:.2f}x "
              f"scalar_slowdown={row['scalar_fault_slowdown']:.2f}x "
              f"retried={sc.n_retried}",
              flush=True)
        out[str(n)] = row

    _merge_sched_artifact(json_path, {
        "eventloop_faults": {
            "unit_note": "events/s through run_event_loop under an active "
                         "FaultPlan (crashes mttf=40ms + 5% stragglers + "
                         "retry gate) vs fault-free on the same trace; "
                         "fault_slowdown = free/faulted rate per engine; "
                         "best of 3 reps",
            "plan": plan.to_dict(),
            "sizes": out,
        },
    })


class _FifoModelScheduler:
    """Object-path FIFO over per-model queues: arrivals bucket by
    ``model_id``, ``next_batch`` drains the most-backlogged model
    (deterministic tie-break by model name) and stamps ``Batch.model`` —
    the minimum a scheduler must do to drive a residency-managed run.
    As with the fault benchmark, the scheduler is near-free so the
    measured delta is the residency machinery, not scheduling."""

    reads_request_state = False

    def __init__(self, max_batch: int = 64) -> None:
        self.queues: dict[str, list[Request]] = {}
        self.max_batch = max_batch
        self.n_timed_out = 0

    def on_arrival(self, req: Request, now: float) -> None:
        self.queues.setdefault(req.model_id, []).append(req)

    def on_arrivals(self, reqs, now: float) -> None:
        for r in reqs:
            self.on_arrival(r, now)

    def next_batch(self, now: float):
        best = None
        for m in sorted(self.queues):
            q = self.queues[m]
            if q and (best is None or len(q) > len(self.queues[best])):
                best = m
        if best is None:
            return None, None
        q = self.queues[best]
        k = min(self.max_batch, len(q))
        picked = q[:k]
        del q[:k]
        return Batch(picked, k, model=best), None

    def on_batch_done(self, batch, now, alone) -> None:
        pass

    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self.queues.values())


def residency_churn(full: bool = False,
                    json_path: str = "BENCH_sched.json") -> None:
    """Residency-cache cost under churn (DESIGN.md §13), two measurements:

    ``acquire_us`` — µs per :meth:`ResidencyState.acquire` call on a
    Zipf-skewed model stream with a cache that holds ~1 resident model
    (every head/tail alternation evicts), per eviction policy.  The
    acquire sits on the dispatch hot path of every residency-managed
    batch, so the gate budgets it absolutely.

    ``residency_slowdown`` — events/s through ``run_event_loop`` on a
    multi-model FIFO trace, residency-free over residency-managed, per
    engine (same process, same trace, so the ratio is immune to runner
    load).  Both engines must agree exactly on the managed outcome
    (asserted) — the residency extension of the engine-equivalence
    contract."""
    from repro.serving.residency import ResidencyPlan, model_roster, zoo_profile
    from repro.serving.workload import zipf_weights

    # --- acquire micro: cache holds ~1 model, Zipf stream forces churn
    n_models = 6
    roster = model_roster(n_models)
    worker_mem = 1.05 * max(zoo_profile(m).nbytes for m in roster)
    n_calls = 200_000 if full else 50_000
    rng = np.random.default_rng(0)
    stream = rng.choice(n_models, size=n_calls,
                        p=zipf_weights(n_models, 1.1))
    names = [roster[i] for i in stream.tolist()]
    acquire_row: dict[str, float] = {}
    for policy in ("lru", "cost_aware"):
        plan = ResidencyPlan.from_zoo(roster, worker_mem=worker_mem,
                                      policy=policy)
        state = plan.start(1)
        t0 = time.perf_counter()
        now = 0.0
        for m in names:
            now += state.acquire(0, m, now)
        us = (time.perf_counter() - t0) / n_calls * 1e6
        acquire_row[f"{policy}_acquire_us"] = round(us, 3)
        acquire_row[f"{policy}_hit_rate"] = round(
            state.n_hits / n_calls, 3
        )
    print(f"residency/acquire,{acquire_row['lru_acquire_us']:.3f},"
          f"cost_aware_us={acquire_row['cost_aware_acquire_us']:.3f} "
          f"hit={acquire_row['lru_hit_rate']:.2f}",
          flush=True)

    # --- end-to-end: residency-managed vs residency-free FIFO replay
    plan4 = ResidencyPlan.from_zoo(model_roster(4),
                                   worker_mem=float(3 * 2**30))
    probs4 = zipf_weights(4, 1.1)
    roster4 = model_roster(4)
    sizes = (10_000, 100_000) if full else (10_000,)
    reps = 3
    out: dict[str, dict[str, float]] = {}
    for n in sizes:
        master = _eventloop_requests(n, tick_ms=4.0, rate_per_ms=64.0)
        which = np.random.default_rng(1).choice(4, size=n, p=probs4)
        for r, m in zip(master, which.tolist()):
            r.model_id = roster4[m]
        row: dict[str, float] = {}
        results = {}
        for engine in ("scalar", "array"):
            per_mode = {}
            for mode, residency in (("free", None), ("managed", plan4)):
                best = float("inf")
                for _ in range(reps):
                    reqs = [
                        Request(app_id=r.app_id, release=r.release, slo=r.slo,
                                true_time=r.true_time, model_id=r.model_id)
                        for r in master
                    ]
                    workers = [Worker(_FifoModelScheduler(), _ConstExecutor())]
                    t0 = time.perf_counter()
                    res = run_event_loop(
                        reqs, workers, engine=engine, residency=residency
                    )
                    best = min(best, time.perf_counter() - t0)
                per_mode[mode] = (res.n_total + res.n_batches) / best
                if mode == "managed":
                    results[engine] = res
            slowdown = per_mode["free"] / per_mode["managed"]
            row[f"{engine}_managed_events_per_s"] = round(
                per_mode["managed"], 1
            )
            row[f"{engine}_residency_slowdown"] = round(slowdown, 3)
        sc, ar = results["scalar"], results["array"]
        assert (
            sc.n_finished_ok, sc.n_finished_late, sc.n_batches,
            sc.n_model_loads, sc.n_model_evicts, sc.model_load_ms,
        ) == (
            ar.n_finished_ok, ar.n_finished_late, ar.n_batches,
            ar.n_model_loads, ar.n_model_evicts, ar.model_load_ms,
        ), "engines diverged under the residency plan"
        row["n_model_loads"] = sc.n_model_loads
        row["n_model_evicts"] = sc.n_model_evicts
        print(f"residency/eventloop/n{n},"
              f"{1e6 / row['array_managed_events_per_s']:.3f},"
              f"slowdown={row['array_residency_slowdown']:.2f}x "
              f"scalar_slowdown={row['scalar_residency_slowdown']:.2f}x "
              f"loads={sc.n_model_loads}",
              flush=True)
        out[str(n)] = row

    _merge_sched_artifact(json_path, {
        "residency": {
            "unit_note": "acquire = us per ResidencyState.acquire on a "
                         "Zipf model stream with a ~1-model cache (churn); "
                         "eventloop = events/s residency-free over "
                         "residency-managed on the same multi-model FIFO "
                         "trace per engine; best of 3 reps",
            "acquire": acquire_row,
            "sizes": out,
        },
    })


def _token_requests(n: int, rate_per_ms: float, ttft_ms: float,
                    tpot_ms: float, seed: int = 0) -> list[Request]:
    """Token-mode trace: geometric output lengths (mean 24), uniform
    prompts, Poisson arrivals, implied TTFT/TPOT deadlines.  Deadlines
    are generous relative to the DecodeModelExecutor step time so the
    length-aware scheduler admits rather than drops — the run measures
    hook cost, not SLO behaviour."""
    rng = np.random.default_rng(seed)
    out = np.maximum(rng.geometric(1.0 / 24.0, size=n), 1)
    prompts = rng.integers(16, 129, size=n)
    at = np.cumsum(rng.exponential(1.0 / rate_per_ms, size=n))
    return [
        Request(app_id="a", release=float(t),
                slo=ttft_ms + tpot_ms * (float(o) - 1.0),
                true_time=float(o), prompt_tokens=int(p), out_tokens=int(o))
        for t, o, p in zip(at, out, prompts)
    ]


def token_decode(full: bool = False,
                 json_path: str = "BENCH_sched.json") -> None:
    """Decode-step hook cost on the continuous-batching path (DESIGN.md
    §12).  Replays a token trace through ``run_event_loop`` with the
    :class:`~repro.core.eventloop.DecodeModelExecutor` and both token
    schedulers; ``decision_us`` = metered scheduler time over *all*
    decisions (``next_batch`` + one ``on_decode_step`` per token step —
    the latter dominates, firing once per step of every decode run), the
    per-call number ``repro.eval.sched_gate`` budgets absolutely: this
    hook runs on every token boundary, so unlike ``next_batch`` it has
    no batch of work to amortize against.  Scalar and array engines
    must agree exactly on the token outcome (asserted) — the
    continuous-batching extension of the engine-equivalence contract."""
    from repro.core.eventloop import DecodeModelExecutor
    from repro.core.tokensched import (
        FcfsTokenScheduler,
        LengthAwareTokenScheduler,
        TokenSchedConfig,
    )

    cfg = TokenSchedConfig(max_batch=16, ttft_slo_ms=200.0, tpot_slo_ms=12.0)
    # ~0.8 load on a worker continuously batching at k=16: k tokens per
    # (d0 + d1*k) ms step, E[out]=24 tokens per request.
    rate_per_ms = 0.8 * 16 / ((cfg.d0 + cfg.d1 * 16) * 24.0)
    sizes = (2_000, 10_000) if full else (2_000,)
    reps = 3
    systems = (
        ("token_fcfs", lambda: FcfsTokenScheduler(cfg)),
        ("token_orloj", lambda: LengthAwareTokenScheduler(cfg)),
    )
    out: dict[str, dict[str, float]] = {}
    for n in sizes:
        master = _token_requests(n, rate_per_ms, cfg.ttft_slo_ms,
                                 cfg.tpot_slo_ms)
        row: dict[str, float] = {}
        for name, mk in systems:
            results = {}
            per_engine: dict[str, float] = {}
            for engine in ("scalar", "array"):
                best_us, best_steps = float("inf"), 0.0
                for _ in range(reps):
                    reqs = [
                        Request(app_id=r.app_id, release=r.release,
                                slo=r.slo, true_time=r.true_time,
                                prompt_tokens=r.prompt_tokens,
                                out_tokens=r.out_tokens)
                        for r in master
                    ]
                    workers = [Worker(mk(), DecodeModelExecutor(
                        cfg.d0, cfg.d1, cfg.prefill_per_token))]
                    t0 = time.perf_counter()
                    res = run_event_loop(reqs, workers, engine=engine)
                    wall = time.perf_counter() - t0
                    best_us = min(
                        best_us, 1e3 * res.sched_time_ms / res.n_decisions
                    )
                    best_steps = max(best_steps, res.n_decisions / wall)
                results[engine] = res
                per_engine[engine] = best_us
            sc, ar = results["scalar"], results["array"]
            assert (sc.n_finished_ok, sc.n_finished_late, sc.n_dropped,
                    sc.n_batches, sc.n_decisions) == (
                ar.n_finished_ok, ar.n_finished_late, ar.n_dropped,
                ar.n_batches, ar.n_decisions
            ), f"engines diverged on the token trace under {name}"
            # The hook is pure scheduler python, identical on both
            # engines; record the cheaper measurement.
            row[f"{name}_decision_us"] = round(min(per_engine.values()), 3)
            row[f"{name}_steps_per_s"] = round(best_steps, 1)
            row[f"{name}_n_decisions"] = sc.n_decisions
        print(f"token_decode/orloj/n{n},{row['token_orloj_decision_us']:.3f},"
              f"fcfs_us={row['token_fcfs_decision_us']:.3f} "
              f"decisions={row['token_orloj_n_decisions']}",
              flush=True)
        out[str(n)] = row

    _merge_sched_artifact(json_path, {
        "token_decode": {
            "unit_note": "metered scheduler us per decision (next_batch + "
                         "on_decode_step, hook-dominated) through "
                         "run_event_loop with DecodeModelExecutor on a "
                         "geometric-length token trace at ~0.8 load; "
                         "best of 3 reps, min over engines",
            "max_batch": cfg.max_batch,
            "sizes": out,
        },
    })
