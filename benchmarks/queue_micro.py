"""Fig. 12: priority-queue insertion / query microbenchmark.

Reproduces the O(log² n) scaling study for our Bentley–Saxe hull queue
(the paper's Overmars–van Leeuwen replacement; DESIGN.md §Substitutions).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import HullQueue


def fig12_queue(full: bool = False) -> None:
    sizes = (10, 100, 1_000, 10_000) if not full else (10, 32, 100, 316, 1_000, 3_162, 10_000)
    rng = np.random.default_rng(0)
    for n in sizes:
        # --- insertion: average per-request time filling to n
        reps = 3
        ins_total = 0.0
        for _ in range(reps):
            q = HullQueue()
            coeffs = rng.normal(size=(n, 2)) * 50
            t0 = time.perf_counter()
            for i in range(n):
                q.insert(i, float(coeffs[i, 0]), float(coeffs[i, 1]))
            ins_total += time.perf_counter() - t0
        ins_us = ins_total / (reps * n) * 1e6

        # --- query with a line of random slope
        q = HullQueue()
        coeffs = rng.normal(size=(n, 2)) * 50
        for i in range(n):
            q.insert(i, float(coeffs[i, 0]), float(coeffs[i, 1]))
        xs = np.exp(rng.uniform(0, 10, size=100))
        t0 = time.perf_counter()
        for x in xs:
            q.argmax(float(x))
        qry_us = (time.perf_counter() - t0) / xs.size * 1e6

        log2n = np.log2(max(n, 2)) ** 2
        print(f"fig12/insert/n{n},{ins_us:.2f},log2sq={log2n:.1f}", flush=True)
        print(f"fig12/query/n{n},{qry_us:.2f},log2sq={log2n:.1f}", flush=True)


def fig12_mixed_ops(full: bool = False) -> None:
    """Sustained scheduler-like mix: insert + milestone updates + pops."""
    rng = np.random.default_rng(1)
    n = 5_000 if full else 2_000
    q = HullQueue()
    t0 = time.perf_counter()
    alive = []
    ops = 0
    for i in range(n):
        q.insert(i, float(rng.normal() * 50), float(rng.normal() * 50))
        alive.append(i)
        ops += 1
        if i % 3 == 0 and len(alive) > 4:
            k = alive.pop(rng.integers(0, len(alive)))
            q.update(k, float(rng.normal() * 50), float(rng.normal() * 50))
            alive.append(k)
            ops += 1
        if i % 5 == 0 and len(alive) > 8:
            got = q.pop_max(float(np.exp(rng.uniform(0, 8))))
            alive.remove(got[0])
            ops += 1
    us = (time.perf_counter() - t0) / ops * 1e6
    print(f"fig12/mixed/n{n},{us:.2f},ops={ops}", flush=True)
