"""Paper-table reproductions (Tables 2–5 / Figs. 3, 8–10, 11) — thin
formatting wrappers over the :mod:`repro.eval.grid` spec constructors."""

from __future__ import annotations

from repro.eval import grid

from .common import run_and_emit


def table2_bimodal_std(full: bool = False) -> None:
    """Table 2: bimodal request distributions with varying per-peak std."""
    run_and_emit(grid.table2(full))


def table3_modality(full: bool = False) -> None:
    """Table 3 / Fig. 8: one- to eight-modal distributions."""
    run_and_emit(grid.table3(full))


def fig9_unequal_peaks(full: bool = False) -> None:
    run_and_emit(grid.fig9(full))


def table4_static(full: bool = False) -> None:
    """Table 4 / Fig. 11: static models (no execution-time variance)."""
    run_and_emit(grid.table4(full))


def table5_real_tasks(full: bool = False) -> None:
    """Table 5: real model/dataset pairs fitted from published mean/P99."""
    run_and_emit(grid.table5(full))
