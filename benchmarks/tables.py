"""Paper-table reproductions (Tables 2–5 / Figs. 3, 8–10, 11)."""

from __future__ import annotations

from repro.serving.workload import (
    bimodal,
    k_modal,
    real_task,
    static,
    unequal_bimodal,
    REAL_TASKS,
)

from .common import case_rows, emit, run_case

SLOS_FULL = (1.5, 2.0, 3.0, 4.0, 5.0)
SLOS_FAST = (1.5, 3.0, 5.0)


def table2_bimodal_std(full: bool = False) -> None:
    """Table 2: bimodal request distributions with varying per-peak std."""
    slos = SLOS_FULL if full else SLOS_FAST
    cases = {
        "std-0.5": bimodal(0.5),
        "std-1": bimodal(1.0),
        "std-2": bimodal(2.0),
        "std-2/0.5": bimodal((2.0, 0.5)),
        "std-0.5/2": bimodal((0.5, 2.0)),
    }
    for case, apps in cases.items():
        for slo in slos:
            emit(case_rows("table2", case, slo, run_case(apps, slo)))


def table3_modality(full: bool = False) -> None:
    """Table 3 / Fig. 8: one- to eight-modal distributions."""
    slos = SLOS_FULL if full else SLOS_FAST
    ks = range(1, 9) if full else (1, 2, 3, 5, 8)
    for k in ks:
        for slo in slos:
            emit(case_rows("table3", f"{k}-modal", slo, run_case(k_modal(k), slo)))


def fig9_unequal_peaks(full: bool = False) -> None:
    slos = SLOS_FULL if full else SLOS_FAST
    for case in ("short", "long"):
        for slo in slos:
            emit(
                case_rows(
                    "fig9", f"more-{case}", slo, run_case(unequal_bimodal(case), slo)
                )
            )


def table4_static(full: bool = False) -> None:
    """Table 4 / Fig. 11: static models (no execution-time variance)."""
    slos = SLOS_FULL if full else SLOS_FAST
    for case, mean in (("inception", 12.0), ("resnet", 7.0)):
        for slo in slos:
            emit(
                case_rows(
                    "table4",
                    case,
                    slo,
                    run_case(static(mean), slo, utilization=0.7),
                )
            )


def table5_real_tasks(full: bool = False) -> None:
    """Table 5: real model/dataset pairs fitted from published mean/P99."""
    slos = SLOS_FULL if full else SLOS_FAST
    names = list(REAL_TASKS) if full else ["gpt-cornell", "bart-cnn", "skipnet-imagenet", "rdinet-cifar"]
    for name in names:
        for slo in slos:
            emit(case_rows("table5", name, slo, run_case(real_task(name), slo)))
