"""Scale-out study (beyond-paper; §3.1 replica pools): finish rate vs
replica count and front-end dispatch policy under overload, plus a
heterogeneous-pool study (fast + slow replicas) that only the unified
event engine can express."""

from __future__ import annotations

import numpy as np

from repro.core import BatchLatencyModel, ModelExecutor, OrlojScheduler
from repro.core.eventloop import DISPATCH_POLICIES, Worker, run_event_loop
from repro.serving.cluster import simulate_cluster
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

from .common import LM

POLICIES = tuple(DISPATCH_POLICIES)
SLOW_LM = BatchLatencyModel(c0=2 * LM.c0, c1=2 * LM.c1)


def _trace(n: int, utilization: float, seed: int = 13):
    return generate_requests(
        bimodal(1.0), LM, slo_scale=3.0,
        cfg=TraceConfig(n_requests=n, seed=seed, utilization=utilization),
    )


def cluster_scale(full: bool = False) -> None:
    replicas = (1, 2, 4, 8) if full else (1, 2, 4)
    n = 1_500 if full else 800
    for k in replicas:
        # offered load ≈ 0.8 × k single-worker capacities
        rs = _trace(n, utilization=0.8 * k)
        for policy in POLICIES:
            scheds = [
                OrlojScheduler(LM, initial_dists=rs.initial_dists())
                for _ in range(k)
            ]
            res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), policy=policy)
            print(
                f"cluster/{policy}/r{k},0,finish_rate={res.finish_rate:.3f};util={res.utilization:.2f}",
                flush=True,
            )


def cluster_hetero(full: bool = False) -> None:
    """Mixed pool: half fast, half slow replicas (2× latency model).  Work-
    and distribution-aware policies should exploit the asymmetry that
    count-based balancing cannot see."""
    n = 1_500 if full else 800
    k = 4
    # offered load ≈ 0.8 × the mixed pool's aggregate capacity (a slow
    # replica is worth half a fast one here)
    rs = _trace(n, utilization=0.8 * (k / 2 + k / 4))
    for policy in POLICIES:
        workers = []
        for i in range(k):
            lm = LM if i < k // 2 else SLOW_LM
            workers.append(
                Worker(
                    OrlojScheduler(lm, initial_dists=rs.initial_dists()),
                    ModelExecutor(lm, seed=i),
                )
            )
        res = run_event_loop(rs.fresh(), workers, policy=policy, seed=1)
        print(
            f"cluster_hetero/{policy}/r{k},0,finish_rate={res.finish_rate:.3f};util={res.utilization:.2f}",
            flush=True,
        )
