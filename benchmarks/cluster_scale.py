"""Scale-out study (beyond-paper; §3.1 replica pools): finish rate vs
replica count and load-balancing policy under overload."""

from __future__ import annotations

import numpy as np

from repro.core import ModelExecutor, OrlojScheduler
from repro.serving.cluster import simulate_cluster
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal

from .common import LM


def cluster_scale(full: bool = False) -> None:
    replicas = (1, 2, 4, 8) if full else (1, 2, 4)
    policies = ("least_loaded", "round_robin", "jsq_work")
    n = 1_500 if full else 800
    for k in replicas:
        # offered load ≈ 0.8 × k single-worker capacities
        rs = generate_requests(
            bimodal(1.0), LM, slo_scale=3.0,
            cfg=TraceConfig(n_requests=n, seed=13, utilization=0.8 * k),
        )
        for policy in policies:
            scheds = [
                OrlojScheduler(LM, initial_dists=rs.initial_dists())
                for _ in range(k)
            ]
            res = simulate_cluster(rs.fresh(), scheds, ModelExecutor(LM), policy=policy)
            print(
                f"cluster/{policy}/r{k},0,finish_rate={res.finish_rate:.3f};util={res.utilization:.2f}",
                flush=True,
            )
