"""Scale-out study (beyond-paper; §3.1 replica pools): finish rate vs
replica count and front-end dispatch policy under overload, plus a
heterogeneous-pool study (fast + slow replicas) — thin wrappers over the
:mod:`repro.eval.grid` spec constructors (the specs' ``n_workers`` /
``policy`` / ``hetero`` fields drive the unified event engine)."""

from __future__ import annotations

from repro.eval import grid

from .common import run_and_emit


def cluster_scale(full: bool = False) -> None:
    run_and_emit(grid.cluster(full))


def cluster_hetero(full: bool = False) -> None:
    """Mixed pool: half fast, half slow replicas (2x latency model).  Work-
    and distribution-aware policies should exploit the asymmetry that
    count-based balancing cannot see."""
    run_and_emit(grid.cluster_hetero(full))
