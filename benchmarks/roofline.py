"""Roofline analysis from the dry-run artifacts (deliverable g).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  cost_analysis FLOPs/bytes and the HLO-parsed collective
bytes are *per-device* quantities (validated against analytic 6·N·D for
olmo-1b), so each term is simply per-device-quantity / per-chip-rate:

    compute   = flops / 197e12        [s]
    memory    = bytes_accessed / 819e9 [s]
    collective= collective_bytes / 50e9 [s]

MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params —
the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch overhead.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config, for_shape

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    shape = SHAPES[shape_name]
    cfg = for_shape(get_config(arch), shape)
    n = cfg.n_active_params_estimate
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def load_rows(mesh: str = "16x16") -> list[dict]:
    rows = []
    for p in sorted(ART_DIR.glob(f"*_{mesh}.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh or not d.get("ok"):
            continue
        chips = CHIPS[mesh]
        # Corrected values extrapolate a per-layer body from an L0 compile
        # pair; XLA occasionally swaps collective strategies between the
        # pair, which can push a per-type delta negative — clamp to the
        # raw full-compile measurement as the floor.
        flops = max(d.get("flops_corrected") or 0.0, d["flops"])
        nbytes = max(d.get("bytes_corrected") or 0.0, d["bytes_accessed"])
        coll = {
            k: max(v, d["collective_bytes"].get(k, 0.0))
            for k, v in (d.get("collective_corrected") or d["collective_bytes"]).items()
        }
        coll_sum = sum(coll.values())
        t_c = flops / PEAK_FLOPS
        t_m = nbytes / HBM_BW
        t_n = coll_sum / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_n)), key=lambda e: e[1])
        mf = model_flops_per_device(d["arch"], d["shape"], chips)
        rows.append(
            dict(
                arch=d["arch"],
                shape=d["shape"],
                mesh=mesh,
                compute_s=t_c,
                memory_s=t_m,
                collective_s=t_n,
                dominant=dom[0],
                model_flops=mf,
                hlo_flops=flops,
                useful_ratio=mf / flops if flops else 0.0,
                coll_detail=coll,
                mem=d.get("per_device_memory", {}),
            )
        )
    return rows


ADVICE = {
    "compute": "reduce recompute (remat policy) or shard more compute onto idle axes",
    "memory": "fuse/keep activations in bf16, raise arithmetic intensity with larger tiles or batch",
    "collective": "reshard to cut all-gathers (move the collective off the critical path, overlap, or change the parallel axis)",
}


def roofline_report(mesh: str = "16x16") -> str:
    rows = load_rows(mesh)
    lines = [
        f"| arch | shape | compute s | memory s | collective s | bottleneck | MODEL/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {ADVICE[r['dominant']]} |"
        )
    return "\n".join(lines)


def bench_roofline(full: bool = False) -> None:
    for mesh in ("16x16",):
        for r in load_rows(mesh):
            print(
                f"roofline/{r['arch']}/{r['shape']}/{mesh},0,"
                f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
                f"collective_s={r['collective_s']:.3e};bottleneck={r['dominant']};"
                f"useful={r['useful_ratio']:.2f}",
                flush=True,
            )


if __name__ == "__main__":
    print(roofline_report())
