"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the complete
grids (paper-size); the default is a reduced sweep that finishes in
minutes on one CPU core.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,fig12]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="", help="comma-separated benchmark names")
    args = ap.parse_args()

    from . import ablation, cluster_scale, queue_micro, sensitivity, tables
    from .roofline import bench_roofline

    benches = {
        "ablation": ablation.ablation,
        "cluster": cluster_scale.cluster_scale,
        "cluster_hetero": cluster_scale.cluster_hetero,
        "table2": tables.table2_bimodal_std,
        "table3": tables.table3_modality,
        "fig9": tables.fig9_unequal_peaks,
        "table4": tables.table4_static,
        "table5": tables.table5_real_tasks,
        "fig12": queue_micro.fig12_queue,
        "fig12b": queue_micro.fig12_mixed_ops,
        "sched": queue_micro.sched_throughput,  # writes BENCH_sched.json
        "eventloop": queue_micro.eventloop_throughput,  # merges into BENCH_sched.json
        "eventloop_faults": queue_micro.eventloop_faults,  # merges into BENCH_sched.json
        "token_decode": queue_micro.token_decode,  # merges into BENCH_sched.json
        "residency": queue_micro.residency_churn,  # merges into BENCH_sched.json
        "fig13": sensitivity.fig13_b_sweep,
        "fig14": sensitivity.fig14_min_exec,
        "roofline": bench_roofline,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        fn(full=args.full)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
