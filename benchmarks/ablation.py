"""Beyond-paper ablations of ORLOJ's design choices — a thin wrapper over
the :func:`repro.eval.grid.ablation` spec grid.

- Algorithm-1 line-16 ordering: the prose ("earliest deadline first") vs
  the literal pseudocode ("(D, bs) descending") — see DESIGN.md
  §Substitutions.
- Per-app refinement of the drop-phase feasibility estimate
  (EstimateBatchLatency(r, bs) with the request's own distribution vs the
  pure §4.3 mixture).
- Distribution resolution (histogram bin count).
"""

from __future__ import annotations

from repro.eval import grid

from .common import run_and_emit


def ablation(full: bool = False) -> None:
    run_and_emit(grid.ablation(full))
