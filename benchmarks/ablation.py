"""Beyond-paper ablations of ORLOJ's design choices.

- Algorithm-1 line-16 ordering: the prose ("earliest deadline first") vs
  the literal pseudocode ("(D, bs) descending") — see DESIGN.md
  §Substitutions.
- Per-app refinement of the drop-phase feasibility estimate
  (EstimateBatchLatency(r, bs) with the request's own distribution vs the
  pure §4.3 mixture).
- Distribution resolution (histogram bin count).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    ModelExecutor,
    OrlojScheduler,
    SchedulerConfig,
    simulate,
)
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal, k_modal

from .common import LM


def _run(apps, slo, cfg: SchedulerConfig, seed=11) -> float:
    rs = generate_requests(
        apps, LM, slo_scale=slo, cfg=TraceConfig(n_requests=1_200, seed=seed)
    )
    sched = OrlojScheduler(LM, cfg=cfg, initial_dists=rs.initial_dists())
    return simulate(rs.fresh(), sched, ModelExecutor(LM)).finish_rate


def ablation(full: bool = False) -> None:
    apps = k_modal(3)
    slos = (1.5, 3.0, 5.0)
    variants = {
        "base": SchedulerConfig(),
        "paper-desc-order": SchedulerConfig(bs_order="paper_desc"),
        "no-refine": SchedulerConfig(refine_feasibility=False),
        "bins-4": SchedulerConfig(n_bins=4),
        "bins-32": SchedulerConfig(n_bins=32),
    }
    for name, cfg in variants.items():
        for slo in slos:
            fr = _run(apps, slo, cfg)
            print(f"ablation/{name}/slo{slo:g},0,finish_rate={fr:.3f}", flush=True)
