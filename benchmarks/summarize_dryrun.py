"""Generate experiments/dryrun_summary.md and experiments/roofline.md from
the dry-run artifacts."""

from __future__ import annotations

import json

from .roofline import ART_DIR, roofline_report

OUT_DIR = ART_DIR.parent


def dryrun_summary() -> str:
    rows = []
    for p in sorted(ART_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        mem = d.get("per_device_memory", {})
        rows.append(
            (
                d["arch"],
                d["shape"],
                d["mesh"],
                "OK" if d["ok"] else "FAIL",
                d.get("n_params", 0) / 1e9,
                d.get("flops_corrected", 0.0),
                sum(d.get("collective_corrected", {}).values()),
                mem.get("argument_size_in_bytes", 0) / 1e9,
                mem.get("temp_size_in_bytes", 0) / 1e9,
                d.get("seconds", 0.0),
            )
        )
    lines = [
        "# Dry-run summary (generated)",
        "",
        "| arch | shape | mesh | status | params (B) | HLO flops/dev | coll B/dev | args GB/dev | temps GB* | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r[0]} | {r[1]} | {r[2]} | {r[3]} | {r[4]:.1f} | {r[5]:.2e} "
            f"| {r[6]:.2e} | {r[7]:.2f} | {r[8]:.1f} | {r[9]:.0f} |"
        )
    n_ok = sum(1 for r in rows if r[3] == "OK")
    lines += [
        "",
        f"**{n_ok}/{len(rows)} combinations compile.**",
        "",
        "*temp sizes come from the CPU backend's unpartitioned scheduling and"
        " over-estimate device temps; argument sizes are per-device.",
    ]
    return "\n".join(lines)


def main() -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / "dryrun_summary.md").write_text(dryrun_summary())
    report = ["# Roofline (generated)"]
    for mesh in ("16x16",):
        report += [f"\n## mesh {mesh}\n", roofline_report(mesh)]
    (OUT_DIR / "roofline.md").write_text("\n".join(report))
    print("wrote", OUT_DIR / "dryrun_summary.md", "and", OUT_DIR / "roofline.md")


if __name__ == "__main__":
    main()
