"""Shared benchmark plumbing — thin formatting layer over ``repro.eval``.

The sweeps themselves are typed spec grids (:mod:`repro.eval.grid`); this
module renders :class:`~repro.eval.spec.ExperimentResult` s back into the
historical ``name,us_per_call,derived`` CSV rows so ``python -m
benchmarks.run`` output keeps its schema.  The ``us_per_call`` column is
the *scheduler decision time* per request (time inside scheduler hooks,
measured by the event loop) — not the whole simulation wall-clock.
"""

from __future__ import annotations

from repro.eval.runner import run_specs
from repro.eval.spec import ExperimentResult, ExperimentSpec


def emit(rows: list[str]) -> None:
    for r in rows:
        print(r, flush=True)


def legacy_rows(results: list[ExperimentResult]) -> list[str]:
    """``name,us_per_call,derived`` rows; the name is the spec's tag."""
    rows = []
    for r in results:
        derived = f"finish_rate={r.finish_rate:.3f}"
        # Pool sweeps always report utilization (the legacy cluster rows
        # did so even for the 1-replica anchor).
        if r.spec.n_workers > 1 or r.spec.tag.startswith("cluster"):
            derived += f";util={r.utilization:.2f}"
        rows.append(f"{r.spec.tag},{r.sched_us_per_request:.1f},{derived}")
    return rows


def run_and_emit(specs: list[ExperimentSpec]) -> None:
    emit(legacy_rows(run_specs(specs)))
