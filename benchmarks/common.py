"""Shared benchmark plumbing: run the four systems on a workload and emit
``name,us_per_call,derived`` CSV rows (one benchmark per paper table/figure)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    BatchLatencyModel,
    ClipperScheduler,
    ClockworkScheduler,
    ModelExecutor,
    NexusScheduler,
    OrlojScheduler,
    SchedulerConfig,
    simulate,
)
from repro.serving.trace import TraceConfig, generate_requests

LM = BatchLatencyModel(c0=25.0, c1=1.0)
SYSTEMS = ("orloj", "clockwork", "nexus", "clipper")


def run_case(
    apps,
    slo_scale: float,
    *,
    n_requests: int = 1_200,
    utilization: float = 0.85,
    seed: int = 7,
    lm: BatchLatencyModel | None = None,
    systems=SYSTEMS,
) -> dict[str, tuple[float, float]]:
    """Returns {system: (finish_rate, scheduler_us_per_request)}."""
    lm = lm or LM
    rs = generate_requests(
        apps,
        lm,
        slo_scale=slo_scale,
        cfg=TraceConfig(n_requests=n_requests, utilization=utilization, seed=seed),
    )
    warm = np.concatenate(list(rs.app_history.values()))
    out = {}
    for name in systems:
        if name == "orloj":
            sched = OrlojScheduler(lm, initial_dists=rs.initial_dists())
        else:
            cls = {
                "clockwork": ClockworkScheduler,
                "nexus": NexusScheduler,
                "clipper": ClipperScheduler,
            }[name]
            sched = cls(lm, init_samples=warm)
        reqs = rs.fresh()
        t0 = time.perf_counter()
        res = simulate(reqs, sched, ModelExecutor(lm))
        wall = time.perf_counter() - t0
        out[name] = (res.finish_rate, wall / n_requests * 1e6)
    return out


def emit(rows: list[str]) -> None:
    for r in rows:
        print(r, flush=True)


def case_rows(table: str, case: str, slo: float, result) -> list[str]:
    return [
        f"{table}/{case}/slo{slo:g}/{sys},{us:.1f},finish_rate={fr:.3f}"
        for sys, (fr, us) in result.items()
    ]
