"""Demonstrates the §4.4 machinery directly: priority scores over time
(the paper's Fig. 6 toy example) and the dynamic convex-hull queue.

    PYTHONPATH=src python examples/priority_queue_demo.py
"""

import numpy as np

from repro.core import (
    BatchLatencyModel,
    BinScoreModel,
    EmpiricalDistribution,
    HullQueue,
    ModelExecutor,
    OrlojScheduler,
    Request,
    Worker,
    hetero_max,
    run_event_loop,
)


def main() -> None:
    # Two request types with the same mean: one concentrated, one bimodal
    # (exactly Fig. 6a).
    d1 = EmpiricalDistribution(np.array([90.0, 110.0]), np.array([1.0]))
    d2 = EmpiricalDistribution(
        np.array([20.0, 40.0, 160.0, 180.0]), np.array([0.5, 0.0, 0.5])
    )
    print(f"means: d1={d1.mean():.1f} d2={d2.mean():.1f}")

    # Fig. 6b: the batch max distribution skews right.
    batch = hetero_max([d1, d2])
    lm = BatchLatencyModel(c0=0.0, c1=0.5)  # c1·k = 1 for k = 2 (paper toy)
    print(f"E[batch max] = {batch.mean():.1f} (> each mean: straggler effect)")

    # Fig. 6c: three requests entering one after another.
    model = BinScoreModel(lm.batch_dist(batch, 2))
    reqs = [Request(app_id="a", release=t0, slo=400.0, true_time=0) for t0 in (0.0, 120.0, 240.0)]
    print(f"{'t':>6s}" + "".join(f"  r{i+1:>8d}" for i in range(3)))
    for t in np.linspace(0, 650, 14):
        scores = [model.value(r, t, 0.0) if t >= r.release else float('nan') for r in reqs]
        print(f"{t:6.0f}" + "".join(f"  {s:8.3f}" for s in scores))

    # The O(log² n) queue: top-priority request via a line query.
    q = HullQueue()
    for i, r in enumerate(reqs):
        sc = model.score(r, 300.0, 0.0)
        q.insert(i, sc.alpha, sc.beta)
    x = np.exp(model.b * 300.0)
    top, val = q.argmax(x)
    print(f"\nat t=300 the hull queue selects r{top+1} (score {val:.3f})")

    # The same machinery end-to-end: the scores above drive Algorithm 1
    # inside the unified event engine — one worker, then a two-replica pool
    # on the identical trace (§3.1 scale-out, same substrate).
    lm2 = BatchLatencyModel(c0=5.0, c1=1.0)
    rng = np.random.default_rng(0)
    dists = {"a": d1, "b": d2}
    trace = [
        Request(
            app_id="a" if i % 2 == 0 else "b",
            release=float(i * 40.0),
            slo=600.0,
            true_time=float((d1 if i % 2 == 0 else d2).sample(rng, 1)[0]),
        )
        for i in range(40)
    ]

    def replica():
        return Worker(OrlojScheduler(lm2, initial_dists=dists), ModelExecutor(lm2))

    def clone():
        return [
            Request(app_id=r.app_id, release=r.release, slo=r.slo, true_time=r.true_time)
            for r in trace
        ]

    one = run_event_loop(clone(), [replica()])
    two = run_event_loop(clone(), [replica(), replica()], policy="p2c")
    print(f"\nevent loop, 1 worker : {one.summary()}")
    print(f"event loop, 2 workers: {two.summary()} (p2c dispatch)")


if __name__ == "__main__":
    main()
