"""Train a small model end to end on the synthetic corpus (data pipeline →
sharded train step → AdamW → checkpoint), verifying the loss decreases.

    PYTHONPATH=src python examples/train_small.py
"""

import subprocess
import sys


def main() -> None:
    subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.train",
            "--arch",
            "orloj_gpt",
            "--steps",
            "60",
            "--batch",
            "8",
            "--seq",
            "128",
            "--log-every",
            "20",
        ],
        check=True,
    )


if __name__ == "__main__":
    main()
