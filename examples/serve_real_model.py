"""End-to-end driver (the paper's kind is *serving*): serve a real JAX
model with batched requests under ORLOJ scheduling, with measured
execution times feeding the online profiler.

    PYTHONPATH=src python examples/serve_real_model.py
"""

import numpy as np

from repro.configs import get_config
from repro.configs.orloj_gpt import SERVE_BATCH_SIZES, SERVE_BUCKETS
from repro.core import EmpiricalDistribution, OrlojScheduler, SchedulerConfig
from repro.core.baselines import ClockworkScheduler
from repro.serving.engine import EngineConfig, ServingEngine


def main() -> None:
    cfg = get_config("orloj_gpt").reduced(vocab_size=8192)
    ecfg = EngineConfig(buckets=SERVE_BUCKETS, batch_sizes=SERVE_BATCH_SIZES)
    engine = ServingEngine(cfg, ecfg)

    print("profiling the Eq.-3 latency curve on this machine ...")
    lm = engine.profile_latency_model()
    print(f"  c0 = {lm.c0:.2f} ms, c1 = {lm.c1:.4f} ms/token")

    def lengths(rng):  # short chats + long documents (dynamic NLP case)
        return int(
            np.clip(rng.normal(40, 12), 4, 256)
            if rng.random() < 0.7
            else np.clip(rng.normal(200, 30), 4, 256)
        )

    for name in ("orloj", "clockwork"):
        reqs, hist = engine.make_requests(
            100, lm, length_sampler=lengths, slo_scale=3.0, utilization=0.6
        )
        if name == "orloj":
            dists = {
                a: EmpiricalDistribution.from_samples(x) for a, x in hist.items()
            }
            sched = OrlojScheduler(
                lm,
                cfg=SchedulerConfig(batch_sizes=ecfg.batch_sizes),
                initial_dists=dists,
            )
        else:
            sched = ClockworkScheduler(
                lm,
                batch_sizes=ecfg.batch_sizes,
                init_samples=np.concatenate(list(hist.values())),
            )
        res = engine.serve(reqs, sched)
        print(f"{name:10s} {res.summary()}")


if __name__ == "__main__":
    main()
