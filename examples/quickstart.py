"""Quickstart: ORLOJ vs. the baselines on a dynamic-DNN workload (paper
Fig. 3 in miniature).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    BatchLatencyModel,
    ClipperScheduler,
    ClockworkScheduler,
    ModelExecutor,
    NexusScheduler,
    OrlojScheduler,
    simulate,
)
from repro.serving.trace import TraceConfig, generate_requests
from repro.serving.workload import bimodal


def main() -> None:
    # Eq. 3 latency model: 25 ms fixed overhead + 1 ms per size unit.
    lm = BatchLatencyModel(c0=25.0, c1=1.0)
    apps = bimodal(std=1.0)  # two applications, short & long requests

    print(f"{'SLO×P99':>8s} {'orloj':>8s} {'clockwork':>10s} {'nexus':>8s} {'clipper':>8s}")
    for slo_scale in (1.5, 2.0, 3.0, 5.0):
        rs = generate_requests(
            apps, lm, slo_scale=slo_scale,
            cfg=TraceConfig(n_requests=1_500, utilization=0.85, seed=7),
        )
        warm = np.concatenate(list(rs.app_history.values()))
        row = []
        for mk in (
            lambda: OrlojScheduler(lm, initial_dists=rs.initial_dists()),
            lambda: ClockworkScheduler(lm, init_samples=warm),
            lambda: NexusScheduler(lm, init_samples=warm),
            lambda: ClipperScheduler(lm, init_samples=warm),
        ):
            res = simulate(rs.fresh(), mk(), ModelExecutor(lm))
            row.append(res.finish_rate)
        print(
            f"{slo_scale:8.1f} {row[0]:8.2f} {row[1]:10.2f} {row[2]:8.2f} {row[3]:8.2f}"
        )


if __name__ == "__main__":
    main()
